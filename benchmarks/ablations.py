"""MARS design-point ablations (beyond the paper's single configuration).

The paper fixes RequestQ=512, PhyPageList=128x2-way and reports one point.
These ablations justify (or challenge) that design point under our
reproduction: sweep each structure while holding the rest at paper values,
measure mean bandwidth uplift over WL1-WL5.

Emits ``name,us_per_call,derived`` rows; derived = mean BW uplift.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import dram, experiment, mars, streams

RPC = 128  # keep each point cheap; trends match rpc=256


def _uplift(mars_cfg) -> float:
    res = experiment.run_all(mars_cfg=mars_cfg, reqs_per_core=RPC)
    return float(np.mean([r.bw_uplift for r in res]))


def sweep(emit, name, field, values):
    for v in values:
        cfg = mars.MarsConfig(**{field: v})
        t0 = time.perf_counter()
        u = _uplift(cfg)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"ablation/{name}/{v}", us, f"bw_uplift={100*u:.1f}%")


def run(emit):
    # lookahead window: the paper's central claim is that 512 >> MC queue
    sweep(emit, "request_q", "request_q", [64, 128, 256, 512, 1024])
    # page-tracking capacity and associativity
    sweep(emit, "page_entries", "page_entries", [32, 64, 128, 256])
    sweep(emit, "ways", "ways", [1, 2, 4])
    # boundary concurrency
    sweep(emit, "n_ports", "n_ports", [1, 2, 8])
    sweep(emit, "mshr", "mshr_per_core", [4, 16, 64])
