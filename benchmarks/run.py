"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV.  One section per paper
table/figure plus the TPU-adaptation kernel benchmarks.

``--smoke`` runs a reduced pass of the sections that support it (the
placement/eviction/decode-path benches) and skips the rest — cheap enough
for CI, so the benches cannot silently rot.

CI regression gate: the placement/decode bandwidth numbers come from the
seeded churn workload through the deterministic DRAM model, so they are
bit-stable across machines.  ``--update-baseline`` snapshots them into
``results/bench_baseline.json``; ``--baseline <path>`` compares the
current run against a snapshot and exits non-zero on a >10% regression
(wall-clock ``us_per_call`` is never compared — only simulated
bandwidth/hit-rate values).  ``--json <path>`` dumps every emitted row
for artifact upload.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import re
import sys

# keys gated against the baseline: deterministic DRAM-simulation /
# allocator-churn outputs (tier & alloc rows are seeded and bit-stable;
# their wall-clock lives in the ungated us column)
_GATED = re.compile(r"^kvcache/(placement|decode|alloc|tier|sched)/")
_BASELINE_DEFAULT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "bench_baseline.json")
_REGRESSION_TOLERANCE = 0.10
# per-key overrides of the default tolerance
_TOLERANCES = {
    # instrumented-vs-bare decode efficiency: 100 = metrics are free; the
    # ISSUE gate is <5% overhead, so fail below 95
    "kvcache/decode/obs/efficiency": 0.05,
    # pipelined-vs-sequential decode step throughput: 100 = tie; the
    # pipeline must not fall behind the synchronous path, with a wide
    # allowance for shared-CI wall-clock jitter
    "kvcache/decode/pipeline/single": 0.30,
    "kvcache/decode/pipeline/shards2": 0.30,
    "kvcache/decode/pipeline/tiered": 0.30,
    # class-aware vs class-blind interactive p99 ratio: 100 = tie; the
    # staged scheduler must improve chat tail latency under overload
    # (small slack for cross-version token drift in the smoke LM)
    "kvcache/sched/class/single/interactive-p99": 0.05,
    "kvcache/sched/class/shards2/interactive-p99": 0.05,
    # batch-class token throughput vs class-blind: the acceptance cap is
    # "within 10%", which is exactly the default tolerance against the
    # pinned 100 reference
}
# keys whose baseline is a definitional reference point, not a measured
# snapshot — pinned so --update-baseline cannot drift the gate (wall-clock
# ratios can exceed 100 by noise; the gate must stay "within 5% of free"
# resp. "pipelined >= sequential")
_PINNED = {
    "kvcache/decode/obs/efficiency": 100.0,
    "kvcache/decode/pipeline/single": 100.0,
    "kvcache/decode/pipeline/shards2": 100.0,
    "kvcache/decode/pipeline/tiered": 100.0,
    "kvcache/sched/class/single/interactive-p99": 100.0,
    "kvcache/sched/class/shards2/interactive-p99": 100.0,
    "kvcache/sched/class/single/batch-tput": 100.0,
    "kvcache/sched/class/shards2/batch-tput": 100.0,
}


def _parse_value(derived: str):
    """Leading float of a derived string ("3.21GB/s", "42.5%hit")."""
    m = re.match(r"^-?\d+(\.\d+)?", derived)
    return float(m.group(0)) if m else None


def check_baseline(rows, baseline: dict) -> list[str]:
    """Regressions below baseline among the gated keys (default tolerance
    10%; per-key overrides in ``_TOLERANCES``)."""
    current = {r["name"]: _parse_value(r["derived"]) for r in rows}
    failures = []
    for key, want in baseline.items():
        got = current.get(key)
        tol = _TOLERANCES.get(key, _REGRESSION_TOLERANCE)
        if got is None:
            failures.append(f"{key}: missing from current run "
                            f"(baseline {want})")
        elif want > 0 and got < want * (1 - tol):
            failures.append(f"{key}: {got} vs baseline {want} "
                            f"({100 * (got / want - 1):+.1f}%, "
                            f"tolerance {100 * tol:.0f}%)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark section name")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI pass; sections without smoke support "
                         "are skipped")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the emitted rows as JSON (CI artifact)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="compare placement/decode bandwidth rows against "
                         "a checked-in baseline; fail on >10% regression")
    ap.add_argument("--update-baseline", action="store_true",
                    help=f"refresh {_BASELINE_DEFAULT} from this run "
                         "(forces --smoke: the baseline gates the CI "
                         "smoke pass, so it must be built from the same "
                         "row set and seeds)")
    args = ap.parse_args()
    if args.update_baseline:
        args.smoke = True

    rows: list[dict] = []

    def _emit(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    sections = []
    from benchmarks import paper_figures
    sections.append(("paper_figures", paper_figures.run))
    try:
        from benchmarks import kernel_benches
        sections.append(("kernel_benches", kernel_benches.run))
    except ImportError:
        pass
    try:
        from benchmarks import ablations
        sections.append(("ablations", ablations.run))
    except ImportError:
        pass
    try:
        from benchmarks import kvcache_bench
        sections.append(("kvcache_bench", kvcache_bench.run))
    except ImportError:
        pass

    print("name,us_per_call,derived")
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        smoke_aware = "smoke" in inspect.signature(fn).parameters
        if args.smoke:
            if smoke_aware:
                fn(_emit, smoke=True)
            continue
        fn(_emit)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": rows}, f, indent=2)
        print(f"[bench] wrote {len(rows)} rows to {args.json}",
              file=sys.stderr)

    if args.update_baseline:
        snap = {r["name"]: _parse_value(r["derived"]) for r in rows
                if _GATED.match(r["name"])
                and _parse_value(r["derived"]) is not None}
        assert snap, "no gated rows emitted (did --only filter out kvcache?)"
        for key, pin in _PINNED.items():
            if key in snap:
                snap[key] = pin
        with open(_BASELINE_DEFAULT, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[bench] baseline refreshed: {len(snap)} keys -> "
              f"{_BASELINE_DEFAULT}", file=sys.stderr)

    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures = check_baseline(rows, baseline)
        for msg in failures:
            print(f"[bench] REGRESSION {msg}", file=sys.stderr)
        if failures:
            sys.exit(1)
        print(f"[bench] baseline check passed ({len(baseline)} keys)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
