"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV.  One section per paper
table/figure plus the TPU-adaptation kernel benchmarks.

``--smoke`` runs a reduced pass of the sections that support it (the
placement/eviction benches) and skips the rest — cheap enough for CI, so
the benches cannot silently rot.
"""
from __future__ import annotations

import argparse
import inspect
import sys


def _emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark section name")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI pass; sections without smoke support "
                         "are skipped")
    args = ap.parse_args()

    sections = []
    from benchmarks import paper_figures
    sections.append(("paper_figures", paper_figures.run))
    try:
        from benchmarks import kernel_benches
        sections.append(("kernel_benches", kernel_benches.run))
    except ImportError:
        pass
    try:
        from benchmarks import ablations
        sections.append(("ablations", ablations.run))
    except ImportError:
        pass
    try:
        from benchmarks import kvcache_bench
        sections.append(("kvcache_bench", kvcache_bench.run))
    except ImportError:
        pass

    print("name,us_per_call,derived")
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        smoke_aware = "smoke" in inspect.signature(fn).parameters
        if args.smoke:
            if smoke_aware:
                fn(_emit, smoke=True)
            continue
        fn(_emit)


if __name__ == "__main__":
    main()
