"""Paper-figure benchmarks: one function per paper artifact.

Fig 2  -> bench_locality      (locality vs window vs core count)
Fig 7  -> bench_bandwidth     (achieved-BW uplift per workload)
Fig 8  -> bench_cas_act       (CAS/ACT uplift per workload)

Each emits ``name,us_per_call,derived`` CSV rows (derived = the figure's
headline quantity).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import experiment, streams

RPC = 256


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def bench_locality(emit) -> None:
    loc, us = _timed(lambda: experiment.locality_experiment(reqs_per_core=512))
    for series, vals in loc.items():
        for w, v in vals.items():
            emit(f"fig2/locality/{series}/w{w}", us / max(len(loc), 1),
                 f"{v:.3f}")


def _workload_results():
    return experiment.run_all(reqs_per_core=RPC)


def bench_bandwidth(emit, results=None) -> None:
    if results is None:
        results, us = _timed(_workload_results)
    else:
        us = 0.0
    for r in results:
        emit(f"fig7/bw_uplift/{r.name}", us / 5, f"{100 * r.bw_uplift:.2f}%")
    mean = np.mean([r.bw_uplift for r in results])
    emit("fig7/bw_uplift/mean", us / 5, f"{100 * mean:.2f}%")


def bench_cas_act(emit, results=None) -> None:
    if results is None:
        results, us = _timed(_workload_results)
    else:
        us = 0.0
    for r in results:
        emit(f"fig8/cas_act_uplift/{r.name}", us / 5,
             f"{100 * r.cas_act_uplift:.2f}%")
    mean = np.mean([r.cas_act_uplift for r in results])
    emit("fig8/cas_act_uplift/mean", us / 5, f"{100 * mean:.2f}%")


def run(emit) -> None:
    bench_locality(emit)
    results, us = _timed(_workload_results)
    bench_bandwidth(emit, results)
    bench_cas_act(emit, results)
    emit("paper/workload_sim_total", us, f"{len(results)}wl")
