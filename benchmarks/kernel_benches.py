"""TPU-adaptation benchmarks (beyond-paper): MARS dispatch/gather vs the
locality-oblivious baselines.

CPU wall-time is NOT the roofline metric (that's the dry-run's job); what
these benches report as ``derived`` is the access-pattern statistic the
reorder exists to improve — destination-run length (the CAS/ACT analogue)
— plus compute-cost ratios of baseline vs MARS paths.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _run_len(a: np.ndarray) -> float:
    return float(np.diff(np.flatnonzero(np.concatenate(
        [[True], a[1:] != a[:-1], [True]]))).mean())


def _timeit(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def bench_moe_dispatch(emit):
    from repro.models import moe as moe_mod
    from repro.models.config import ModelConfig
    from repro.kernels.moe_dispatch import ops

    cfg = ModelConfig(name="bench", family="moe", n_layers=1, d_model=256,
                      n_heads=4, n_kv_heads=4, d_ff=512, vocab=128,
                      n_experts=32, top_k=2, d_expert=512)
    params = moe_mod.moe_init(jax.random.key(0), cfg).params
    T = 2048
    x = jax.random.normal(jax.random.key(1), (T, cfg.d_model))
    idx, gates, _ = moe_mod.router_topk(params, x, cfg)

    us_mars = _timeit(jax.jit(lambda x, i, g: ops.mars_moe_ffn(
        x, i, g, params["w_in"], params["w_gate"], params["w_out"],
        n_experts=32)), x, idx, gates)
    us_base = _timeit(jax.jit(lambda x: moe_mod.moe_apply_einsum(
        params, x, cfg)[0]), x)
    flat = np.asarray(idx).reshape(-1)
    emit("moe_dispatch/mars_sorted", us_mars,
         f"run_len={_run_len(np.sort(flat)):.1f}")
    emit("moe_dispatch/einsum_baseline", us_base,
         f"run_len={_run_len(flat):.2f}")
    emit("moe_dispatch/speedup", 0.0, f"{us_base/us_mars:.2f}x")


def bench_mars_gather(emit):
    from repro.kernels.mars_gather import ops
    table = jax.random.normal(jax.random.key(0), (1 << 15, 512))
    rng = np.random.default_rng(0)
    ids = jnp.asarray((rng.zipf(1.3, 1 << 14) % (1 << 15)).astype(np.int32))
    us_plain = _timeit(jax.jit(lambda t, i: ops.embedding_gather(
        t, i, mode="plain")), table, ids)
    us_sorted = _timeit(jax.jit(lambda t, i: ops.embedding_gather(
        t, i, mode="sorted")), table, ids)
    pages = np.asarray(ids) >> 2
    emit("mars_gather/plain", us_plain,
         f"page_run={_run_len(pages):.2f}")
    emit("mars_gather/sorted", us_sorted,
         f"page_run={_run_len(np.sort(pages)):.1f}")


def bench_scheduler(emit):
    from repro.serving.scheduler import MarsScheduler, Request, \
        unique_prefix_blocks
    rng = np.random.default_rng(0)
    prefixes = [tuple(rng.integers(1, 100, 16).tolist()) for _ in range(16)]
    reqs = [Request(rid=i, prompt=prefixes[i % 16]
                    + tuple(rng.integers(1, 100, 4).tolist()),
                    arrival=i * 1e-3, prefix_len=16) for i in range(256)]
    for mars in (False, True):
        sched = MarsScheduler(mars=mars)
        pend = list(reqs)
        blocks, batches = 0, 0
        t0 = time.perf_counter()
        while pend or len(sched):
            while pend and sched.offer(pend[0]):
                pend.pop(0)
            b = sched.schedule_batch(16, now=1.0)
            if not b:
                break
            blocks += unique_prefix_blocks(b)
            batches += 1
        us = (time.perf_counter() - t0) * 1e6
        emit(f"scheduler/{'mars' if mars else 'fifo'}", us,
             f"prefix_blocks_per_batch={blocks/max(batches,1):.2f}")


def bench_mars_engine(emit):
    from repro.core import mars, streams
    wl = streams.make_workload("WL1", reqs_per_core=128)
    ports = np.asarray(wl.source) // 8
    t0 = time.perf_counter()
    perm, stats = mars.mars_reorder(wl.addr, ports,
                                    src=np.asarray(wl.source))
    us = (time.perf_counter() - t0) * 1e6
    emit("mars_engine/reorder_8192req", us,
         f"cycles={stats['total_cycles']}")


def run(emit):
    bench_moe_dispatch(emit)
    bench_mars_gather(emit)
    bench_scheduler(emit)
    bench_mars_engine(emit)
