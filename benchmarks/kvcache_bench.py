"""KV-cache placement benchmark: MARS-aware vs naive block placement.

Serving workload through the paper's DRAM model: a pool is churned by
arriving/finishing sequences until fragmented, then a decode batch's full
KV gather (``kernels.paged_attention.ops.kv_read_trace`` — per-lane block
reads interleaved by the parallel gather) is served by
``core.dram.simulate``.  MARS placement packs each sequence's blocks into
few DRAM row neighborhoods, so the interleaved lanes land in distinct
banks instead of thrashing rows; the naive LIFO free list scatters blocks
after churn.

Emits ``kvcache/<placement>/...`` rows plus the headline uplift, and the
same traces after a bounded-window ``reorder.mars_order`` pass (the MC-side
MARS reorder buffer) to show placement and reordering compose.

Decode-path section (``kvcache/decode/...``): the same fragmented pool
read two ways — the gather path's round-robin lane interleave
(``ops.kv_read_trace``) vs the Pallas kernel's sequence-major page walk
(``ops.kv_read_trace_kernel``) — through ``core.dram.simulate``, reporting
bandwidth and row-buffer hit rate.  The kernel path never interleaves
lanes, so its hit rate bounds the gather path's from above; this is the
bandwidth MARS placement actually delivers to the attention kernel.

Sharded section (``kvcache/placement/sharded/...``): the same churn
schedule run through a mesh-sharded pool (``sharded_placement_comparison``)
— sequences routed to the least-loaded shard, each shard's decode lanes
traced and replayed through ``core/dram.simulate`` as its *own* memory
device.  Per-device interleave is shallower than the single pool's, so
shard-routed MARS row-hit bounds single-pool MARS which bounds naive;
aggregate bandwidth sums across devices (the scale-out half).

Eviction section (ROADMAP "online eviction tuning"): a skewed-prefix
workload — request popularity Zipf-distributed over prompt prefixes —
drives the prefix cache under memory pressure and reports the FIFO
(PhyPageOrderQ first-arrival) vs LRU hit rates side by side.  FIFO evicts
hot prefixes simply because they are old; LRU keeps them resident, so its
hit rate should pull ahead as the skew sharpens.

Tier section (``kvcache/tier/...``): the tiered KV memory layer
(``kvcache.tiers``) at the tier boundary.  ``tiered_promotion_comparison``
replays the *write* stream of one batched promotion copy-in through
``core/dram.simulate`` twice — MARS-reordered by destination row group vs
naive arrival order over the identical scattered destination set — the
paper's source-side reorder applied to inter-tier traffic.
``tiered_eviction_comparison`` runs the same deep/shallow prefix stream
under cost-aware vs LRU eviction: cost mode spends evictions on blocks
that are cheap to re-acquire (clean tier copy, shallow recompute) and
keeps deep chains resident, so its token reuse pulls ahead and its
recompute bill drops.

Allocator soak section (``kvcache/alloc/...``): multi-round Zipf-sized
alloc/free churn over ``BlockPool`` and ``ShardedBlockPool`` — long-run
fragmentation (mean free-run length, live-table row-group locality) plus
per-alloc wall latency in the us column.

Decode-pipeline section (``kvcache/decode/pipeline/...``): wall-clock
A/B of the split-phase backend lifecycle (``flush -> dispatch_decode ->
sync``; KV write-back one step deferred, mirrors double-buffered)
against the synchronous ``decode()`` wrapper, twin real-LM backends
serving identical ragged lanes in single-pool, 2-shard, and tiered
configurations.  Decode runs a genuinely compiled path — the Pallas
kernel non-interpret where the jax backend supports it, else the jitted
XLA gather decode (CPU Pallas only runs interpreted, which is not a
wall-clock measurement).  Greedy tokens must be bit-identical; the
derived column is 100 * t_sequential / t_pipelined (>= 100: the
pipeline at least matches sequential step throughput).

Traffic-class section (``kvcache/sched/class/...``): SMS staged
scheduling + decode preemption under overload
(``mixed_traffic_comparison``) — an identical mixed chat/batch/long-
context stream (Zipf prefix popularity, fake step clock, deliberately
undersized pool) served by the class-aware scheduler and the class-blind
one, single-pool and 2-shard.  Gated rows are pinned ratios:
interactive-class p99 turnaround must improve (>= ~100) while batch-
class token throughput stays within 10% of class-blind.
"""
from __future__ import annotations

import time

import numpy as np

import dataclasses

from repro.core import dram
from repro.core.reorder import mars_order
from repro.core.streams import PAGE_SHIFT
from repro.kernels.paged_attention import ops
from repro.kvcache import BlockPool, PoolConfig, ShardedBlockPool
from repro.kvcache.prefix import BlockTable, PrefixCache


def churned_pool(placement: str, *, num_blocks: int = 512, n_live: int = 16,
                 churn_events: int = 400, seed: int = 0):
    """Alloc/free sequences until the free list is realistically scattered;
    return (pool, live decode batch tables)."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(PoolConfig(num_blocks=num_blocks, placement=placement))
    live: list[BlockTable] = []

    def start_one():
        t = BlockTable()
        for _ in range(int(rng.integers(2, 9))):
            t.blocks.append(pool.alloc(1, hint_blocks=t.blocks)[0])
        t.num_tokens = len(t.blocks) * pool.cfg.block_size
        live.append(t)

    for _ in range(churn_events):
        if len(live) >= n_live or (live and rng.random() < 0.5):
            t = live.pop(int(rng.integers(len(live))))
            for b in t.blocks:
                pool.decref(b)
        else:
            start_one()
    while len(live) > n_live:
        t = live.pop(0)
        for b in t.blocks:
            pool.decref(b)
    while len(live) < n_live:       # top up to a full decode batch
        start_one()
    pool.check_invariants()
    return pool, live


def placement_comparison(*, n_live: int = 16, grant_beats: int = 2,
                         reorder_window=None, seed: int = 0) -> dict:
    """{placement: DramResult} for the same churn trace under both policies."""
    out = {}
    for placement in ("naive", "mars"):
        pool, tables = churned_pool(placement, n_live=n_live,
                                    churn_events=600, seed=seed)
        trace = ops.kv_read_trace(tables, grant_beats=grant_beats)
        if reorder_window is not None:
            perm = np.asarray(mars_order(
                np.asarray(trace, np.int64) >> PAGE_SHIFT,
                window=reorder_window))
            trace = np.asarray(trace)[perm]
        out[placement] = dram.simulate(trace)
    return out


def mean_uplift(n_live: int, seeds=(0, 1, 2), **kw) -> tuple[float, dict]:
    """Seed-averaged bandwidth uplift of MARS over naive placement."""
    ups, last = [], {}
    for seed in seeds:
        last = placement_comparison(n_live=n_live, seed=seed, **kw)
        ups.append(last["mars"].achieved_gbps
                   / last["naive"].achieved_gbps - 1)
    return float(np.mean(ups)), last


def row_hit_rate(res) -> float:
    """Row-buffer hit rate of a ``DramResult``: CAS that did not activate."""
    return 1.0 - res.n_act / max(res.n_requests, 1)


def decode_path_comparison(*, placement: str = "mars", n_live: int = 16,
                           grant_beats: int = 4, window_tokens: int = 0,
                           seed: int = 0, paths=("gather", "kernel"),
                           pool_tables=None) -> dict:
    """{path: DramResult} for one decode step over the same churned pool.

    ``gather``  the dense-view path: every lane's pages gathered in
                parallel, so the memory system sees the round-robin
                interleave of the per-lane streams.  A sliding window
                does not shrink this stream — the dense view gathers the
                whole table and masks afterwards.
    ``kernel``  the Pallas ``paged_attention`` path: the grid walks lanes
                one after another, each lane's pages in page-table order,
                page-contiguously — MARS placement finally reaches the
                attention kernel's address stream unflattened.  With
                ``window_tokens`` > 0 the kernel's window page gate also
                drops pages entirely outside the sliding window from the
                address stream.
    """
    if pool_tables is None:
        pool_tables = churned_pool(placement, n_live=n_live,
                                   churn_events=600, seed=seed)
    pool, tables = pool_tables
    out = {}
    if "gather" in paths:
        out["gather"] = dram.simulate(
            ops.kv_read_trace(tables, grant_beats=grant_beats))
    if "kernel" in paths:
        out["kernel"] = dram.simulate(ops.kv_read_trace_kernel(
            tables, window_tokens=window_tokens,
            block_size=pool.cfg.block_size))
    return out


@dataclasses.dataclass
class ShardedDramResult:
    """Aggregate of per-shard ``DramResult``s: every shard is its own
    memory device serving only its shard's lanes, in parallel.  Row-hit
    aggregates by summing CAS/ACT counts; bandwidth sums across devices
    (S devices deliver S memory systems' worth — the scaling half of the
    sharding story; the placement half is the row-hit rate)."""
    n_requests: int
    n_act: int
    achieved_gbps: float
    per_shard: list


def _aggregate_shards(results) -> ShardedDramResult:
    results = [r for r in results if r.n_requests > 0]
    return ShardedDramResult(
        n_requests=sum(r.n_requests for r in results),
        n_act=sum(r.n_act for r in results),
        achieved_gbps=float(sum(r.achieved_gbps for r in results)),
        per_shard=results)


def sharded_churned_pool(n_shards: int, *, num_blocks: int = 512,
                         n_live: int = 16, churn_events: int = 400,
                         seed: int = 0):
    """Churn a mesh-sharded pool with the same arrival/finish schedule as
    ``churned_pool`` (same rng draws), routing each arriving sequence to
    the least-loaded shard; returns (spool, [(shard, table), ...])."""
    rng = np.random.default_rng(seed)
    spool = ShardedBlockPool(
        PoolConfig(num_blocks=num_blocks, placement="mars"),
        n_shards=n_shards)
    live: list[tuple[int, BlockTable]] = []

    def start_one():
        s = min(range(n_shards),
                key=lambda i: (spool.shards[i].num_live, i))
        t = BlockTable()
        for _ in range(int(rng.integers(2, 9))):
            t.blocks.append(
                spool.shards[s].alloc(1, hint_blocks=t.blocks)[0])
        t.num_tokens = len(t.blocks) * spool.cfg.block_size
        live.append((s, t))

    for _ in range(churn_events):
        if len(live) >= n_live or (live and rng.random() < 0.5):
            s, t = live.pop(int(rng.integers(len(live))))
            for b in t.blocks:
                spool.shards[s].decref(b)
        else:
            start_one()
    while len(live) > n_live:
        s, t = live.pop(0)
        for b in t.blocks:
            spool.shards[s].decref(b)
    while len(live) < n_live:
        start_one()
    spool.check_invariants()
    return spool, live


def sharded_placement_comparison(*, n_shards: int = 4, n_live: int = 16,
                                 grant_beats: int = 2, churn_events: int = 600,
                                 seed: int = 0) -> dict:
    """Shard-routed MARS vs single-pool MARS vs naive, same churn trace.

    The single pool serves the whole decode batch from one memory device,
    so all ``n_live`` lanes interleave into one address stream.  The
    sharded pool routes sequences to ``n_shards`` devices; each device
    sees only its own lanes' interleave (shallower multi-stream merge)
    with MARS row-group packing *within* the shard — the leading shard
    coordinate of the placement key doing its job.  Expected ordering:
    shard-routed MARS row-hit >= single-pool MARS >= naive.
    """
    out = {}
    for placement in ("naive", "mars"):
        _, tables = churned_pool(placement, n_live=n_live,
                                 churn_events=churn_events, seed=seed)
        out[f"single/{placement}"] = dram.simulate(
            ops.kv_read_trace(tables, grant_beats=grant_beats))
    spool, live = sharded_churned_pool(n_shards, n_live=n_live,
                                       churn_events=churn_events, seed=seed)
    per_shard = []
    for s in range(n_shards):
        tables_s = [t for sh, t in live if sh == s]
        per_shard.append(dram.simulate(
            ops.kv_read_trace(tables_s, grant_beats=grant_beats)))
    out["sharded/mars"] = _aggregate_shards(per_shard)
    return out


def obs_overhead_comparison(*, n_requests: int = 12, max_new: int = 24,
                            max_lanes: int = 8, num_blocks: int = 256,
                            seed: int = 0) -> dict:
    """Median per-step wall time of the toy serve engine, bare vs fully
    instrumented (``obs.Observer`` attached: metrics registry adoption,
    trace spans, per-step row-locality feed, shard load sampling).

    The two engines run the identical request schedule and are stepped
    alternately, step for step, so ambient machine noise lands on both
    sides equally; the first few steps (prefill admission) are dropped as
    warm-up.  Returns median seconds per step for each side plus the
    ``efficiency`` ratio ``100 * bare / instrumented`` — 100 means free,
    95 means 5% overhead (the CI gate's floor).
    """
    from repro.obs import Observer
    from repro.serve.engine import ServeEngine
    from repro.serving.scheduler import MarsScheduler, Request

    def build(instrument: bool) -> ServeEngine:
        pool = BlockPool(PoolConfig(num_blocks=num_blocks, block_size=16,
                                    n_kv_heads=2, head_dim=32))
        eng = ServeEngine(pool, MarsScheduler(pool=pool),
                          max_lanes=max_lanes)
        if instrument:
            Observer().attach(eng)
        rng = np.random.default_rng(seed)
        pref = tuple(int(t) for t in rng.integers(1, 100, 32))
        for i in range(n_requests):
            tail = tuple(int(t) for t in rng.integers(1, 100, 3))
            assert eng.submit(Request(rid=i, prompt=pref + tail,
                                      prefix_len=16, max_new=max_new))
        return eng

    engines = {"bare": build(False), "instrumented": build(True)}
    times: dict = {k: [] for k in engines}
    while True:
        live = {k: e for k, e in engines.items()
                if len(e.finished) < n_requests}
        if not live:
            break
        for k, e in live.items():
            t0 = time.perf_counter()
            e.step()
            times[k].append(time.perf_counter() - t0)
    warmup = 3
    med = {k: float(np.median(v[warmup:])) for k, v in times.items()}
    med["efficiency"] = 100.0 * med["bare"] / med["instrumented"]
    return med


def zipf_requests(n_requests: int, n_prefixes: int, zipf_a: float,
                  prefix_tokens: int, seed: int = 0):
    """Skewed-prefix workload: request i reuses prefix p with
    P(p) ∝ 1/(rank+1)^a, plus a unique tail (never shareable)."""
    rng = np.random.default_rng(seed)
    prefixes = [tuple(int(t) for t in rng.integers(1, 10_000, prefix_tokens))
                for _ in range(n_prefixes)]
    probs = 1.0 / np.arange(1, n_prefixes + 1) ** zipf_a
    probs /= probs.sum()
    picks = rng.choice(n_prefixes, size=n_requests, p=probs)
    out = []
    for i, p in enumerate(picks):
        tail = (100_000 + 2 * i, 100_001 + 2 * i)
        out.append(prefixes[p] + tail)
    return out


def eviction_comparison(*, zipf_a: float = 1.1, n_prefixes: int = 48,
                        n_requests: int = 400, num_blocks: int = 48,
                        prefix_blocks: int = 2, block_size: int = 16,
                        seed: int = 0) -> dict:
    """{policy: prefix-cache hit rate} for the same Zipf request stream
    under FIFO and LRU eviction, with the pool sized well below the
    working set so eviction decides who stays resident."""
    assert num_blocks < n_prefixes * prefix_blocks, \
        "pool must be under memory pressure for eviction to matter"
    prompts = zipf_requests(n_requests, n_prefixes, zipf_a,
                            prefix_blocks * block_size, seed=seed)
    out = {}
    for policy in ("fifo", "lru"):
        pool = BlockPool(PoolConfig(num_blocks=num_blocks,
                                    block_size=block_size,
                                    eviction=policy))
        cache = PrefixCache(block_size)
        cache.attach(pool)
        hits = possible = 0
        for prompt in prompts:
            prompt = list(prompt)
            bids, n = cache.match(prompt, pool)
            table = BlockTable(list(bids), n)
            table.extend(pool, prompt[n:], seq_tokens=prompt, cache=cache)
            hits += n
            possible += prefix_blocks * block_size
            cache.release(table, pool)
        pool.check_invariants()
        out[policy] = hits / possible
    return out


def tiered_promotion_comparison(*, n_prefixes: int = 24,
                                num_blocks: int = 64, block_size: int = 16,
                                seed: int = 0) -> dict:
    """{mode: DramResult} for the same batched promotion copy-in, written
    MARS-reordered vs in arrival order.

    Setup (identical under both modes, same rng): register
    ``n_prefixes`` single-block prefixes, demote them all under pool
    pressure, fragment the free list with a shuffled alloc/free pass so
    promotion destinations scatter across row groups, then ``match`` all
    prompts in one lookahead batch and ``flush_promotions``.  The flush's
    destination order is replayed through ``core/dram.simulate`` as a
    write stream — the only difference between the two runs is the copy
    order (``TierManager(reorder=...)``), so the row-hit gap is the
    reorder's contribution.
    """
    from repro.kvcache.tiers import TierManager
    out = {}
    for mode, reorder in (("mars", True), ("naive", False)):
        rng = np.random.default_rng(seed)
        pool = BlockPool(PoolConfig(num_blocks=num_blocks,
                                    block_size=block_size,
                                    placement="naive"))
        cache = PrefixCache(block_size)
        cache.attach(pool)
        tiers = TierManager(pool, cache, reorder=reorder)
        prompts = []
        for i in range(n_prefixes):
            prompt = [int(t) for t in rng.integers(1, 10_000, block_size)]
            prompt.append(i + 1)           # tail token: prefix < prompt
            t = BlockTable()
            t.extend(pool, prompt, seq_tokens=prompt, cache=cache)
            cache.release(t, pool)
            prompts.append(prompt)
        grab = pool.alloc(pool.num_free + pool.num_cached)  # demote all
        assert tiers.stats.demotes == n_prefixes
        for b in grab:
            pool.decref(b)
        # fragment: re-grab everything, free a shuffled half — the free
        # list (= destination allocation order) now scatters across row
        # groups exactly like a churned serving pool
        grab = pool.alloc(num_blocks)
        freed = rng.permutation(num_blocks)[:num_blocks // 2]
        for i in freed:
            pool.decref(grab[i])
        for p in prompts:                  # one lookahead batch
            tiers.match(p)
        assert tiers.pending == n_prefixes
        dsts = tiers.flush_promotions()
        trace = TierManager.write_trace(dsts)
        out[mode] = dram.simulate(trace, is_write=np.ones(len(trace), bool))
    return out


def tiered_eviction_comparison(*, n_deep: int = 6, deep_blocks: int = 4,
                               n_shallow: int = 36, shallow_window: int = 12,
                               rounds: int = 24, num_blocks: int = 36,
                               block_size: int = 16, tier_blocks: int = 8,
                               seed: int = 0) -> dict:
    """Cost-aware vs LRU eviction over the same tiered prefix stream.

    The stream mixes ``n_deep`` deep prefixes (``deep_blocks`` chained
    blocks — a causal recompute reruns the whole chain) recurring every
    round with a sliding window of shallow single-block prefixes, over a
    pool well below the working set and a spill tier too small to hold
    everyone (so some evictions genuinely drop).  Cost mode ranks victims
    by re-acquisition cost and so protects the deep chains; LRU evicts by
    recency and keeps the fresher shallow blocks instead.  Returns per
    policy: ``reuse`` (matched / matchable prefix tokens, promoted blocks
    included — higher is better) and ``recompute_tokens`` (the prefill
    bill for what was lost).
    """
    from repro.kvcache.tiers import TierManager, TierSpec
    rng = np.random.default_rng(seed)
    deep = [tuple(int(t) for t in rng.integers(1, 10_000,
                                               deep_blocks * block_size))
            for _ in range(n_deep)]
    shallow = [tuple(int(t) for t in rng.integers(1, 10_000, block_size))
               for _ in range(n_shallow)]
    schedule = []
    for r in range(rounds):
        for p in deep:
            schedule.append(p + (9_000_000 + r,))      # unique tail
        for j in range(shallow_window):
            p = shallow[(r + j) % n_shallow]
            schedule.append(p + (9_500_000 + r,))
    out = {}
    for policy in ("cost", "lru"):
        pool = BlockPool(PoolConfig(num_blocks=num_blocks,
                                    block_size=block_size,
                                    eviction=policy))
        cache = PrefixCache(block_size)
        cache.attach(pool)
        tiers = TierManager(pool, cache,
                            specs=(TierSpec("host", tier_blocks,
                                            latency_us=5.0, gbps=20.0),))
        hits = possible = 0
        for prompt in schedule:
            prompt = list(prompt)
            bids, n = tiers.match(prompt)
            table = BlockTable(list(bids), n)
            table.extend(pool, prompt[n:], seq_tokens=prompt, cache=cache)
            tiers.flush_promotions()
            hits += n
            possible += len(prompt) - 1    # all full blocks are matchable
            cache.release(table, pool)
        pool.check_invariants()
        tiers.check()
        out[policy] = {"reuse": hits / possible,
                       "recompute_tokens": possible - hits,
                       "promoted_tokens": tiers.stats.promoted_tokens,
                       "drops": tiers.stats.drops}
    return out


def alloc_soak(kind: str = "single", *, num_blocks: int = 256,
               events: int = 2000, n_live_cap: int = 48,
               n_shards: int = 2, seed: int = 0) -> dict:
    """Multi-round Zipf-sized alloc/free soak over one pool (or a
    mesh-sharded pool, least-loaded routing) — the allocator's long-run
    behaviour under realistic churn.

    Sequence sizes are Zipf-distributed (many short, a heavy tail of
    long), frees are random, and the pool runs near capacity, so the free
    list scatters the way a serving pool's does.  Reports:

      ``locality``      mean over live tables of the fraction of blocks
                        in the table's modal row group (MARS placement's
                        long-run survival under fragmentation pressure)
      ``free_run``      mean contiguous free-block run length (classic
                        external-fragmentation measure; higher = less
                        fragmented)
      ``alloc_us``      mean wall microseconds per alloc() call
    """
    rng = np.random.default_rng(seed)
    if kind == "single":
        pools = [BlockPool(PoolConfig(num_blocks=num_blocks,
                                      placement="mars"))]
        route = lambda: 0
    else:
        spool = ShardedBlockPool(
            PoolConfig(num_blocks=num_blocks, placement="mars"),
            n_shards=n_shards)
        pools = spool.shards
        route = lambda: min(range(n_shards),
                            key=lambda i: (pools[i].num_live, i))
    live: list[tuple[int, BlockTable]] = []
    alloc_s = 0.0
    n_allocs = 0

    def start_one():
        nonlocal alloc_s, n_allocs
        z = int(min(8, rng.zipf(1.5)))
        s = route()
        if pools[s].num_free + pools[s].num_cached < z:
            return False
        t = BlockTable()
        for _ in range(z):
            t0 = time.perf_counter()
            t.blocks.append(pools[s].alloc(1, hint_blocks=t.blocks)[0])
            alloc_s += time.perf_counter() - t0
            n_allocs += 1
        t.num_tokens = len(t.blocks) * pools[s].cfg.block_size
        live.append((s, t))
        return True

    for _ in range(events):
        if live and (len(live) >= n_live_cap or rng.random() < 0.45):
            s, t = live.pop(int(rng.integers(len(live))))
            for b in t.blocks:
                pools[s].decref(b)
        else:
            start_one()
    for p in pools:
        p.check_invariants()
    # live-table row-group locality: modal-group fraction per table
    bpg = pools[0].cfg.blocks_per_group
    fracs = []
    for _, t in live:
        groups = [b // bpg for b in t.blocks]
        fracs.append(max(groups.count(g) for g in set(groups))
                     / len(groups))
    # free-list fragmentation: mean contiguous free run length
    runs = []
    for p in pools:
        run = 0
        for bid in range(p.cfg.num_blocks):
            if not p.used[bid]:
                run += 1
            elif run:
                runs.append(run)
                run = 0
        if run:
            runs.append(run)
    return {"locality": float(np.mean(fracs)) if fracs else 0.0,
            "free_run": float(np.mean(runs)) if runs else 0.0,
            "alloc_us": 1e6 * alloc_s / max(n_allocs, 1),
            "n_allocs": n_allocs}


_PIPELINE_MODEL = {}


def _pipeline_model(seed: int = 0):
    """Cached smoke-model (cfg, params) for the decode-pipeline bench —
    params init is the expensive part and every scenario shares it."""
    if seed not in _PIPELINE_MODEL:
        import jax
        from repro import configs
        from repro.models import lm as lm_mod
        cfg = configs.get_smoke("qwen1_5_0_5b")
        _PIPELINE_MODEL[seed] = (
            cfg, lm_mod.init(cfg, jax.random.key(seed)).params)
    return _PIPELINE_MODEL[seed]


def decode_pipeline_comparison(scenario: str = "single", *,
                               n_lanes: int = 4, warm_steps: int = 4,
                               timed_steps: int = 16, seed: int = 0) -> dict:
    """Wall-clock A/B: split-phase decode pipeline vs the synchronous
    ``decode()`` wrapper, twin backends serving the same ragged lanes.

    ``scenario``: "single" (one pool), "shards2" (mesh-sharded, 2
    shards, issue-then-gather dispatch), "tiered" (spill tiers behind
    the pool).  The decode path is compiled, never interpreted: the
    Pallas kernel with ``kernel_interpret=False`` on TPU/GPU, the jitted
    XLA gather decode on CPU (where Pallas supports interpret mode
    only).  Prompt lengths and step counts stay inside one pow2 operand
    bucket so neither loop recompiles mid-flight.

    Returns ``{"seq_us", "pipe_us", "ratio"}`` — best per-step wall
    times and ``100 * t_seq / t_pipe`` (>= 100 means the pipeline at
    least matches the sequential path's step throughput).  The twin
    backends advance in lock-step within ONE loop (both paths sampled
    under the same machine noise each iteration) and the estimator is
    the per-step MINIMUM: scheduler/GC noise only ever inflates a wall
    clock, so the min converges on the true step cost where totals and
    even medians of few-ms steps drown in shared-CI jitter.  Greedy
    tokens from the two paths are asserted bit-identical first: same
    decode mode, same operand values, the pipeline only reorders work.
    """
    import jax
    from repro.kvcache.backend import make_backend

    mode = "kernel" if jax.default_backend() in ("tpu", "gpu") else "gather"
    cfg, params = _pipeline_model(seed)
    kw = dict(num_blocks=64, block_size=16, decode_mode=mode,
              kernel_interpret=False)
    if scenario == "shards2":
        kw["shards"] = 2
    elif scenario == "tiered":
        kw["tiered"] = True
    else:
        assert scenario == "single", scenario
    rng = np.random.default_rng(seed)
    # 33..36-token prompts sit just past a block boundary: 3 pages pads
    # to the 4-page pow2 bucket (block_size 16), which covers
    # num_tokens + 1 <= 64 — up to 27 decode steps with zero mid-loop
    # recompiles for either path
    assert 36 + warm_steps + timed_steps + 1 <= 64
    prompts = [rng.integers(1, cfg.vocab, 33 + i).tolist()
               for i in range(n_lanes)]

    def make() -> dict:
        backend = make_backend(cfg, "paged", **kw)
        return {"b": backend, "last": [p[-1] for p in prompts],
                "sids": [backend.new_seq(params, p)[0] for p in prompts],
                "toks": [], "dts": []}

    def advance(st: dict, pipelined: bool, timed: bool) -> None:
        backend = st["b"]
        t0 = time.perf_counter()
        if pipelined:
            backend.flush()               # commit step i-1's write-back
            step = backend.dispatch_decode(params, st["last"],
                                           sids=st["sids"])
            logits = backend.sync(step)
        else:
            logits = backend.decode(params, st["sids"], st["last"])
        st["last"] = [int(np.argmax(lg)) for lg in np.asarray(logits)]
        dt = time.perf_counter() - t0
        if timed:
            st["dts"].append(dt)
        st["toks"].append(list(st["last"]))

    seq, pipe = make(), make()
    for i in range(warm_steps + timed_steps):
        # alternate who goes first so a sustained noise burst lands on
        # both paths' samples, not systematically on one
        first, second = (seq, pipe) if i % 2 == 0 else (pipe, seq)
        advance(first, first is pipe, i >= warm_steps)
        advance(second, second is pipe, i >= warm_steps)
    pipe["b"].flush()
    assert seq["toks"] == pipe["toks"], \
        f"pipelined decode diverged from sequential ({scenario})"
    t_seq, t_pipe = (float(np.min(st["dts"])) for st in (seq, pipe))
    for st in (seq, pipe):
        st["b"].release()
    return {"seq_us": 1e6 * t_seq,
            "pipe_us": 1e6 * t_pipe,
            "ratio": 100.0 * t_seq / max(t_pipe, 1e-12)}


def mixed_traffic_comparison(scenario: str = "single", *,
                             max_lanes: int = 4, seed: int = 0) -> dict:
    """Class-aware SMS scheduling + decode preemption vs the class-blind
    scheduler, same overloaded mixed-class stream, fake step clock.

    The stream mixes three traffic classes the way a serving mix does:
    ``batch`` summarize jobs (long decodes) and long-context ``stream``
    requests arrive first and hog the deliberately undersized pool;
    ``interactive`` chat turns (short decodes, Zipf-popular prefixes)
    keep arriving while the pool is full.  Both engines serve identical
    requests through a real smoke-LM ``PagedBackend`` (single pool or 2
    mesh shards); bounced offers retry every step (client retry), so
    every request eventually completes and the only difference is WHEN.

    Returns per-class p99 turnaround (finish - arrival, in steps) for
    both schedulers plus the two gated ratios:

      ``interactive_gain``   100 * blind_p99 / aware_p99 for the
                             interactive class (> 100: class-aware
                             scheduling + preemption cut chat tail
                             latency under overload)
      ``batch_tput_ratio``   100 * aware / blind batch-class token
                             throughput (tokens per step) — the price
                             paid; the gate holds it within 10%

    Tokens are greedy over fixed params, the clock is the step counter,
    and the schedule is seeded, so both ratios are deterministic."""
    import jax  # noqa: F401  (backend selection side effects)
    from repro.kvcache.backend import make_backend
    from repro.serve.engine import PagedLM, ServeEngine
    from repro.serving.scheduler import MarsScheduler, Request, \
        default_classes

    mode = "kernel" if __import__("jax").default_backend() \
        in ("tpu", "gpu") else "gather"
    cfg, params = _pipeline_model(seed)
    rng = np.random.default_rng(seed)
    prefixes = [tuple(int(t) for t in rng.integers(1, cfg.vocab, 16))
                for _ in range(4)]
    long_prefixes = [tuple(int(t) for t in rng.integers(1, cfg.vocab, 48))
                     for _ in range(2)]
    probs = 1.0 / np.arange(1, 5) ** 1.1
    probs /= probs.sum()
    # request spec: (class, prompt, arrival, max_new) — instantiated
    # fresh per engine (the scheduler stamps routing state on Request)
    spec = []
    for i in range(4):          # batch summarize: long decode, early
        spec.append(("batch", prefixes[i % 2], float(i), 16))
    for i in range(3):          # long-context stream: big prompt
        spec.append(("stream", long_prefixes[i % 2], 2.0 + 2 * i, 8))
    for i in range(12):         # interactive chat: Zipf prefix, steady
        p = prefixes[int(rng.choice(4, p=probs))]
        spec.append(("interactive", p, 4.0 + 2 * i, 4))

    def serve(classes) -> dict:
        kw = dict(num_blocks=16, block_size=16, decode_mode=mode,
                  kernel_interpret=False)
        if scenario == "shards2":
            # 12 blocks/shard: a sequence never spans shards, so per-shard
            # pressure must stay comparable to the single-pool run for
            # overload (and preemption) to actually trigger
            kw.update(shards=2, num_blocks=24)
        else:
            assert scenario == "single", scenario
        backend = make_backend(cfg, "paged", **kw)
        pool = backend.pool
        sched = MarsScheduler(pool=pool, classes=classes)
        eng = ServeEngine(pool, sched, PagedLM(params, cfg, backend),
                          max_lanes=max_lanes)
        reqs = [Request(rid=i, prompt=pr + (1 + i, 2 + i), arrival=arr,
                        max_new=new, traffic_class=cname)
                for i, (cname, pr, arr, new) in enumerate(spec)]
        queue = sorted(reqs, key=lambda r: (r.arrival, r.rid))
        waiting: list = []
        finished_at: dict = {}
        t0 = time.perf_counter()
        step = 0
        while len(finished_at) < len(reqs):
            now = float(step)
            while queue and queue[0].arrival <= now:
                waiting.append(queue.pop(0))
            waiting = [r for r in waiting if not eng.submit(r)]
            eng.step(now=now)
            for rid in eng.finished:
                finished_at.setdefault(rid, now)
            step += 1
            assert step < 5000, "mixed-traffic serve did not drain"
        wall_us = (time.perf_counter() - t0) * 1e6
        backend.release()
        lat: dict = {}
        toks: dict = {}
        for r in reqs:
            lat.setdefault(r.traffic_class, []).append(
                finished_at[r.rid] - r.arrival)
            toks[r.traffic_class] = toks.get(r.traffic_class, 0) + r.max_new
        return {"p99": {c: float(np.percentile(v, 99))
                        for c, v in lat.items()},
                "batch_tput": toks["batch"] / step,
                "preempts": sum(cs.preempt
                                for cs in sched.class_stats.values()),
                "steps": step, "wall_us": wall_us}

    aware = serve(default_classes(3))
    blind = serve(None)
    return {"aware": aware, "blind": blind,
            "interactive_gain": 100.0 * blind["p99"]["interactive"]
            / max(aware["p99"]["interactive"], 1e-9),
            "batch_tput_ratio": 100.0 * aware["batch_tput"]
            / max(blind["batch_tput"], 1e-9),
            "wall_us": aware["wall_us"] + blind["wall_us"]}


def run(emit, smoke: bool = False) -> None:
    lanes = (8,) if smoke else (8, 32)
    seeds = (0,) if smoke else (0, 1, 2)
    for n_live in lanes:     # decode lanes: more lanes = deeper interleave
        t0 = time.perf_counter()
        uplift, res = mean_uplift(n_live, seeds=seeds)
        us = (time.perf_counter() - t0) * 1e6
        for placement, r in res.items():
            emit(f"kvcache/placement/{placement}/lanes{n_live}", us / 6,
                 f"{r.achieved_gbps:.2f}GB/s")
        emit(f"kvcache/placement/uplift/lanes{n_live}", us / 6,
             f"{100 * uplift:.2f}%")
    if not smoke:
        # with the MC-side MARS reorder buffer in front (window = RequestQ):
        # reordering recovers part of what naive placement lost, shrinking
        # the gap — the co-design point: placement helps where reordering
        # cannot
        t0 = time.perf_counter()
        res = placement_comparison(n_live=32, reorder_window=512)
        us = (time.perf_counter() - t0) * 1e6
        uplift = res["mars"].achieved_gbps / res["naive"].achieved_gbps - 1
        emit("kvcache/placement+reorder/uplift", us / 2,
             f"{100 * uplift:.2f}%")
    # decode-path bandwidth: gather-path interleave vs the kernel's
    # sequence-major page walk, same MARS-placed pool — the first
    # end-to-end measurement of placement reaching the attention kernel
    mars_pt = None
    for placement in ("naive", "mars"):
        t0 = time.perf_counter()
        pt = churned_pool(placement, n_live=16, churn_events=600, seed=0)
        res = decode_path_comparison(placement=placement, pool_tables=pt)
        us = (time.perf_counter() - t0) * 1e6
        if placement == "mars":
            mars_pt = pt
        for path, r in res.items():
            emit(f"kvcache/decode/{path}/{placement}", us / 2,
                 f"{r.achieved_gbps:.2f}GB/s")
            emit(f"kvcache/decode/{path}/{placement}/rowhit", us / 2,
                 f"{100 * row_hit_rate(r):.2f}%")
    # sliding-window decode: the kernel's window page gate drops
    # out-of-window pages from its walk; the gather path still fetches
    # the full table, so its window trace is identical to the
    # kvcache/decode/gather/mars rows above — only the kernel re-traces,
    # over the same churned pool
    t0 = time.perf_counter()
    res = decode_path_comparison(window_tokens=64, paths=("kernel",),
                                 pool_tables=mars_pt)
    us = (time.perf_counter() - t0) * 1e6
    r = res["kernel"]
    emit("kvcache/decode/kernel/mars/window64", us,
         f"{r.achieved_gbps:.2f}GB/s")
    emit("kvcache/decode/kernel/mars/window64/rowhit", us,
         f"{100 * row_hit_rate(r):.2f}%")
    # mesh-sharded placement: route streams to devices first, row-group-
    # -pack within each — per-shard traces replayed through the DRAM
    # model (each shard = its own memory device); shard-routed MARS
    # row-hit must bound single-pool MARS which bounds naive
    for i, n_shards in enumerate((2,) if smoke else (2, 4)):
        t0 = time.perf_counter()
        res = sharded_placement_comparison(n_shards=n_shards, n_live=16)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"kvcache/placement/sharded/rowhit/shards{n_shards}", us / 3,
             f"{100 * row_hit_rate(res['sharded/mars']):.2f}%")
        if i == 0:      # single-pool baselines are shard-count-independent
            emit("kvcache/placement/sharded/rowhit/single-mars", us / 3,
                 f"{100 * row_hit_rate(res['single/mars']):.2f}%")
            emit("kvcache/placement/sharded/rowhit/single-naive", us / 3,
                 f"{100 * row_hit_rate(res['single/naive']):.2f}%")
        emit(f"kvcache/placement/sharded/gbps/shards{n_shards}", us / 3,
             f"{res['sharded/mars'].achieved_gbps:.2f}GB/s")
    # observability overhead: identical toy-engine schedules stepped
    # alternately, bare vs Observer-attached — efficiency is the ratio of
    # median per-step wall times (100 = free; the CI baseline gate fails
    # below 95, i.e. >5% metrics overhead)
    t0 = time.perf_counter()
    ov = obs_overhead_comparison(max_new=12 if smoke else 24)
    us = (time.perf_counter() - t0) * 1e6
    emit("kvcache/decode/obs/efficiency", us,
         f"{ov['efficiency']:.2f}%")
    # wall-clock detail row — named outside the gated namespace on purpose
    emit("kvcache/obs/decode-step", ov["instrumented"] * 1e6,
         f"{1e6 * (ov['instrumented'] - ov['bare']):.1f}us-overhead")
    # FIFO vs LRU under skewed prefix popularity
    n_requests = 150 if smoke else 400
    for zipf_a in (0.8, 1.3):
        t0 = time.perf_counter()
        rates = eviction_comparison(zipf_a=zipf_a, n_requests=n_requests)
        us = (time.perf_counter() - t0) * 1e6
        for policy, rate in rates.items():
            emit(f"kvcache/evict/{policy}/zipf{zipf_a}", us / 2,
                 f"{100 * rate:.1f}%hit")
    # tier boundary: MARS-reordered batched promotion vs arrival order —
    # the same scattered destination set written in two orders through
    # the DRAM model; the reordered stream must hold the row-hit bound
    t0 = time.perf_counter()
    res = tiered_promotion_comparison()
    us = (time.perf_counter() - t0) * 1e6
    for mode, r in res.items():
        emit(f"kvcache/tier/promote/{mode}/rowhit", us / 2,
             f"{100 * row_hit_rate(r):.2f}%")
    # cost-aware vs LRU eviction over the tiered prefix stream: cost mode
    # protects expensive-to-recompute deep chains, so reuse is higher and
    # the recompute bill lower
    t0 = time.perf_counter()
    tres = tiered_eviction_comparison()
    us = (time.perf_counter() - t0) * 1e6
    for policy, d in tres.items():
        emit(f"kvcache/tier/evict/{policy}/reuse", us / 2,
             f"{100 * d['reuse']:.2f}%")
        # recompute bill: detail row, outside the gated namespace
        # (lower is better — the gate only understands higher-is-better)
        emit(f"kvcache/tierdetail/evict/{policy}", us / 2,
             f"{d['recompute_tokens']}tok-recomputed")
    # allocator soak: Zipf-sized churn fragmentation + alloc latency over
    # the plain and mesh-sharded pools; locality/free-run are gated,
    # wall-clock lives in the us column
    events = 800 if smoke else 2000
    for kind in ("single", "sharded2"):
        soak = alloc_soak("single" if kind == "single" else "sharded",
                          events=events)
        emit(f"kvcache/alloc/{kind}/locality", soak["alloc_us"],
             f"{100 * soak['locality']:.2f}%")
        emit(f"kvcache/alloc/{kind}/freerun", soak["alloc_us"],
             f"{soak['free_run']:.2f}blocks")
    # split-phase decode pipeline vs the synchronous decode() wrapper:
    # real-LM twin backends, compiled (non-interpret) decode, bit-
    # identical tokens asserted inside.  The ratio row is gated against
    # the pinned 100.0 baseline with a wide wall-clock-jitter tolerance:
    # the pipeline must at least roughly hold the sequential path's step
    # throughput in every configuration
    for scen in ("single", "shards2", "tiered"):
        r = decode_pipeline_comparison(scen)
        emit(f"kvcache/decode/pipeline/{scen}", r["pipe_us"],
             f"{r['ratio']:.2f}%")
    # SMS traffic classes under overload: class-aware staged scheduling +
    # decode preemption vs the class-blind scheduler, identical mixed
    # stream on a fake step clock.  Both gated rows are pinned ratios:
    # interactive-p99 >= ~100 (chat tail latency must improve) and
    # batch-tput within 10% of class-blind (the throughput price cap)
    for scen in ("single", "shards2"):
        r = mixed_traffic_comparison(scen)
        emit(f"kvcache/sched/class/{scen}/interactive-p99",
             r["wall_us"] / 2, f"{r['interactive_gain']:.2f}%")
        emit(f"kvcache/sched/class/{scen}/batch-tput",
             r["wall_us"] / 2, f"{r['batch_tput_ratio']:.2f}%")
        # absolute tails + preempt count: detail rows, outside the gate
        emit(f"kvcache/scheddetail/{scen}/aware-p99", r["wall_us"] / 2,
             f"{r['aware']['p99']['interactive']:.1f}steps")
        emit(f"kvcache/scheddetail/{scen}/blind-p99", r["wall_us"] / 2,
             f"{r['blind']['p99']['interactive']:.1f}steps")
        emit(f"kvcache/scheddetail/{scen}/preempts", r["wall_us"] / 2,
             f"{r['aware']['preempts']}preempts")
