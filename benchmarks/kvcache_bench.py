"""KV-cache placement benchmark: MARS-aware vs naive block placement.

Serving workload through the paper's DRAM model: a pool is churned by
arriving/finishing sequences until fragmented, then a decode batch's full
KV gather (``kernels.paged_attention.ops.kv_read_trace`` — per-lane block
reads interleaved by the parallel gather) is served by
``core.dram.simulate``.  MARS placement packs each sequence's blocks into
few DRAM row neighborhoods, so the interleaved lanes land in distinct
banks instead of thrashing rows; the naive LIFO free list scatters blocks
after churn.

Emits ``kvcache/<placement>/...`` rows plus the headline uplift, and the
same traces after a bounded-window ``reorder.mars_order`` pass (the MC-side
MARS reorder buffer) to show placement and reordering compose.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import dram
from repro.core.reorder import mars_order
from repro.core.streams import PAGE_SHIFT
from repro.kernels.paged_attention import ops
from repro.kvcache import BlockPool, PoolConfig
from repro.kvcache.prefix import BlockTable


def churned_pool(placement: str, *, num_blocks: int = 512, n_live: int = 16,
                 churn_events: int = 400, seed: int = 0):
    """Alloc/free sequences until the free list is realistically scattered;
    return (pool, live decode batch tables)."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(PoolConfig(num_blocks=num_blocks, placement=placement))
    live: list[BlockTable] = []

    def start_one():
        t = BlockTable()
        for _ in range(int(rng.integers(2, 9))):
            t.blocks.append(pool.alloc(1, hint_blocks=t.blocks)[0])
        t.num_tokens = len(t.blocks) * pool.cfg.block_size
        live.append(t)

    for _ in range(churn_events):
        if len(live) >= n_live or (live and rng.random() < 0.5):
            t = live.pop(int(rng.integers(len(live))))
            for b in t.blocks:
                pool.decref(b)
        else:
            start_one()
    while len(live) > n_live:
        t = live.pop(0)
        for b in t.blocks:
            pool.decref(b)
    while len(live) < n_live:       # top up to a full decode batch
        start_one()
    pool.check_invariants()
    return pool, live


def placement_comparison(*, n_live: int = 16, grant_beats: int = 2,
                         reorder_window=None, seed: int = 0) -> dict:
    """{placement: DramResult} for the same churn trace under both policies."""
    out = {}
    for placement in ("naive", "mars"):
        pool, tables = churned_pool(placement, n_live=n_live,
                                    churn_events=600, seed=seed)
        trace = ops.kv_read_trace(tables, grant_beats=grant_beats)
        if reorder_window is not None:
            perm = np.asarray(mars_order(
                np.asarray(trace, np.int64) >> PAGE_SHIFT,
                window=reorder_window))
            trace = np.asarray(trace)[perm]
        out[placement] = dram.simulate(trace)
    return out


def mean_uplift(n_live: int, seeds=(0, 1, 2), **kw) -> tuple[float, dict]:
    """Seed-averaged bandwidth uplift of MARS over naive placement."""
    ups, last = [], {}
    for seed in seeds:
        last = placement_comparison(n_live=n_live, seed=seed, **kw)
        ups.append(last["mars"].achieved_gbps
                   / last["naive"].achieved_gbps - 1)
    return float(np.mean(ups)), last


def run(emit) -> None:
    for n_live in (8, 32):   # decode lanes: more lanes = deeper interleave
        t0 = time.perf_counter()
        uplift, res = mean_uplift(n_live)
        us = (time.perf_counter() - t0) * 1e6
        for placement, r in res.items():
            emit(f"kvcache/placement/{placement}/lanes{n_live}", us / 6,
                 f"{r.achieved_gbps:.2f}GB/s")
        emit(f"kvcache/placement/uplift/lanes{n_live}", us / 6,
             f"{100 * uplift:.2f}%")
    # with the MC-side MARS reorder buffer in front (window = RequestQ):
    # reordering recovers part of what naive placement lost, shrinking the
    # gap — the co-design point: placement helps where reordering cannot
    t0 = time.perf_counter()
    res = placement_comparison(n_live=32, reorder_window=512)
    us = (time.perf_counter() - t0) * 1e6
    uplift = res["mars"].achieved_gbps / res["naive"].achieved_gbps - 1
    emit("kvcache/placement+reorder/uplift", us / 2, f"{100 * uplift:.2f}%")
