"""Quickstart: the MARS paper result in three calls.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import dram, experiment, mars, streams

# 1. Build a paper workload (WL1: 64 cores, single texture stream) and see
#    how arbitration destroyed per-stream locality.
wl = streams.make_workload("WL1", reqs_per_core=128)
print("locality @512-window  source: %.1f   GPU boundary: %.1f" % (
    streams.locality(streams.single_cache_stream(reqs_per_core=4096), 512),
    streams.locality(wl.addr, 512)))

# 2. Run the request stream through the DRAM model, with and without MARS.
base = dram.simulate(wl.addr, is_write=wl.is_write)
perm, stats = mars.mars_reorder(wl.addr, np.asarray(wl.source) // 8,
                                src=np.asarray(wl.source))
perm = np.asarray(perm)
with_ = dram.simulate(np.asarray(wl.addr)[perm],
                      is_write=np.asarray(wl.is_write)[perm])

# 3. The paper's two headline metrics.
print("bandwidth : %.1f -> %.1f GB/s  (+%.0f%%)" % (
    base.achieved_gbps, with_.achieved_gbps,
    100 * (with_.achieved_gbps / base.achieved_gbps - 1)))
print("CAS/ACT   : %.2f -> %.2f       (+%.0f%%)" % (
    base.cas_per_act, with_.cas_per_act,
    100 * (with_.cas_per_act / base.cas_per_act - 1)))
print("MARS engine: %d boundary-port stalls, %d cycles"
      % (stats["stall_events"], stats["total_cycles"]))
