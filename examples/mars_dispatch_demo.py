"""The paper's technique on TPU: MARS-sorted MoE dispatch.

    PYTHONPATH=src python examples/mars_dispatch_demo.py

Routes a token batch to 16 experts, then runs the expert FFN three ways:
  a. dense per-token oracle (what the math says),
  b. locality-oblivious einsum dispatch (the "no MARS" baseline),
  c. MARS-sorted grouped matmul (ragged_dot and the Pallas kernel).
All must agree; the point is the ACCESS PATTERN, quantified by the
page-run statistics printed at the end (the CAS/ACT analogue).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_dispatch import ops
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig

cfg = ModelConfig(name="demo", family="moe", n_layers=1, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=96, vocab=128,
                  n_experts=16, top_k=2, d_expert=96,
                  param_dtype="float32", compute_dtype="float32")
params = moe_mod.moe_init(jax.random.key(0), cfg).params
T = 256
x = jax.random.normal(jax.random.key(1), (T, cfg.d_model))
idx, gates, _ = moe_mod.router_topk(params, x, cfg)

y_mars = ops.mars_moe_ffn(x, idx, gates, params["w_in"], params["w_gate"],
                          params["w_out"], n_experts=16)
y_pallas = ops.mars_moe_ffn(x, idx, gates, params["w_in"],
                            params["w_gate"], params["w_out"],
                            n_experts=16, use_pallas=True, bm=32)
y_base, _ = moe_mod.moe_apply_einsum(params, x, cfg)
np.testing.assert_allclose(np.asarray(y_mars), np.asarray(y_pallas),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(np.asarray(y_mars), np.asarray(y_base),
                           rtol=2e-4, atol=2e-4)
print("[example] three dispatch paths agree")

# access-pattern statistics: expert-id run lengths before/after MARS sort
flat = np.asarray(idx).reshape(-1)
runs = lambda a: np.diff(np.flatnonzero(np.concatenate(
    [[True], a[1:] != a[:-1], [True]])))
print(f"[example] expert-visit run length: interleaved {runs(flat).mean():.2f}"
      f" -> MARS-sorted {runs(np.sort(flat)).mean():.2f} "
      f"(x{runs(np.sort(flat)).mean()/runs(flat).mean():.1f} page locality)")
