"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # full run
    PYTHONPATH=src python examples/train_lm.py --quick    # CI-sized

Uses the full framework stack: config registry, MARS-gather embedding,
pjit-able train step, AdamW, checkpoint/restart supervision.  The config
is a 12-layer/768-wide dense transformer (~100M params); --quick shrinks
it for fast CPU verification.
"""
import argparse
import dataclasses
import sys

from repro.configs import qwen1_5_0_5b
from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    # ~100M params: 12L x 768d (same family as qwen: GQA + bias + SwiGLU)
    if args.quick:
        argv = ["--arch", "qwen1_5_0_5b", "--smoke", "--steps",
                str(args.steps or 30), "--batch", "4", "--seq", "64",
                "--ckpt-interval", "10", "--workdir", "/tmp/repro_quick"]
        losses = train.main(argv)
    else:
        cfg = dataclasses.replace(
            qwen1_5_0_5b.CONFIG, name="lm-100m", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=12, d_ff=2048, vocab=32768)
        # register ad hoc: drive the loop directly
        import repro.configs as configs
        configs.ALIASES["lm-100m"] = "lm_100m"
        sys.modules["repro.configs.lm_100m"] = type(sys)("lm_100m")
        sys.modules["repro.configs.lm_100m"].CONFIG = cfg
        sys.modules["repro.configs.lm_100m"].smoke = lambda: cfg
        configs.ARCHS = tuple(list(configs.ARCHS) + ["lm_100m"])
        print(f"[example] {cfg.name}: {cfg.n_params()/1e6:.0f}M params")
        losses = train.main(["--arch", "lm_100m", "--steps",
                             str(args.steps or 300), "--batch", "8",
                             "--seq", "512", "--ckpt-interval", "50",
                             "--workdir", "/tmp/repro_100m"])
    assert losses[-1] < losses[0], "loss did not decrease"
    print("[example] OK — loss decreased",
          f"{losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
