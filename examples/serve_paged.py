"""Batched serving with the MARS request scheduler + paged KV attention.

    PYTHONPATH=src python examples/serve_paged.py

Shows both MARS layers of the serving stack:
  1. the ONLINE scheduler (software RequestQ) grouping requests by KV
     prefix block, vs FIFO batching;
  2. the BULK kernel: paged_attention visiting KV pages in page order
     (validated against its jnp oracle here).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.launch import serve

# 1. scheduler comparison (runs a real smoke model underneath)
results = serve.main(["--arch", "qwen1_5_0_5b", "--smoke",
                      "--requests", "48", "--batch", "8"])

# 2. paged attention kernel demo: decode one token for 4 sequences whose
#    KV lives in 16-entry pages
B, H, Hkv, D, page, npages = 4, 8, 2, 64, 16, 6
ks = jax.random.split(jax.random.key(0), 3)
q = jax.random.normal(ks[0], (B, H, D))
kp = jax.random.normal(ks[1], (B * npages, page, Hkv, D))
vp = jax.random.normal(ks[2], (B * npages, page, Hkv, D))
pt = jnp.arange(B * npages, dtype=jnp.int32).reshape(B, npages)
lengths = jnp.asarray([90, 64, 17, 96], jnp.int32)
out = paged_attention(q, kp, vp, pt, lengths, interpret=True)
ref = paged_attention_ref(q, kp, vp, pt, lengths)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                           atol=2e-4)
print("[example] paged_attention kernel matches oracle "
      f"(max err {np.abs(np.asarray(out) - np.asarray(ref)).max():.2e})")
