"""Batched serving through the paged KV-cache pool + MARS scheduler.

    PYTHONPATH=src python examples/serve_paged.py

All three MARS layers of the serving stack:
  1. the ONLINE scheduler (software RequestQ) grouping requests by KV
     prefix block, vs FIFO batching, driving a real smoke model;
  2. the MEMORY subsystem: continuous batching over the block pool —
     prefix-shared blocks, MARS-aware placement, copy-on-write forks,
     pool-capacity admission;
  3. the BULK kernel: paged_attention reading the pool's block tables
     (Pallas interpret mode), validated against the dense jnp oracle;
  4. the FULL LM: a real multi-layer config served through the unified
     KV-backend API (``PagedBackend``), token-exact against the dense
     backend.
"""
import numpy as np

from repro.kvcache import BlockPool, PoolConfig
from repro.launch import serve
from repro.serve.engine import ServeEngine
from repro.serving.scheduler import MarsScheduler, Request

# 1. scheduler comparison (runs a real smoke model underneath)
results = serve.main(["--arch", "qwen1_5_0_5b", "--smoke",
                      "--requests", "48", "--batch", "8"])

# 2 + 3. continuous batching over the pool, decode via the Pallas kernel
rng = np.random.default_rng(0)
prefixes = [tuple(rng.integers(1, 100, 20).tolist()) for _ in range(4)]
reqs = []
for i in range(24):
    reqs.append(Request(rid=i, prompt=prefixes[i % 4]
                        + tuple(rng.integers(1, 100, 4).tolist()),
                        arrival=i * 1e-3, max_new=6,
                        n_samples=3 if i == 0 else 1))  # forks exercise CoW

outs = {}
for use_kernel in (False, True):
    pool = BlockPool(PoolConfig(num_blocks=96, block_size=16,
                                n_kv_heads=2, head_dim=64))
    eng = ServeEngine(pool, MarsScheduler(pool=pool), max_lanes=6,
                      use_kernel=use_kernel)
    outs[use_kernel] = eng.run(reqs)
    pool.check_invariants()
    if use_kernel:
        print(f"[example] paged pool: served={len(outs[use_kernel])} "
              f"prefix_hits={pool.stats.prefix_hits} "
              f"cow_copies={pool.stats.cow_copies} "
              f"evictions={pool.stats.evictions} "
              f"pool_rejects={eng.scheduler.stats.pool_rejects}")

assert outs[False] == outs[True], "kernel vs oracle serving paths diverged"
print("[example] paged_attention kernel serving matches dense oracle "
      f"on {sum(len(v) for v in outs[True].values())} sequences")

# 4. full-LM paged serving: qwen smoke config, every layer's KV in the
# layered pool, parity against the dense backend asserted inside
serve.main(["--paged", "--config", "qwen1_5_0_5b", "--smoke",
            "--requests", "12", "--batch", "4", "--new-tokens", "5"])
