"""All MoE dispatch paths compute the same function (in f32):
dense oracle == einsum baseline == MARS local == kernels op."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.moe_dispatch import ops
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="eq", family="moe", n_layers=1, d_model=48,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab=64,
                      n_experts=8, top_k=2, d_expert=64,
                      param_dtype="float32", compute_dtype="float32")
    params = moe_mod.moe_init(jax.random.key(0), cfg).params
    T = 96
    x = jax.random.normal(jax.random.key(1), (T, cfg.d_model))
    idx, gates, _ = moe_mod.router_topk(params, x, cfg)
    return cfg, params, x, idx, gates


def _dense_oracle(params, x, idx, gates):
    T = x.shape[0]
    h = jnp.einsum("td,edf->tef", x, params["w_in"])
    g = jnp.einsum("td,edf->tef", x, params["w_gate"])
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * h, params["w_out"])
    per = o[jnp.arange(T)[:, None], idx]
    return (per * gates[..., None]).sum(1)


def test_einsum_matches_dense(setup):
    cfg, params, x, idx, gates = setup
    want = _dense_oracle(params, x, idx, gates)
    got, _ = moe_mod.moe_apply_einsum(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_mars_local_matches_dense(setup):
    cfg, params, x, idx, gates = setup
    want = _dense_oracle(params, x, idx, gates)
    got, _ = moe_mod._mars_dispatch_local(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_kernel_op_matches_dense(setup):
    cfg, params, x, idx, gates = setup
    want = _dense_oracle(params, x, idx, gates)
    got = ops.mars_moe_ffn(x, idx, gates, params["w_in"], params["w_gate"],
                           params["w_out"], n_experts=cfg.n_experts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_moe_apply_adds_shared_expert(setup):
    cfg, params, x, idx, gates = setup
    cfg_sh = dataclasses.replace(cfg, n_shared_experts=1)
    params_sh = moe_mod.moe_init(jax.random.key(0), cfg_sh).params
    y, _ = moe_mod.moe_apply(params_sh, x[None], cfg_sh)
    y_no, _ = moe_mod.moe_apply(
        {k: v for k, v in params_sh.items() if k != "shared"},
        x[None], cfg)
    assert not np.allclose(np.asarray(y), np.asarray(y_no))
