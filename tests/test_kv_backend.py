"""Unified KV-backend API: dense-vs-paged decode parity (gathered dense
view AND per-layer Pallas kernel path), layer-axis placement, ragged
continuous-batching decode, and the full-LM engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kvcache import row_group_of
from repro.kvcache.backend import DenseBackend, PagedBackend, make_backend
from repro.models import lm

ARCHS = ["qwen1_5_0_5b", "starcoder2_7b", "phi3_medium_14b"]


def _model(arch, seed=0, f32=False):
    cfg = configs.get_smoke(arch)
    if f32:
        # f32 compute removes compute-dtype near-ties, so the kernel
        # path's f32 attention accumulation (vs the dense path's rounding
        # through bf16) still yields identical argmaxes
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
    params = lm.init(cfg, jax.random.key(seed)).params
    return cfg, params


# ---------------------------------------------------------------------------
# dense vs paged logit parity — gather path and kernel path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("decode_mode", ["gather", "kernel"])
def test_dense_paged_decode_parity(arch, decode_mode):
    """DenseBackend and PagedBackend must produce matching logits across
    prefill + several greedy decode steps — gathered-dense-view decode
    runs bit-identical math; kernel-path decode (Pallas paged_attention
    per layer) must agree to accumulation-order tolerance with identical
    argmaxes (checked in f32 compute, where no near-ties exist)."""
    cfg, params = _model(arch, f32=decode_mode == "kernel")
    tokens = jax.random.randint(jax.random.key(1), (2, 9), 1, cfg.vocab)

    dense = DenseBackend(cfg, batch=2, max_seq=24)
    paged = PagedBackend(cfg, num_blocks=64, block_size=4,
                         decode_mode=decode_mode)
    assert paged.decode_mode == decode_mode
    lg_d, _ = lm.prefill(params, cfg, tokens, backend=dense)
    lg_p, _ = lm.prefill(params, cfg, tokens, backend=paged)
    np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                               np.asarray(lg_p, np.float32),
                               rtol=1e-4, atol=1e-4)
    tok = jnp.argmax(lg_d[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(5):
        lg_d, _ = lm.decode_step(params, cfg, tok, dense)
        lg_p, _ = lm.decode_step(params, cfg, tok, paged)
        np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   rtol=1e-4, atol=1e-4)
        a = np.argmax(np.asarray(lg_d[:, -1], np.float32), -1)
        b = np.argmax(np.asarray(lg_p[:, -1], np.float32), -1)
        assert (a == b).all()
        tok = jnp.asarray(a, jnp.int32)[:, None]
    assert (np.asarray(paged.lengths) == np.asarray(dense.lengths)).all()
    paged.release()
    paged.pool.check_invariants()
    assert paged.pool.num_live == 0


def test_make_backend_registry():
    cfg, _ = _model(ARCHS[0])
    assert isinstance(make_backend(cfg, "dense", batch=1, max_seq=8),
                      DenseBackend)
    assert isinstance(make_backend(cfg, "paged", num_blocks=16),
                      PagedBackend)
    # paged sizing honors the caller's capacity request: batch lanes of
    # max_seq tokens (+1 decode slot), ceil-divided into blocks
    be = make_backend(cfg, "paged", batch=2, max_seq=64)
    assert be.pool.cfg.num_blocks == 2 * (-(-(64 + 1) // 16))
    with pytest.raises(ValueError):
        make_backend(cfg, "holographic")
    # families whose decode state the pool cannot hold are refused, not
    # silently mis-served
    with pytest.raises(NotImplementedError):
        make_backend(configs.get_smoke("mamba2_370m"), "paged")


def test_kernel_decode_parity_moe_layer_offsets():
    """MoE config with a leading dense block stack (kimi: n_dense_layers=1)
    — the kernel path's scanned absolute layer index must address the
    right plane of the layered pool in both stacks."""
    cfg, params = _model("kimi_k2_1t_a32b", f32=True)
    assert cfg.is_moe and cfg.n_dense_layers > 0
    tokens = jax.random.randint(jax.random.key(3), (2, 9), 1, cfg.vocab)
    dense = DenseBackend(cfg, batch=2, max_seq=24)
    paged = PagedBackend(cfg, num_blocks=64, block_size=4)
    lg_d, _ = lm.prefill(params, cfg, tokens, backend=dense)
    lg_p, _ = lm.prefill(params, cfg, tokens, backend=paged)
    tok = jnp.argmax(lg_d[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        lg_d, _ = lm.decode_step(params, cfg, tok, dense)
        lg_p, _ = lm.decode_step(params, cfg, tok, paged)
        np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   rtol=1e-4, atol=1e-4)
        a = np.argmax(np.asarray(lg_d[:, -1], np.float32), -1)
        assert (a == np.argmax(np.asarray(lg_p[:, -1], np.float32),
                               -1)).all()
        tok = jnp.asarray(a, jnp.int32)[:, None]
    paged.release()
    paged.pool.check_invariants()


def test_paged_decode_mode_selection():
    cfg, _ = _model(ARCHS[0])
    # kernel is the default decode path; gather stays as fallback/oracle
    assert PagedBackend(cfg, num_blocks=16).decode_mode == "kernel"
    assert PagedBackend(cfg, num_blocks=16,
                        decode_mode="gather").decode_mode == "gather"
    with pytest.raises(ValueError):
        PagedBackend(cfg, num_blocks=16, decode_mode="telepathic")
    # sliding-window configs stay on the kernel path — the kernel masks
    # the window natively (per-layer flag for global_every hybrids)
    swin = dataclasses.replace(cfg, sliding_window=8)
    assert PagedBackend(swin, num_blocks=16).decode_mode == "kernel"


@pytest.mark.parametrize("decode_mode", ["gather", "kernel"])
def test_dense_paged_parity_sliding_window(decode_mode):
    """Pure-window config (starcoder2-style: every layer windowed): the
    kernel's sliding-window mask must reproduce the dense backend's
    window mask exactly — decoded past the window edge so the mask is
    actually cutting keys."""
    cfg, params = _model("starcoder2_7b", f32=decode_mode == "kernel")
    cfg = dataclasses.replace(cfg, sliding_window=5)
    params = lm.init(cfg, jax.random.key(0)).params
    tokens = jax.random.randint(jax.random.key(11), (2, 9), 1, cfg.vocab)

    dense = DenseBackend(cfg, batch=2, max_seq=24)
    paged = PagedBackend(cfg, num_blocks=64, block_size=4,
                         decode_mode=decode_mode)
    assert paged.decode_mode == decode_mode
    lg_d, _ = lm.prefill(params, cfg, tokens, backend=dense)
    lg_p, _ = lm.prefill(params, cfg, tokens, backend=paged)
    np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                               np.asarray(lg_p, np.float32),
                               rtol=1e-4, atol=1e-4)
    tok = jnp.argmax(lg_d[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(7):          # lengths reach 16 >> window 5
        lg_d, _ = lm.decode_step(params, cfg, tok, dense)
        lg_p, _ = lm.decode_step(params, cfg, tok, paged)
        np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   rtol=1e-4, atol=1e-4)
        a = np.argmax(np.asarray(lg_d[:, -1], np.float32), -1)
        b = np.argmax(np.asarray(lg_p[:, -1], np.float32), -1)
        assert (a == b).all()
        tok = jnp.asarray(a, jnp.int32)[:, None]
    paged.release()
    paged.pool.check_invariants()


@pytest.mark.parametrize("decode_mode", ["gather", "kernel"])
def test_dense_paged_parity_hybrid_ssm_state(decode_mode):
    """Hybrid (hymba: parallel attention+SSM heads, window + global_every
    layers): PagedBackend pages the KV and carries the per-sequence
    SSM/conv side state — logits must match the dense backend whose cache
    pytree holds the same state."""
    cfg, params = _model("hymba_1_5b", f32=decode_mode == "kernel")
    # shrink the window below the decoded length so the mask really cuts
    cfg = dataclasses.replace(cfg, sliding_window=6)
    params = lm.init(cfg, jax.random.key(0)).params
    assert cfg.has_ssm and cfg.sliding_window and cfg.global_every
    # prompt length must be a multiple of the SSD chunk (smoke: 8)
    tokens = jax.random.randint(jax.random.key(5), (2, 8), 1, cfg.vocab)

    dense = DenseBackend(cfg, batch=2, max_seq=24)
    paged = PagedBackend(cfg, num_blocks=64, block_size=4,
                         decode_mode=decode_mode)
    assert paged.decode_mode == decode_mode
    lg_d, _ = lm.prefill(params, cfg, tokens, backend=dense)
    lg_p, _ = lm.prefill(params, cfg, tokens, backend=paged)
    np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                               np.asarray(lg_p, np.float32),
                               rtol=1e-4, atol=1e-4)
    tok = jnp.argmax(lg_d[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(7):
        lg_d, _ = lm.decode_step(params, cfg, tok, dense)
        lg_p, _ = lm.decode_step(params, cfg, tok, paged)
        np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   rtol=1e-4, atol=1e-4)
        a = np.argmax(np.asarray(lg_d[:, -1], np.float32), -1)
        assert (a == np.argmax(np.asarray(lg_p[:, -1], np.float32),
                               -1)).all()
        tok = jnp.asarray(a, jnp.int32)[:, None]
    paged.release()
    paged.pool.check_invariants()
    assert paged.pool.num_live == 0


def test_hybrid_fork_copies_side_state():
    """A forked hybrid sequence must own its SSM/conv state: diverging
    forks advance independent recurrences (CoW shares only KV blocks)."""
    cfg, params = _model("hymba_1_5b")
    backend = PagedBackend(cfg, num_blocks=64, block_size=4,
                           decode_mode="gather")
    sid, _, _ = backend.new_seq(params, list(range(1, 9)))
    fid = backend.fork_seq(sid)
    s, f = backend._seqs[sid], backend._seqs[fid]
    assert s.ssm is not None and f.ssm is not None
    assert s.ssm is not f.ssm and np.array_equal(s.ssm, f.ssm)
    backend.decode(params, [sid, fid], [7, 9])   # forks diverge
    assert not np.array_equal(backend._seqs[sid].ssm,
                              backend._seqs[fid].ssm)
    backend.release()
    backend.pool.check_invariants()


def test_dense_backend_exposes_concrete_cache_reads():
    """Migration compatibility: .k/.v/.length forward to the pytree."""
    cfg, params = _model(ARCHS[0])
    be = lm.init_cache(cfg, batch=2, max_seq=16)
    assert be.k.shape == (cfg.n_layers, 2, 16,  # lint: ok(dense-kv-read)
                          cfg.n_kv_heads, cfg.d_head)
    tokens = jax.random.randint(jax.random.key(2), (2, 4), 1, cfg.vocab)
    _, be = lm.prefill(params, cfg, tokens, backend=be)
    assert int(be.length) == 4


# ---------------------------------------------------------------------------
# layer-axis placement
# ---------------------------------------------------------------------------

def test_layer_axis_keeps_token_blocks_in_one_row_group():
    """A token's per-layer KV blocks must land in one DRAM row group: the
    pool's layer axis makes one block id (= one placement decision) cover
    every layer, and MARS placement packs a sequence's blocks into few
    groups."""
    cfg, params = _model(ARCHS[0])
    backend = PagedBackend(cfg, num_blocks=64, block_size=4)
    pool = backend.pool
    prompt = list(range(1, 19))
    sid, _, _ = backend.new_seq(params, prompt)
    for _ in range(3):
        backend.decode(params, [sid], [5])
    table = backend.table(sid)
    bpg = pool.cfg.blocks_per_group
    for t in range(table.num_tokens):
        groups = {row_group_of(backend.block_of(sid, layer, t), bpg)
                  for layer in range(cfg.n_layers)}
        assert len(groups) == 1, \
            f"token {t} scattered across row groups {groups}"
    # MARS placement on a fresh pool: the whole sequence packs into the
    # minimum number of row neighborhoods
    seq_groups = {row_group_of(b, bpg) for b in table.blocks}
    assert len(seq_groups) == -(-len(table.blocks) // bpg)
    # and the pool buffer really is layered: one plane per model layer
    assert pool.k_pages.shape[0] == cfg.n_layers


def test_paged_ragged_decode_matches_isolated():
    """Lanes at different lengths decoding in one batched call must see
    exactly the logits they would get decoding alone."""
    cfg, params = _model(ARCHS[1])
    together = PagedBackend(cfg, num_blocks=64, block_size=4,
                            share_prefixes=False)
    a, la, _ = together.new_seq(params, list(range(1, 14)))   # 13 tokens
    b, lb, _ = together.new_seq(params, list(range(20, 25)))  # 5 tokens
    lg = together.decode(params, [a, b], [7, 9])
    for prompt, nxt, want0 in ((list(range(1, 14)), 7, la),
                               (list(range(20, 25)), 9, lb)):
        alone = PagedBackend(cfg, num_blocks=64, block_size=4,
                             share_prefixes=False)
        s, l0, _ = alone.new_seq(params, prompt)
        np.testing.assert_allclose(l0, want0, rtol=1e-4, atol=1e-4)
        lg1 = alone.decode(params, [s], [nxt])
        idx = 0 if nxt == 7 else 1
        np.testing.assert_allclose(lg[idx], lg1[0], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# bugfix regressions: exhaustion rollback, released backends, dirty staging
# ---------------------------------------------------------------------------

def test_pool_exhaustion_rolls_back_partial_prefill():
    """If ``table.extend`` exhausts the pool mid-prefill, the partial
    table (prefix-matched increfed blocks + blocks allocated before the
    failure) must be rolled back — nothing stays live, invariants hold,
    and the error still surfaces."""
    cfg, params = _model(ARCHS[0])
    backend = PagedBackend(cfg, num_blocks=8, block_size=4,
                           decode_mode="gather")
    pool = backend.pool
    # seed the prefix cache: a 20-token sequence fills 5 blocks, all
    # registered; freeing it leaves them cached (evictable), none live
    sid, _, _ = backend.new_seq(params, list(range(1, 21)))
    backend.free_seq(sid)
    assert pool.num_live == 0 and pool.num_cached == 5
    live0, cached0 = pool.num_live, pool.num_cached
    # same prefix + a tail that needs 10 blocks total > 8 in the pool:
    # the prefix match revives 4 cached blocks, extension allocates a few
    # more, then the pool runs out mid-extend
    prompt = list(range(1, 17)) + list(range(100, 124))
    with pytest.raises(RuntimeError, match="pool exhausted"):
        backend.new_seq(params, prompt)
    pool.check_invariants()
    assert pool.num_live == live0, "partial prefill leaked live blocks"
    assert pool.num_cached >= 1    # matched prefix blocks returned to cache
    # the pool still serves: a fitting request succeeds afterwards
    sid2, _, _ = backend.new_seq(params, list(range(1, 13)))
    backend.free_seq(sid2)
    pool.check_invariants()
    assert pool.num_live == 0


def test_pool_exhaustion_rolls_back_whole_batch():
    """Batched prefill is atomic: rows added before the failing row are
    freed too, so ``num_live`` returns to its pre-call value."""
    cfg, params = _model(ARCHS[0])
    backend = PagedBackend(cfg, num_blocks=6, block_size=4,
                           decode_mode="gather", share_prefixes=False)
    pool = backend.pool
    with pytest.raises(RuntimeError, match="pool exhausted"):
        # row 0 fits (3 blocks), row 1 wants 4 more of the remaining 3
        backend._add_seqs(params, np.asarray(
            [list(range(1, 13)) + [0, 0], list(range(20, 34))], np.int32))
    pool.check_invariants()
    assert pool.num_live == 0 and not backend._seqs


def test_released_dense_backend_raises_clear_error():
    cfg, params = _model(ARCHS[0])
    be = DenseBackend(cfg, batch=1, max_seq=8)
    tokens = jax.random.randint(jax.random.key(0), (1, 4), 1, cfg.vocab)
    lm.prefill(params, cfg, tokens, backend=be)
    be.release()
    with pytest.raises(RuntimeError, match="released"):
        be.decode_step(params, jnp.ones((1, 1), jnp.int32))
    with pytest.raises(RuntimeError, match="released"):
        be.prefill(params, tokens)
    with pytest.raises(RuntimeError, match="released"):
        _ = be.lengths
    with pytest.raises(RuntimeError, match="released"):
        _ = be.k    # compatibility reads too; lint: ok(dense-kv-read)


def test_released_paged_backend_raises_clear_error():
    cfg, params = _model(ARCHS[0])
    be = PagedBackend(cfg, num_blocks=32, block_size=4)
    tokens = jax.random.randint(jax.random.key(0), (1, 4), 1, cfg.vocab)
    lm.prefill(params, cfg, tokens, backend=be)
    be.release()
    be.pool.check_invariants()
    for fn in (lambda: be.decode_step(params, jnp.ones((1, 1), jnp.int32)),
               lambda: be.prefill(params, tokens),
               lambda: be.lengths,
               lambda: be.new_seq(params, [1, 2, 3]),
               lambda: be.fork_seq(0),
               lambda: be.free_seq(0),
               lambda: be.table(0)):
        with pytest.raises(RuntimeError, match="released"):
            fn()


def test_decode_stages_only_dirty_blocks():
    """Per-step staging uploads only recently-written blocks — never the
    whole pool (the first step pays the full upload to build the device
    mirrors).  The mirrors are double-buffered: the slot staged for step
    N last scattered at step N-2, so each step's staged set is the union
    of the last TWO steps' dirty blocks — a single tail block in steady
    state, two only when the lane crosses a block boundary."""
    cfg, params = _model(ARCHS[0])
    backend = PagedBackend(cfg, num_blocks=64, block_size=4,
                           share_prefixes=False)
    pool = backend.pool
    sid, _, _ = backend.new_seq(params, list(range(1, 10)))
    backend.decode(params, [sid], [3])
    assert backend.staged_blocks_last_step == pool.cfg.num_blocks
    prev_dirty = set(pool.dirty)
    for tok in (5, 7, 9, 11):
        cur_dirty = set(pool.dirty)
        backend.decode(params, [sid], [tok])
        assert backend.staged_blocks_last_step \
            == len(prev_dirty | cur_dirty) <= 2, \
            "decode restaged more than the last two steps' dirty blocks"
        prev_dirty = cur_dirty
    # a second sequence's prefill dirties its blocks; the next decode
    # stages those plus the first lane's tail — still not the whole pool
    sid2, _, _ = backend.new_seq(params, list(range(30, 45)))
    cur_dirty = set(pool.dirty)
    assert 1 < len(prev_dirty | cur_dirty) < pool.cfg.num_blocks
    backend.decode(params, [sid, sid2], [2, 4])
    assert backend.staged_blocks_last_step == len(prev_dirty | cur_dirty)
    # the mirror converges to the host pool once pending writes stage
    backend._staged_pages()
    np.testing.assert_array_equal(np.asarray(backend._k_dev),
                                  pool.k_pages)
    backend.release()


def test_paged_prefix_sharing_shares_storage():
    cfg, params = _model(ARCHS[0])
    backend = PagedBackend(cfg, num_blocks=64, block_size=4)
    prompt = list(range(1, 18))
    s1, l1, n1 = backend.new_seq(params, prompt)
    s2, l2, n2 = backend.new_seq(params, prompt)
    assert n1 == 0 and n2 == 16          # 4 full blocks matched
    assert backend.table(s1).blocks[:4] == backend.table(s2).blocks[:4]
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)
    backend.release()
    assert backend.pool.num_live == 0
