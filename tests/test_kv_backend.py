"""Unified KV-backend API: dense-vs-paged decode parity (gathered dense
view AND per-layer Pallas kernel path), layer-axis placement, ragged
continuous-batching decode, and the full-LM engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kvcache import row_group_of
from repro.kvcache.backend import DenseBackend, PagedBackend, make_backend
from repro.models import lm

ARCHS = ["qwen1_5_0_5b", "starcoder2_7b", "phi3_medium_14b"]


def _model(arch, seed=0, f32=False):
    cfg = configs.get_smoke(arch)
    if f32:
        # f32 compute removes compute-dtype near-ties, so the kernel
        # path's f32 attention accumulation (vs the dense path's rounding
        # through bf16) still yields identical argmaxes
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
    params = lm.init(cfg, jax.random.key(seed)).params
    return cfg, params


# ---------------------------------------------------------------------------
# dense vs paged logit parity — gather path and kernel path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("decode_mode", ["gather", "kernel"])
def test_dense_paged_decode_parity(arch, decode_mode):
    """DenseBackend and PagedBackend must produce matching logits across
    prefill + several greedy decode steps — gathered-dense-view decode
    runs bit-identical math; kernel-path decode (Pallas paged_attention
    per layer) must agree to accumulation-order tolerance with identical
    argmaxes (checked in f32 compute, where no near-ties exist)."""
    cfg, params = _model(arch, f32=decode_mode == "kernel")
    tokens = jax.random.randint(jax.random.key(1), (2, 9), 1, cfg.vocab)

    dense = DenseBackend(cfg, batch=2, max_seq=24)
    paged = PagedBackend(cfg, num_blocks=64, block_size=4,
                         decode_mode=decode_mode)
    assert paged.decode_mode == decode_mode
    lg_d, _ = lm.prefill(params, cfg, tokens, backend=dense)
    lg_p, _ = lm.prefill(params, cfg, tokens, backend=paged)
    np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                               np.asarray(lg_p, np.float32),
                               rtol=1e-4, atol=1e-4)
    tok = jnp.argmax(lg_d[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(5):
        lg_d, _ = lm.decode_step(params, cfg, tok, dense)
        lg_p, _ = lm.decode_step(params, cfg, tok, paged)
        np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   rtol=1e-4, atol=1e-4)
        a = np.argmax(np.asarray(lg_d[:, -1], np.float32), -1)
        b = np.argmax(np.asarray(lg_p[:, -1], np.float32), -1)
        assert (a == b).all()
        tok = jnp.asarray(a, jnp.int32)[:, None]
    assert (np.asarray(paged.lengths) == np.asarray(dense.lengths)).all()
    paged.release()
    paged.pool.check_invariants()
    assert paged.pool.num_live == 0


def test_make_backend_registry():
    cfg, _ = _model(ARCHS[0])
    assert isinstance(make_backend(cfg, "dense", batch=1, max_seq=8),
                      DenseBackend)
    assert isinstance(make_backend(cfg, "paged", num_blocks=16),
                      PagedBackend)
    # paged sizing honors the caller's capacity request: batch lanes of
    # max_seq tokens (+1 decode slot), ceil-divided into blocks
    be = make_backend(cfg, "paged", batch=2, max_seq=64)
    assert be.pool.cfg.num_blocks == 2 * (-(-(64 + 1) // 16))
    with pytest.raises(ValueError):
        make_backend(cfg, "holographic")
    # families whose decode state the pool cannot hold are refused, not
    # silently mis-served
    with pytest.raises(NotImplementedError):
        make_backend(configs.get_smoke("mamba2_370m"), "paged")


def test_kernel_decode_parity_moe_layer_offsets():
    """MoE config with a leading dense block stack (kimi: n_dense_layers=1)
    — the kernel path's scanned absolute layer index must address the
    right plane of the layered pool in both stacks."""
    cfg, params = _model("kimi_k2_1t_a32b", f32=True)
    assert cfg.is_moe and cfg.n_dense_layers > 0
    tokens = jax.random.randint(jax.random.key(3), (2, 9), 1, cfg.vocab)
    dense = DenseBackend(cfg, batch=2, max_seq=24)
    paged = PagedBackend(cfg, num_blocks=64, block_size=4)
    lg_d, _ = lm.prefill(params, cfg, tokens, backend=dense)
    lg_p, _ = lm.prefill(params, cfg, tokens, backend=paged)
    tok = jnp.argmax(lg_d[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        lg_d, _ = lm.decode_step(params, cfg, tok, dense)
        lg_p, _ = lm.decode_step(params, cfg, tok, paged)
        np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   rtol=1e-4, atol=1e-4)
        a = np.argmax(np.asarray(lg_d[:, -1], np.float32), -1)
        assert (a == np.argmax(np.asarray(lg_p[:, -1], np.float32),
                               -1)).all()
        tok = jnp.asarray(a, jnp.int32)[:, None]
    paged.release()
    paged.pool.check_invariants()


def test_paged_decode_mode_selection():
    cfg, _ = _model(ARCHS[0])
    # kernel is the default decode path; gather stays as fallback/oracle
    assert PagedBackend(cfg, num_blocks=16).decode_mode == "kernel"
    assert PagedBackend(cfg, num_blocks=16,
                        decode_mode="gather").decode_mode == "gather"
    with pytest.raises(ValueError):
        PagedBackend(cfg, num_blocks=16, decode_mode="telepathic")
    # sliding-window configs fall back to the gathered dense view (the
    # kernel has no window mask yet) instead of mis-serving
    swin = dataclasses.replace(cfg, sliding_window=8)
    assert PagedBackend(swin, num_blocks=16).decode_mode == "gather"
    with pytest.raises(NotImplementedError):
        lm.paged_decode_step({}, swin, jnp.zeros((1, 1), jnp.int32),
                             None, None, None, jnp.zeros(1, jnp.int32))


def test_dense_backend_exposes_concrete_cache_reads():
    """Migration compatibility: .k/.v/.length forward to the pytree."""
    cfg, params = _model(ARCHS[0])
    be = lm.init_cache(cfg, batch=2, max_seq=16)
    assert be.k.shape == (cfg.n_layers, 2, 16, cfg.n_kv_heads, cfg.d_head)
    tokens = jax.random.randint(jax.random.key(2), (2, 4), 1, cfg.vocab)
    _, be = lm.prefill(params, cfg, tokens, backend=be)
    assert int(be.length) == 4


# ---------------------------------------------------------------------------
# layer-axis placement
# ---------------------------------------------------------------------------

def test_layer_axis_keeps_token_blocks_in_one_row_group():
    """A token's per-layer KV blocks must land in one DRAM row group: the
    pool's layer axis makes one block id (= one placement decision) cover
    every layer, and MARS placement packs a sequence's blocks into few
    groups."""
    cfg, params = _model(ARCHS[0])
    backend = PagedBackend(cfg, num_blocks=64, block_size=4)
    pool = backend.pool
    prompt = list(range(1, 19))
    sid, _, _ = backend.new_seq(params, prompt)
    for _ in range(3):
        backend.decode(params, [sid], [5])
    table = backend.table(sid)
    bpg = pool.cfg.blocks_per_group
    for t in range(table.num_tokens):
        groups = {row_group_of(backend.block_of(sid, layer, t), bpg)
                  for layer in range(cfg.n_layers)}
        assert len(groups) == 1, \
            f"token {t} scattered across row groups {groups}"
    # MARS placement on a fresh pool: the whole sequence packs into the
    # minimum number of row neighborhoods
    seq_groups = {row_group_of(b, bpg) for b in table.blocks}
    assert len(seq_groups) == -(-len(table.blocks) // bpg)
    # and the pool buffer really is layered: one plane per model layer
    assert pool.k_pages.shape[0] == cfg.n_layers


def test_paged_ragged_decode_matches_isolated():
    """Lanes at different lengths decoding in one batched call must see
    exactly the logits they would get decoding alone."""
    cfg, params = _model(ARCHS[1])
    together = PagedBackend(cfg, num_blocks=64, block_size=4,
                            share_prefixes=False)
    a, la, _ = together.new_seq(params, list(range(1, 14)))   # 13 tokens
    b, lb, _ = together.new_seq(params, list(range(20, 25)))  # 5 tokens
    lg = together.decode(params, [a, b], [7, 9])
    for prompt, nxt, want0 in ((list(range(1, 14)), 7, la),
                               (list(range(20, 25)), 9, lb)):
        alone = PagedBackend(cfg, num_blocks=64, block_size=4,
                             share_prefixes=False)
        s, l0, _ = alone.new_seq(params, prompt)
        np.testing.assert_allclose(l0, want0, rtol=1e-4, atol=1e-4)
        lg1 = alone.decode(params, [s], [nxt])
        idx = 0 if nxt == 7 else 1
        np.testing.assert_allclose(lg[idx], lg1[0], rtol=1e-4, atol=1e-4)


def test_paged_prefix_sharing_shares_storage():
    cfg, params = _model(ARCHS[0])
    backend = PagedBackend(cfg, num_blocks=64, block_size=4)
    prompt = list(range(1, 18))
    s1, l1, n1 = backend.new_seq(params, prompt)
    s2, l2, n2 = backend.new_seq(params, prompt)
    assert n1 == 0 and n2 == 16          # 4 full blocks matched
    assert backend.table(s1).blocks[:4] == backend.table(s2).blocks[:4]
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)
    backend.release()
    assert backend.pool.num_live == 0
