"""OK: every lifecycle entry point drains the pipeline first."""


class SafeBackend:
    def _commit_pending(self):
        pass

    def _check_released(self):
        pass

    def flush(self):
        self._commit_pending()

    def fork_seq(self, sid):
        self._check_released()
        self.flush()
        self._seqs[99] = self._seqs[sid]
        return 99

    def free_seq(self, sid):
        self._check_released()
        self.flush()
        return self._seqs.pop(sid)

    def prefill(self, params, tokens):
        self._check_released()
        self.flush()
        self._batch = []
        return self._add_seqs(params, tokens)

    def new_seq(self, params, prompt):
        return self._add_seqs(params, [prompt])   # delegate flushes

    def _add_seqs(self, params, tokens):
        self.flush()
        self._batch = list(tokens)
        return self._batch

    def release(self):
        if not self._released:
            self._commit_pending()
        self._released = True


class UnpipelinedBackend:
    # no _commit_pending -> no pipeline, the rule does not apply
    def prefill(self, params, tokens):
        self._batch = list(tokens)
        return self._batch
