"""BAD: deprecated concrete-Cache reads on a backend handle."""


def cache_bytes(cfg, lm, params):
    be = lm.init_cache(cfg, batch=2, max_seq=16)
    total = be.k.nbytes + be.v.nbytes           # deprecated compat reads
    return total


def dense_peek(cfg, DenseBackend):
    be = DenseBackend(cfg, 1, 8)
    return be.k.shape
