"""BAD: mutates pool KV payload / dirty set outside BlockPool."""


def sneaky_promote(pool, dst, k, v):
    pool.k_pages[:, dst] = k        # bypasses the dirty-staging contract
    pool.v_pages[:, dst] = v
    pool.dirty.add(dst)


def sneaky_forget(pool, bid):
    pool.dirty.discard(bid)


def sneaky_reset(pool):
    pool.dirty = set()
