"""OK: the fetch gate lives in the index map — out-of-range grid steps
re-name an in-range block (jnp.clip) so Pallas elides the DMA."""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def kernel(pt_ref, kv_ref, o_ref, *, n_pages):
    j = pl.program_id(1)

    @pl.when(j < n_pages)               # compute gate, paired with the clamp
    def _():
        o_ref[...] += kv_ref[...]


def build_specs(pt, j0, jmax):
    def kv_index(b, j, pt_ref):
        jj = jnp.clip(j, j0, jnp.maximum(jmax, j0))
        return (0, pt_ref[b, jj], 0, 0, 0)

    kv_spec = pl.BlockSpec((1, 1, 8, 1, 1), kv_index)
    plain = pl.BlockSpec((1, 8), lambda b, j: (b, 0))   # no table: fine
    return kv_spec, plain
