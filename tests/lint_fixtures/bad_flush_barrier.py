"""BAD: pipelined backend mutates state before the flush barrier."""


class RacyBackend:
    def _commit_pending(self):
        pass

    def flush(self):
        self._commit_pending()

    def fork_seq(self, sid):
        src = self._seqs[sid]               # reads are fine...
        self._seqs[99] = src                # ...but this store races the
        self.flush()                        # lagged write-back
        return 99

    def free_seq(self, sid):
        self._n -= 1                        # bookkeeping before draining
        seq = self._seqs.pop(sid)
        self.flush()
        return seq

    def release(self):
        self._seqs = {}                     # never drains the pipeline
        self._released = True
