"""BAD (when placed under src/): a second drain_dirty consumer."""


def steal_staging(pool):
    # the owning backend's mirror drains; this steals its dirty stream
    return pool.drain_dirty()
