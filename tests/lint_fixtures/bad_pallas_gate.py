"""BAD: pl.when bounds guard, but the table-driven index map never
clamps — the pipeline still DMAs whatever block the map names."""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def kernel(pt_ref, kv_ref, o_ref, *, n_pages):
    j = pl.program_id(1)

    @pl.when(j < n_pages)               # compute-only gate: fetch not elided
    def _():
        o_ref[...] += kv_ref[...]


def build_specs(pt):
    kv_spec = pl.BlockSpec(
        (1, 1, 8, 1, 1),
        lambda b, j, pt_ref: (0, pt_ref[b, j], 0, 0, 0),   # unclamped
    )
    return kv_spec
