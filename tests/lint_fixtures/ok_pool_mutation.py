"""OK: payload writes go through BlockPool's own write paths."""


class BlockPool:
    def __init__(self, n):
        self.k_pages = self.v_pages = None
        self.dirty = set()

    def write_kv(self, bid, offset, k, v):
        self.k_pages[:, bid] = k
        self.v_pages[:, bid] = v
        self.dirty.add(bid)

    def forget_dirty(self, bid):
        self.dirty.discard(bid)

    def drain_dirty(self):
        out = sorted(self.dirty)
        self.dirty.clear()
        return out


def promote(pool, dst, k, v):
    pool.write_kv(dst, 0, k, v)     # the sanctioned copy-in


def forget(pool, bid):
    pool.forget_dirty(bid)
