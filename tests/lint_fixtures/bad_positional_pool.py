"""BAD: legacy positional pool construction."""


def build(cfg, pool):
    from repro.kvcache.backend import PagedBackend, ShardedPagedBackend
    a = PagedBackend(cfg, pool)                 # deprecated signature
    b = ShardedPagedBackend(cfg, pool, 2)       # ditto
    return a, b
