"""OK (even under src/): observing dirty without draining it."""


def peek_staging(pool):
    return sorted(pool.dirty) if hasattr(pool, "dirty") else []


def pending_count(pool):
    return len(pool.dirty)
