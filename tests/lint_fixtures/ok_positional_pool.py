"""OK: keyword pools and the make_backend registry constructor."""


def build(cfg, pool):
    from repro.kvcache.backend import PagedBackend, make_backend
    a = PagedBackend(cfg, pool=pool)
    b = make_backend(cfg, "paged", num_blocks=16, block_size=4)
    c = PagedBackend(cfg)                       # one positional arg is fine
    return a, b, c
