"""OK: per-layer accessor and the documented pytree escape hatch."""


def cache_bytes(cfg, lm):
    be = lm.init_cache(cfg, batch=2, max_seq=16)
    k0, v0 = be.kv_for_layer(0)
    total = be.cache.k.nbytes                   # backend.cache.* is the
    return k0.nbytes + v0.nbytes + total        # sanctioned pytree read


def unrelated(record):
    # .k on something that is not a backend handle is untouched
    return record.k + record.v
