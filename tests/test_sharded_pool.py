"""Mesh-sharded block pools: partitioning/discovery, admission routing
(prefix-page affinity + shard load), cross-shard parity vs a single pool,
shard-local CoW forks, per-shard invariants under soak, and exhaustion
isolation."""
import dataclasses

import numpy as np
import pytest

from repro.kvcache import BlockPool, BlockTable, PoolConfig, \
    ShardedBlockPool, placement_key, row_group_of
from repro.serving.scheduler import MarsScheduler, Request


def _spool(num_blocks=32, n_shards=2, block_size=4, **kw):
    return ShardedBlockPool(
        PoolConfig(num_blocks=num_blocks, block_size=block_size, **kw),
        n_shards=n_shards)


# ---------------------------------------------------------------------------
# partitioning + mesh discovery
# ---------------------------------------------------------------------------

def test_shards_partition_the_pool():
    sp = _spool(num_blocks=32, n_shards=4)
    assert sp.n_shards == 4 and sp.shard_blocks == 8
    assert all(s.cfg.num_blocks == 8 for s in sp.shards)
    assert sp.num_free == 32 and sp.num_live == 0
    with pytest.raises(AssertionError):
        _spool(num_blocks=30, n_shards=4)   # must divide evenly


def test_mesh_discovery_from_model_axis():
    import jax
    from repro.launch.mesh import make_local_mesh
    from repro.sharding import rules
    from repro.sharding.context import use_mesh

    assert rules.pool_shard_count(None) == 1
    mesh = make_local_mesh()                 # model axis size 1
    assert rules.pool_shard_count(mesh) == 1
    sp = ShardedBlockPool(PoolConfig(num_blocks=16), mesh=mesh)
    assert sp.n_shards == 1
    with use_mesh(mesh):                     # ambient discovery
        assert ShardedBlockPool(PoolConfig(num_blocks=16)).n_shards == 1
    # no mesh anywhere -> single shard
    assert ShardedBlockPool(PoolConfig(num_blocks=16)).n_shards == 1


def test_placement_key_leads_with_shard():
    # the device/shard coordinate orders ahead of the bank+row-group key:
    # a later row group on an earlier shard sorts first
    assert placement_key(63, 8, shard=0) < placement_key(0, 8, shard=1)
    assert placement_key(5, 8) == (0, row_group_of(5, 8), 5)


# ---------------------------------------------------------------------------
# two-phase admission routing
# ---------------------------------------------------------------------------

def test_route_prefix_affinity_cohabits_pages():
    sp = _spool(num_blocks=32, n_shards=2)
    sp.reserve(2)
    s0 = sp.route(rid=0, page="hot", n=2)
    # same page keeps routing to the same shard even though the other
    # shard is now emptier
    sp.reserve(2)
    assert sp.route(rid=1, page="hot", n=2) == s0
    # a different page balances to the other shard (load = reserved)
    sp.reserve(2)
    assert sp.route(rid=2, page="cold", n=2) != s0
    assert sp.reserved == 6 and sp._pending == 0
    sp.check_invariants()


def test_route_defers_when_no_shard_has_headroom():
    sp = _spool(num_blocks=8, n_shards=2)    # 4 blocks per shard
    sp.reserve(4); assert sp.route(rid=0, page="a", n=4) is not None
    sp.reserve(4); assert sp.route(rid=1, page="b", n=4) is not None
    # both shards fully reserved: aggregate admission refuses too
    assert not sp.can_reserve(1)
    sp.reserve(2)
    assert sp.route(rid=2, page="c", n=2) is None   # queued, not lost
    assert sp._pending == 2
    # releasing rid 0 frees its shard; the deferred request routes now
    sp.unreserve(4, rid=0)
    assert sp.route(rid=2, page="c", n=2) is not None
    sp.check_invariants()


def test_can_reserve_requires_single_shard_fit():
    sp = _spool(num_blocks=16, n_shards=2)   # 8 per shard
    # 10 blocks fit the aggregate but can never sit on one shard: a
    # sequence (and its CoW forks) never spans shards
    assert not sp.can_reserve(10)
    assert sp.can_reserve(8)


def test_scheduler_routes_admissions_by_page_and_load():
    sp = _spool(num_blocks=64, n_shards=2, block_size=8)
    sched = MarsScheduler(pool=sp)
    # two hot prefixes, interleaved arrivals (prefix_len 8 = one block)
    pa = tuple(range(1, 9))
    pb = tuple(range(101, 109))
    reqs = [Request(rid=i, prompt=(pa if i % 2 == 0 else pb) + (200 + i,),
                    prefix_len=8, max_new=4) for i in range(6)]
    for r in reqs:
        assert sched.offer(r)
    batch = sched.schedule_batch(6)
    assert len(batch) == 6
    shard_of = {r.rid: r._shard for r in batch}
    # page-coherent co-location: each prefix's requests share one shard,
    # and the two prefixes landed on different shards (load balancing)
    sa = {shard_of[r.rid] for r in reqs if r.prompt[:8] == pa}
    sb = {shard_of[r.rid] for r in reqs if r.prompt[:8] == pb}
    assert len(sa) == 1 and len(sb) == 1 and sa != sb
    sp.check_invariants()


def test_scheduler_defers_until_a_shard_frees():
    sp = _spool(num_blocks=16, n_shards=2, block_size=8)  # 8 blocks/shard
    sched = MarsScheduler(pool=sp)
    # each request needs 5 blocks -> one per shard fits, third defers
    reqs = [Request(rid=i, prompt=tuple(range(1 + 32 * i, 33 + 32 * i)),
                    prefix_len=8, max_new=8) for i in range(3)]
    for r in reqs:
        assert sched.offer(r)
    batch = sched.schedule_batch(8)
    assert [r.rid for r in batch] == [0, 1]
    assert sched.stats.shard_defers == 1
    assert len(sched) == 1                    # rid 2 still buffered
    # a finished request frees its shard reservation -> rid 2 schedules
    sp.unreserve(5, rid=batch[0].rid)
    batch2 = sched.schedule_batch(8)
    assert [r.rid for r in batch2] == [2]
    sp.check_invariants()


# ---------------------------------------------------------------------------
# cross-shard parity vs a single pool / dense backend
# ---------------------------------------------------------------------------

def _model(arch="qwen1_5_0_5b", f32=False):
    import jax
    from repro import configs
    from repro.models import lm

    cfg = configs.get_smoke(arch)
    if f32:
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
    return cfg, lm.init(cfg, jax.random.key(0)).params


@pytest.mark.parametrize("decode_mode", ["gather", "kernel"])
def test_sharded_paged_parity_vs_dense(decode_mode):
    """Rows routed across two shard pools must decode to exactly the
    logits a single dense cache produces — the shard boundary is a pure
    storage partition, invisible to the math."""
    import jax
    import jax.numpy as jnp
    from repro.kvcache.backend import DenseBackend, ShardedPagedBackend
    from repro.models import lm

    cfg, params = _model(f32=decode_mode == "kernel")
    tokens = jax.random.randint(jax.random.key(1), (4, 9), 1, cfg.vocab)
    dense = DenseBackend(cfg, batch=4, max_seq=24)
    sharded = ShardedPagedBackend(cfg, n_shards=2, num_blocks=64,
                                  block_size=4, decode_mode=decode_mode)
    lg_d, _ = lm.prefill(params, cfg, tokens, backend=dense)
    lg_p, _ = lm.prefill(params, cfg, tokens, backend=sharded)
    np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                               np.asarray(lg_p, np.float32),
                               rtol=1e-4, atol=1e-4)
    # the batch really is spread: both shards hold live blocks
    assert all(p.num_live > 0 for p in sharded.pool.shards)
    tok = jnp.argmax(lg_d[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(4):
        lg_d, _ = lm.decode_step(params, cfg, tok, dense)
        lg_p, _ = lm.decode_step(params, cfg, tok, sharded)
        np.testing.assert_allclose(np.asarray(lg_d, np.float32),
                                   np.asarray(lg_p, np.float32),
                                   rtol=1e-4, atol=1e-4)
        a = np.argmax(np.asarray(lg_d[:, -1], np.float32), -1)
        assert (a == np.argmax(np.asarray(lg_p[:, -1], np.float32),
                               -1)).all()
        tok = jnp.asarray(a, jnp.int32)[:, None]
    assert (np.asarray(sharded.lengths) == np.asarray(dense.lengths)).all()
    sharded.release()
    sharded.pool.check_invariants()
    assert sharded.pool.num_live == 0
    with pytest.raises(RuntimeError, match="released"):
        sharded.decode_step(params, jnp.ones((4, 1), jnp.int32))


def test_sharded_matches_single_pool_backend():
    """Same tokens through a 2-shard backend and a plain single-pool
    PagedBackend: identical logits (both run the same per-shard math)."""
    import jax
    from repro.kvcache.backend import PagedBackend, ShardedPagedBackend
    from repro.models import lm

    cfg, params = _model()
    tokens = jax.random.randint(jax.random.key(2), (2, 9), 1, cfg.vocab)
    single = PagedBackend(cfg, num_blocks=32, block_size=4,
                          decode_mode="gather")
    sharded = ShardedPagedBackend(cfg, n_shards=2, num_blocks=64,
                                  block_size=4, decode_mode="gather")
    lg_s, _ = lm.prefill(params, cfg, tokens, backend=single)
    lg_h, _ = lm.prefill(params, cfg, tokens, backend=sharded)
    np.testing.assert_allclose(np.asarray(lg_s, np.float32),
                               np.asarray(lg_h, np.float32),
                               rtol=1e-5, atol=1e-5)
    # least-loaded row routing spreads one row per shard
    assert [p.num_live for p in sharded.pool.shards] == [3, 3]
    single.release()
    sharded.release()


# ---------------------------------------------------------------------------
# shard-local CoW forks
# ---------------------------------------------------------------------------

def test_fork_stays_shard_local_and_cow_isolates():
    from repro.kvcache.backend import ShardedPagedBackend

    cfg, params = _model()
    backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=64,
                                  block_size=4, decode_mode="gather")
    sid, _, _ = backend.new_seq(params, list(range(1, 11)), shard=1)
    fid = backend.fork_seq(sid)
    assert backend.shard_of(sid) == backend.shard_of(fid) == 1
    pool1 = backend.pool.shards[1]
    # fork shares every block of the parent, all inside shard 1's pool
    assert backend.table(fid).blocks == backend.table(sid).blocks
    assert all(0 <= b < pool1.cfg.num_blocks and pool1.used[b]
               for b in backend.table(fid).blocks)
    assert backend.pool.shards[0].num_live == 0
    # diverging appends CoW the shared tail within the shard; the
    # parent's payload is untouched
    cow0 = pool1.stats.cow_copies
    backend.decode(params, [sid, fid], [3, 7])
    assert pool1.stats.cow_copies > cow0
    t_s, t_f = backend.table(sid), backend.table(fid)
    assert t_s.blocks[-1] != t_f.blocks[-1]
    assert pool1.content[t_s.blocks[-1]] != pool1.content[t_f.blocks[-1]]
    backend.release()
    backend.pool.check_invariants()


# ---------------------------------------------------------------------------
# soak: admit / fork / free with reservation routing
# ---------------------------------------------------------------------------

def test_sharded_soak_admit_fork_free_invariants(tmp_path):
    """Randomized admit (route + reserve + extend), fork (CoW), and free
    over a sharded metadata pool; every shard's allocator invariants and
    the reservation accounting must hold throughout.  The soak runs fully
    instrumented: every step is a trace span wrapping the shard pools'
    alloc/evict/CoW events, the O(dirty) incremental sweep runs each
    step, and the flushed trace must reconstruct cleanly."""
    import json

    from repro.analysis import refsan
    from repro.obs import Observer

    obs = Observer(paranoid=True)
    rng = np.random.default_rng(0)
    sp = _spool(num_blocks=64, n_shards=4, block_size=4)
    san = refsan.attach(sp)             # per-shard shadow refcounts
    sp.obs = obs
    for i, p in enumerate(sp.shards):
        p.obs = obs
        p.obs_shard = i
        obs.registry.adopt(f"pool.shard{i}", p.stats)
    live = []        # (rid, shard, table)
    next_rid = 0
    def soak_step(step: int) -> None:
        nonlocal next_rid
        r = rng.random()
        if r < 0.45 and len(live) < 12:
            n_tokens = int(rng.integers(1, 20))
            n_blocks = -(-n_tokens // 4)
            if not sp.can_reserve(n_blocks):
                return
            sp.reserve(n_blocks)
            shard = sp.route(next_rid, f"page{rng.integers(4)}", n_blocks)
            if shard is None:
                sp.cancel_pending(n_blocks)   # give up instead of waiting
                return
            t = BlockTable()
            toks = [int(x) for x in rng.integers(0, 99, n_tokens)]
            t.extend(sp.shards[shard], toks, seq_tokens=toks)
            sp.unreserve(n_blocks, rid=next_rid)
            live.append((next_rid, shard, t))
            next_rid += 1
        elif r < 0.65 and live:
            rid, shard, t = live[int(rng.integers(len(live)))]
            if sp.shards[shard].num_free + sp.shards[shard].num_cached > 2:
                f = t.fork(sp.shards[shard])
                live.append((next_rid, shard, f))
                next_rid += 1
        elif live:
            rid, shard, t = live.pop(int(rng.integers(len(live))))
            for b in t.blocks:
                sp.shards[shard].decref(b)

    for step in range(300):
        with obs.trace.span("soak.step", step=step):
            soak_step(step)
        sp.check_invariants(incremental=True)   # O(dirty), every step
        if step % 25 == 0:
            sp.check_invariants()
    for rid, shard, t in live:
        for b in t.blocks:
            sp.shards[shard].decref(b)
    sp.check_invariants()
    assert sp.num_live == 0 and sp.reserved == 0
    san.check(quiesced=True)            # no leaks, no double-frees, no UAF
    san.detach()
    # the adopted per-shard counters are the live stats objects
    snap = obs.snapshot()
    for i, p in enumerate(sp.shards):
        for f in p.stats.fields():
            assert snap["counters"][f"pool.shard{i}.{f}"] == \
                getattr(p.stats, f)
    assert sum(snap["counters"][f"pool.shard{i}.allocs"]
               for i in range(sp.n_shards)) == sp.stats.allocs > 0
    # spans wrapped every pool event: 300 step spans at depth 0, every
    # other event stamped inside some step's [ts, ts+dur] window
    evs = obs.trace.events()
    steps = [e for e in evs if e["ev"] == "soak.step"]
    assert len(steps) == 300
    assert all(e["depth"] == 0 for e in steps)
    spans = [(e["ts"], e["ts"] + e["dur_us"]) for e in steps]
    for e in evs:
        if e["ev"] != "soak.step":
            assert any(lo <= e["ts"] <= hi for lo, hi in spans), e
    # flush drains the ring to parseable JSONL
    path = str(tmp_path / "soak_trace.jsonl")
    n = obs.trace.flush(path)
    assert n == len(evs) and obs.trace.events() == []
    lines = [json.loads(l) for l in open(path)]
    assert len(lines) == n
    assert sum(1 for e in lines if e["ev"] == "pool.alloc") > 0


# ---------------------------------------------------------------------------
# exhaustion isolation
# ---------------------------------------------------------------------------

def test_exhaustion_on_one_shard_rolls_back_and_spares_others():
    """A prefill that exhausts its routed shard must roll back atomically
    on that shard and leave every other shard's pool untouched."""
    from repro.kvcache.backend import ShardedPagedBackend

    cfg, params = _model()
    backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=16,
                                  block_size=4, decode_mode="gather")
    p0, p1 = backend.pool.shards
    sid1, _, _ = backend.new_seq(params, list(range(50, 60)), shard=1)
    live1 = p1.num_live
    # 8 blocks/shard; 40 tokens need 10 blocks -> shard 0 exhausts
    with pytest.raises(RuntimeError, match="pool exhausted"):
        backend.new_seq(params, list(range(1, 41)), shard=0)
    p0.check_invariants()
    p1.check_invariants()
    assert p0.num_live == 0, "failed prefill leaked blocks on its shard"
    assert p1.num_live == live1, "exhaustion leaked onto another shard"
    # shard 0 still serves a fitting sequence afterwards
    sid2, _, _ = backend.new_seq(params, list(range(1, 9)), shard=0)
    assert backend.shard_of(sid2) == 0
    backend.release()
    backend.pool.check_invariants()
    assert backend.pool.num_live == 0


def test_batch_prefill_exhaustion_rolls_back_across_shards():
    """Batch prefill is atomic across shards too: if a later shard's
    batched ``_add_seqs`` exhausts its pool, rows already prefilled on
    earlier shards must be freed before the error re-raises."""
    import jax
    import jax.numpy as jnp
    from repro.kvcache.backend import ShardedPagedBackend

    cfg, params = _model()
    backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=8,
                                  block_size=4, decode_mode="gather")
    p0, p1 = backend.pool.shards
    # occupy shard 0 with one block so the planner sends 2 of 3 rows to
    # shard 1 (3 blocks each > 4 blocks/shard -> shard 1 exhausts after
    # shard 0's row already registered)
    backend.new_seq(params, [1, 2, 3], shard=0)
    live0 = (p0.num_live, p1.num_live)
    rows = jax.random.randint(jax.random.key(0), (3, 9), 1, cfg.vocab)
    with pytest.raises(RuntimeError, match="pool exhausted"):
        backend.prefill(params, rows)
    p0.check_invariants()
    p1.check_invariants()
    assert (p0.num_live, p1.num_live) == live0, \
        "cross-shard batch prefill leaked rows on a non-failing shard"
    assert backend._batch == [] and len(backend._seqs) == 1
    # the backend still serves (protocol lanes are rebuildable)
    small = jax.random.randint(jax.random.key(1), (2, 4), 1, cfg.vocab)
    backend.prefill(params, small)
    backend.decode_step(params, jnp.ones((2, 1), jnp.int32))
    backend.release()
    backend.pool.check_invariants()


def test_make_backend_sharded_sizes_whole_lanes_per_shard():
    """The registry's capacity request must survive sharding: a lane
    never spans shards, so each shard holds ceil(batch / n_shards) whole
    lanes — splitting the aggregate block budget would under-size shards
    whenever n_shards does not divide batch."""
    import jax
    from repro.kvcache.backend import ShardedPagedBackend, make_backend
    from repro.models import lm

    cfg, params = _model()
    # 3 lanes of 5 blocks over 2 shards -> 2 lanes/shard -> 20 total
    be = make_backend(cfg, "sharded-paged", batch=3, max_seq=64, n_shards=2)
    assert isinstance(be, ShardedPagedBackend)
    assert be.pool.shard_blocks == 2 * 5 and be.pool.cfg.num_blocks == 20
    # one long lane over 4 shards: the lane's 8 blocks must fit ONE shard
    be = make_backend(cfg, "sharded-paged", batch=1, max_seq=127,
                      n_shards=4)
    assert be.pool.shard_blocks == 8
    tokens = jax.random.randint(jax.random.key(0), (1, 120), 1, cfg.vocab)
    lm.prefill(params, cfg, tokens, backend=be)   # must not exhaust
    assert list(be.lengths) == [120]
    be.release()


def test_decode_precheck_is_atomic_across_shards():
    """Exhaustion on one shard's decode must be detected before ANY shard
    commits its write-back: a caller that catches and retries must not
    double-append KV on the shards that would have gone first."""
    from repro.kvcache.backend import ShardedPagedBackend

    cfg, params = _model()
    backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=8,
                                  block_size=4, decode_mode="gather",
                                  share_prefixes=False)
    s0, _, _ = backend.new_seq(params, [1, 2, 3, 4, 5], shard=0)
    # fill shard 1 completely: two 8-token sequences = 4/4 blocks live
    s1, _, _ = backend.new_seq(params, list(range(10, 18)), shard=1)
    backend.new_seq(params, list(range(20, 28)), shard=1)
    toks0 = list(backend.table(s0).blocks), backend.table(s0).num_tokens
    # s1's lane needs a fresh tail block (fill == 0) shard 1 cannot give;
    # shard 0 sorts first and must NOT have committed when this raises
    with pytest.raises(RuntimeError, match="pool exhausted on shard 1"):
        backend.decode(params, [s0, s1], [7, 9])
    assert (list(backend.table(s0).blocks),
            backend.table(s0).num_tokens) == toks0, \
        "shard 0 committed a step the batch then aborted"
    backend.pool.check_invariants()
    # the step is retryable once shard 1 has room
    backend.free_seq(s1)
    lg = backend.decode(params, [s0], [7])
    assert lg.shape[0] == 1 and backend.table(s0).num_tokens == 6
    backend.release()


def test_route_with_zero_blocks_keeps_invariants():
    """A degenerate request (empty prompt, max_new=0) reserves 0 blocks;
    routing it must still pick a shard without planting bookkeeping that
    can never be released."""
    sp = _spool(num_blocks=8, n_shards=2)
    sp.reserve(0)
    assert sp.route(rid=7, page="zero", n=0) is not None
    assert 7 not in sp._rid_reserved
    sp.unreserve(0, rid=7)        # no-op, must not KeyError
    sp.check_invariants()


def test_batch_api_accepts_empty_batch():
    """Protocol parity: a (0, S) prefill returns empty logits like the
    dense and single-pool paged backends do."""
    from repro.kvcache.backend import ShardedPagedBackend

    cfg, params = _model()
    backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=16,
                                  block_size=4, decode_mode="gather")
    lg = backend.prefill(params, np.zeros((0, 8), np.int32))
    assert lg.shape == (0, 1, cfg.vocab)
    assert backend.lengths.shape == (0,)
    backend.release()


def test_page_affinity_map_is_bounded():
    from repro.kvcache import sharded_pool as sm

    sp = _spool(num_blocks=1024, n_shards=2)
    cap = sm.PAGE_AFFINITY_CAP
    for i in range(cap + 50):
        sp.reserve(1)
        assert sp.route(rid=i, page=f"p{i}", n=1) is not None
        sp.unreserve(1, rid=i)
    assert len(sp._page_shard) == cap
    # oldest entries were trimmed, newest survive
    assert "p0" not in sp._page_shard and f"p{cap + 49}" in sp._page_shard
    sp.check_invariants()


# ---------------------------------------------------------------------------
# engine end-to-end over shards
# ---------------------------------------------------------------------------

def test_engine_sharded_serving_matches_dense_greedy():
    """Continuous batching over a 2-shard pool must emit exactly the
    dense backend's greedy tokens — routing, per-shard decode grouping,
    claims, and lane ordering all live under this one assertion."""
    import jax.numpy as jnp
    from repro.kvcache.backend import ShardedPagedBackend
    from repro.serve.engine import PagedLM, ServeEngine
    from repro.serve.step import greedy_generate

    cfg, params = _model()
    backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=96,
                                  block_size=8, decode_mode="gather")
    eng = ServeEngine(backend.pool, MarsScheduler(pool=backend.pool),
                      PagedLM(params, cfg, backend), max_lanes=3)
    rng = np.random.default_rng(3)
    shared = tuple(int(t) for t in rng.integers(1, cfg.vocab, 16))
    prompts = [shared + tuple(int(t) for t in rng.integers(1, cfg.vocab, 2))
               for _ in range(4)]
    prompts += [tuple(int(t) for t in rng.integers(1, cfg.vocab, 18))
                for _ in range(2)]
    reqs = [Request(rid=i, prompt=p, arrival=i * 1e-3, prefix_len=8,
                    max_new=4) for i, p in enumerate(prompts)]
    out = eng.run(reqs)
    assert sorted(out) == list(range(6))
    # the shared-prefix requests co-located: their shard's prefix cache hit
    assert backend.pool.stats.prefix_hits > 0
    for i, p in enumerate(prompts):
        want = greedy_generate(params, cfg, jnp.asarray([p], jnp.int32),
                               4, max_seq=len(p) + 5)
        assert out[i][0] == list(np.asarray(want[0])), f"lane {i} diverged"
    backend.pool.check_invariants()
    assert backend.pool.num_live == 0 and backend.pool.reserved == 0


def test_batch_lane_order_keeps_shards_distinct():
    """Shard-local block ids collide numerically across shards; the lane
    order key must lead with the shard coordinate so same-id lanes on
    different shards are not treated as row-group neighbors."""
    from repro.kernels.paged_attention import ops

    t0 = BlockTable(blocks=[0], num_tokens=4)    # shard 0, group 0
    t1 = BlockTable(blocks=[1], num_tokens=4)    # shard 1, group 0
    t2 = BlockTable(blocks=[2], num_tokens=4)    # shard 0, group 0
    order = ops.batch_lane_order([t0, t1, t2], blocks_per_group=8,
                                 shard_ids=[0, 1, 0])
    grouped = [([0, 1, 0][i]) for i in order]
    # lanes of each shard end up adjacent (0s together, the 1 alone)
    assert grouped in ([0, 0, 1], [1, 0, 0])
    # without shard ids all three share group 0 -> order stays FIFO
    assert list(ops.batch_lane_order([t0, t1, t2], 8)) == [0, 1, 2]
