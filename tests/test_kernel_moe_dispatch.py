"""moe_dispatch kernel: Pallas (interpret) vs pure-jnp oracle, shape sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.moe_dispatch import ops
from repro.kernels.moe_dispatch.moe_dispatch import grouped_matmul
from repro.kernels.moe_dispatch.ref import (grouped_matmul_ref,
                                            grouped_matmul_ref_loop)


def _mk(M, K, N, G, key, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (M, K), dtype)
    w = jax.random.normal(k2, (G, K, N), dtype) / np.sqrt(K)
    # random group sizes summing to M, each a multiple of tile for kernel
    return x, w, k3


@pytest.mark.parametrize("M,K,N,G,bm", [
    (256, 128, 128, 2, 128),
    (512, 256, 128, 4, 128),
    (256, 512, 256, 8, 64),
    (128, 128, 384, 3, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_vs_ref(M, K, N, G, bm, dtype):
    x, w, k3 = _mk(M, K, N, G, jax.random.key(0), dtype)
    # aligned group boundaries (the op pads to this invariant)
    tiles = M // bm
    tg = np.sort(np.asarray(jax.random.randint(k3, (tiles,), 0, G)))
    group_sizes = np.bincount(tg, minlength=G) * bm
    out = grouped_matmul(x, w, jnp.asarray(tg, jnp.int32), bm=bm,
                         interpret=True)
    ref = grouped_matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32),
                             jnp.asarray(group_sizes))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_two_oracles_agree():
    x, w, _ = _mk(64, 32, 16, 4, jax.random.key(1))
    gs = jnp.array([10, 20, 4, 30])
    a = grouped_matmul_ref(x, w, gs)
    b = grouped_matmul_ref_loop(x, w, gs)
    np.testing.assert_allclose(np.asarray(a), b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,d,f,E,k", [
    (64, 32, 48, 4, 2),
    (128, 64, 64, 8, 2),
    (32, 128, 96, 16, 8),
])
def test_mars_moe_ffn_matches_dense(T, d, f, E, k):
    """Full op (sort + pad + grouped ffn + combine) vs dense per-token."""
    keys = jax.random.split(jax.random.key(2), 6)
    x = jax.random.normal(keys[0], (T, d))
    idx = jax.random.randint(keys[1], (T, k), 0, E)
    gates = jax.nn.softmax(jax.random.normal(keys[2], (T, k)))
    w_in = jax.random.normal(keys[3], (E, d, f)) / np.sqrt(d)
    w_gate = jax.random.normal(keys[4], (E, d, f)) / np.sqrt(d)
    w_out = jax.random.normal(keys[5], (E, f, d)) / np.sqrt(f)

    def dense(x):
        h = jnp.einsum("td,edf->tef", x, w_in)
        g = jnp.einsum("td,edf->tef", x, w_gate)
        o = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * h, w_out)
        per_tok = o[jnp.arange(T)[:, None], idx]       # (T,k,d)
        return (per_tok * gates[..., None]).sum(1)

    want = dense(x)
    for use_pallas in (False, True):
        got = ops.mars_moe_ffn(x, idx, gates, w_in, w_gate, w_out,
                               n_experts=E, use_pallas=use_pallas, bm=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_pad_sorted_groups_invariants():
    from repro.kernels.moe_dispatch.ops import pad_sorted_groups
    e = jnp.asarray(np.sort(np.random.default_rng(0).integers(0, 5, 100)),
                    jnp.int32)
    slot, tg, M_pad = pad_sorted_groups(e, None, 5, 16)
    slot = np.asarray(slot)
    assert len(np.unique(slot)) == 100          # injective
    assert slot.max() < M_pad
    tg = np.asarray(tg)
    assert (np.diff(tg) >= 0).all()             # tiles group-sorted
    # every assignment's tile maps to its own expert
    np.testing.assert_array_equal(tg[slot // 16], np.asarray(e))
