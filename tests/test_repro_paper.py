"""End-to-end reproduction checks against the paper's headline claims.

Paper (Section 4): MARS improves achieved memory bandwidth by ~11% and
CAS/ACT by ~69% on average over WL1-WL5; WL1 and WL5 improve CAS/ACT by
more than 2x.  Our synthetic streams are idealized relative to the paper's
(no cache feedback loop), so we assert the same *direction and magnitude
class*: positive BW uplift on every workload, mean BW uplift in [8%, 60%],
mean CAS/ACT uplift in [50%, 200%], and >2x CAS/ACT on WL1/WL5.
"""
import numpy as np
import pytest

from repro.core import experiment, streams

RPC = 128  # keep CI fast; benchmarks use 256


@pytest.fixture(scope="module")
def results():
    return experiment.run_all(reqs_per_core=RPC)


def test_bw_uplift_every_workload(results):
    for r in results:
        assert r.bw_uplift > 0.0, (r.name, r.bw_uplift)


def test_mean_bw_uplift_magnitude(results):
    s = experiment.summarize(results)
    assert 0.08 <= s["mean_bw_uplift"] <= 0.60, s["mean_bw_uplift"]


def test_mean_cas_act_uplift_magnitude(results):
    s = experiment.summarize(results)
    assert 0.50 <= s["mean_cas_act_uplift"] <= 2.00, s["mean_cas_act_uplift"]


def test_wl1_wl5_cas_act_over_2x(results):
    by = {r.name: r for r in results}
    assert by["WL1"].with_mars.cas_per_act >= 2.0 * by["WL1"].baseline.cas_per_act
    assert by["WL5"].with_mars.cas_per_act >= 2.0 * by["WL5"].baseline.cas_per_act


def test_locality_lost_through_merging():
    """Paper Fig 2: locality at source >> locality at GPU boundary, and
    boundary locality decreases as core count grows."""
    loc = experiment.locality_experiment(core_counts=(24, 64),
                                         reqs_per_core=256)
    w = 512
    assert loc["single_cache"][w] > 2 * loc["gpu_boundary_24cores"][w]
    assert loc["gpu_boundary_24cores"][w] > loc["gpu_boundary_64cores"][w]


def test_locality_grows_with_window():
    loc = experiment.locality_experiment(core_counts=(24,), reqs_per_core=256)
    vals = list(loc["gpu_boundary_24cores"].values())
    assert all(a <= b + 1e-9 for a, b in zip(vals, vals[1:]))
