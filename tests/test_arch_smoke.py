"""Per-architecture smoke tests: reduced same-family configs, one forward +
train step on CPU, shape and NaN checks, decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

ARCHS = configs.all_archs()


def _inputs(cfg, B=2, S=16):
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    fe = None
    if cfg.frontend:
        fe = jax.random.normal(jax.random.key(2),
                               (B, cfg.frontend_seq, cfg.d_model)) * 0.02
    return tokens, fe


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = configs.get_smoke(arch)
    params = lm.init(cfg, jax.random.key(0)).params
    tokens, fe = _inputs(cfg)
    logits, aux = lm.forward(params, cfg, tokens, fe)
    assert logits.shape == (*tokens.shape, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    if cfg.is_moe:
        assert float(aux["moe_lb"]) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = configs.get_smoke(arch)
    params = lm.init(cfg, jax.random.key(0)).params
    tokens, fe = _inputs(cfg)

    @jax.jit
    def step(p):
        (loss, _), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, cfg, tokens, tokens, fe),
            has_aux=True)(p)
        p = jax.tree.map(
            lambda w, gw: (w.astype(jnp.float32)
                           - 0.05 * gw.astype(jnp.float32)).astype(w.dtype),
            p, g)
        return loss, p

    l0, params = step(params)
    for _ in range(3):
        l1, params = step(params)
    assert not bool(jnp.isnan(l1))
    assert float(l1) < float(l0), (float(l0), float(l1))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Prefill + single-token decode must reproduce teacher-forced logits."""
    cfg = configs.get_smoke(arch)
    if cfg.family == "vlm":
        pytest.skip("vlm prefix path exercised in forward test")
    params = lm.init(cfg, jax.random.key(0)).params
    tokens, fe = _inputs(cfg, B=1, S=8)
    full, _ = lm.forward(params, cfg, tokens, fe)
    _, cache = lm.prefill(params, cfg, tokens[:, :4], max_seq=16,
                          frontend_emb=fe)
    lg = None
    for t in range(4, 8):
        lg, cache = lm.decode_step(params, cfg, tokens[:, t:t + 1], cache)
    # after feeding tokens 4..7 the step logits predict token 8 == full[:,7]
    np.testing.assert_allclose(np.asarray(lg[0, 0]),
                               np.asarray(full[0, 7]), rtol=0.05, atol=0.05)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_analytic(arch):
    cfg = configs.get_smoke(arch)
    params = lm.init(cfg, jax.random.key(0)).params
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    approx = cfg.n_params()
    assert abs(actual - approx) / actual < 0.15, (actual, approx)


def test_full_configs_param_counts():
    """Full (non-smoke) configs must land near their published sizes."""
    expect = {
        "mamba2_370m": (0.25e9, 0.6e9),
        "deepseek_coder_33b": (30e9, 36e9),
        "qwen1_5_0_5b": (0.4e9, 0.7e9),
        "starcoder2_7b": (6e9, 8.5e9),
        "phi3_medium_14b": (12e9, 16e9),
        "arctic_480b": (400e9, 560e9),
        "kimi_k2_1t_a32b": (0.85e12, 1.25e12),
        "whisper_base": (0.05e9, 0.11e9),
        "paligemma_3b": (2e9, 3.5e9),
        "hymba_1_5b": (1.0e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).n_params()
        assert lo <= n <= hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9}-{hi/1e9}]")


def test_kimi_active_params():
    cfg = configs.get("kimi_k2_1t_a32b")
    active = cfg.n_active_params()
    assert 20e9 <= active <= 45e9, f"{active/1e9:.1f}B active"
