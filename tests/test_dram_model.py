"""DRAM timing model: analytical sanity anchors."""
import numpy as np
import pytest

from repro.core import dram


def test_sequential_stream_saturates_bus():
    a = np.arange(16384, dtype=np.int32)
    r = dram.simulate(a)
    assert r.bus_utilization > 0.95
    assert r.cas_per_act > 16  # full rows reused


def test_random_stream_is_activate_bound():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << 24, 16384).astype(np.int32)
    r = dram.simulate(a)
    assert r.cas_per_act < 1.3
    # tFAW-limited ceiling: 4 ACT/40clk * 4clk data = 0.4 of peak
    assert r.bus_utilization < 0.45


@pytest.mark.parametrize("runlen", [4, 16, 64])
def test_run_length_monotonicity(runlen):
    rng = np.random.default_rng(1)
    pages = rng.integers(0, 1 << 18, 8192 // runlen).astype(np.int64)
    a = (pages[:, None] * 64 + np.arange(runlen)).reshape(-1).astype(np.int32)
    r = dram.simulate(a)
    # per-channel CA is about half the run length (channel interleave)
    assert r.cas_per_act == pytest.approx(runlen / 2, rel=0.3)


def test_longer_runs_never_slower():
    rng = np.random.default_rng(2)
    utils = []
    for runlen in (2, 8, 32):
        pages = rng.integers(0, 1 << 18, 8192 // runlen).astype(np.int64)
        a = (pages[:, None] * 64 + np.arange(runlen)).reshape(-1)
        utils.append(dram.simulate(a.astype(np.int32)).bus_utilization)
    assert utils[0] <= utils[1] <= utils[2] + 0.02


def test_write_read_turnaround_costs():
    a = np.arange(8192, dtype=np.int32)
    pure = dram.simulate(a, is_write=np.zeros(8192, bool))
    alternating = dram.simulate(a, is_write=(np.arange(8192) % 2 == 0))
    assert alternating.achieved_gbps < pure.achieved_gbps * 0.55


def test_channel_split_is_conserving():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1 << 20, 4096).astype(np.int32)
    cfg = dram.DramConfig()
    ch, local = dram.split_channels(a, cfg)
    assert len(ch) == len(a)
    assert set(np.unique(ch)) <= {0, 1}
    # map is injective: (channel, local) identifies the line
    key = ch.astype(np.int64) << 40 | local
    assert len(np.unique(key)) == len(np.unique(a))


def test_bank_hash_spreads_power_of_two_strides():
    import jax.numpy as jnp
    cfg = dram.DramConfig()
    for stride in (8, 64, 512):
        local = jnp.arange(64) * 32 * stride
        _, bank, _ = dram._decode(local, cfg)
        assert len(np.unique(np.asarray(bank))) >= 6, stride
