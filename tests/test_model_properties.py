"""Model invariants: causality, sliding windows, mask semantics, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers, lm


def test_causality_future_tokens_do_not_affect_past():
    cfg = configs.get_smoke("deepseek_coder_33b")
    params = lm.init(cfg, jax.random.key(0)).params
    t1 = jax.random.randint(jax.random.key(1), (1, 12), 0, cfg.vocab)
    t2 = t1.at[:, 8:].set((t1[:, 8:] + 7) % cfg.vocab)   # change the tail
    l1, _ = lm.forward(params, cfg, t1)
    l2, _ = lm.forward(params, cfg, t2)
    np.testing.assert_allclose(np.asarray(l1[:, :8]), np.asarray(l2[:, :8]),
                               rtol=1e-4, atol=1e-4)
    assert not np.allclose(np.asarray(l1[:, 8:]), np.asarray(l2[:, 8:]))


def test_ssm_causality():
    cfg = configs.get_smoke("mamba2_370m")
    params = lm.init(cfg, jax.random.key(0)).params
    t1 = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab)
    t2 = t1.at[:, 12:].set((t1[:, 12:] + 3) % cfg.vocab)
    l1, _ = lm.forward(params, cfg, t1)
    l2, _ = lm.forward(params, cfg, t2)
    np.testing.assert_allclose(np.asarray(l1[:, :12]),
                               np.asarray(l2[:, :12]), rtol=1e-4, atol=1e-4)


def test_sliding_window_mask():
    m = layers.causal_mask(8, 8, window=3)
    m = np.asarray(m)
    assert m[5, 5] and m[5, 4] and m[5, 3]
    assert not m[5, 2]          # outside window
    assert not m[3, 5]          # future


def test_prefix_mask_bidirectional_prefix():
    m = np.asarray(layers.causal_mask(6, 6, prefix_len=3))
    assert m[0, 2]              # prefix sees prefix (forward!)
    assert m[4, 2]              # suffix sees prefix
    assert not m[3, 4]          # suffix stays causal


def test_vlm_image_prefix_attends_bidirectionally():
    cfg = configs.get_smoke("paligemma_3b")
    params = lm.init(cfg, jax.random.key(0)).params
    tokens = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab)
    fe1 = jax.random.normal(jax.random.key(2),
                            (1, cfg.frontend_seq, cfg.d_model)) * 0.02
    fe2 = fe1.at[:, -1].add(1.0)   # perturb the LAST image patch
    l1, _ = lm.forward(params, cfg, tokens, fe1)
    l2, _ = lm.forward(params, cfg, tokens, fe2)
    # image is a bidirectional prefix: every text position changes
    assert not np.allclose(np.asarray(l1[:, 0]), np.asarray(l2[:, 0]))


def test_rope_relative_shift_invariance():
    """RoPE attention scores depend only on relative positions."""
    cfg = configs.get_smoke("phi3_medium_14b")
    q = jax.random.normal(jax.random.key(0), (1, 4, 2, 16))
    k = jax.random.normal(jax.random.key(1), (1, 4, 2, 16))

    def scores(offset):
        pos = jnp.arange(4)[None, :] + offset
        cos, sin = layers.rope_freqs(cfg, pos)
        qr = layers.apply_rope(q, cos, sin)
        kr = layers.apply_rope(k, cos, sin)
        return jnp.einsum("bqhd,bkhd->bhqk", qr, kr)

    np.testing.assert_allclose(np.asarray(scores(0)),
                               np.asarray(scores(100)), rtol=2e-3, atol=2e-3)


def test_whisper_encoder_bidirectional():
    cfg = configs.get_smoke("whisper_base")
    params = lm.init(cfg, jax.random.key(0)).params
    tokens = jax.random.randint(jax.random.key(1), (1, 6), 0, cfg.vocab)
    fe1 = jax.random.normal(jax.random.key(2),
                            (1, cfg.frontend_seq, cfg.d_model)) * 0.02
    fe2 = fe1.at[:, -1].add(1.0)   # change last audio frame
    l1, _ = lm.forward(params, cfg, tokens, fe1)
    l2, _ = lm.forward(params, cfg, tokens, fe2)
    # cross-attention: all decoder positions see all frames
    assert not np.allclose(np.asarray(l1[:, 0]), np.asarray(l2[:, 0]))


def test_mars_gather_pallas_kernel_matches_ref():
    from repro.kernels.mars_gather.mars_gather import mars_gather_pallas
    table = jax.random.normal(jax.random.key(0), (64, 128))
    ids = jax.random.randint(jax.random.key(1), (40,), 0, 64)
    out = mars_gather_pallas(table, ids, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(table[ids]))
