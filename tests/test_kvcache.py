"""KV-cache block pool: allocator invariants, prefix sharing, CoW, eviction,
placement — including the randomized alloc/share/free soak test."""
import numpy as np
import pytest

from repro.core import dram
from repro.kernels.paged_attention import ops
from repro.kvcache import BlockPool, PoolConfig
from repro.kvcache.prefix import BlockTable, PrefixCache


def _pool(n=64, bs=4, placement="mars", eviction="fifo"):
    pool = BlockPool(PoolConfig(num_blocks=n, block_size=bs,
                                placement=placement, eviction=eviction))
    cache = PrefixCache(bs)
    cache.attach(pool)
    return pool, cache


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("placement", ["mars", "naive"])
def test_alloc_free_roundtrip(placement):
    pool, _ = _pool(placement=placement)
    bids = pool.alloc(10)
    assert len(set(bids)) == 10
    assert pool.num_free == 54 and pool.num_live == 10
    pool.check_invariants()
    for b in bids:
        pool.decref(b)
    assert pool.num_free == 64 and pool.num_live == 0
    pool.check_invariants()


def test_pool_exhaustion_raises():
    pool, _ = _pool(n=8)
    pool.alloc(8)
    assert not pool.can_alloc(1)
    with pytest.raises(RuntimeError):
        pool.alloc(1)
    assert pool.stats.alloc_fails == 1


def test_refcount_sharing_exact():
    pool, cache = _pool()
    t1 = BlockTable()
    prompt = list(range(10))           # 2 full blocks + a partial tail
    t1.extend(pool, prompt, seq_tokens=prompt, cache=cache)
    t2 = BlockTable(*_match_into(cache, prompt + [99], pool))
    t2.extend(pool, (prompt + [99])[t2.num_tokens:],
              seq_tokens=prompt + [99], cache=cache)
    # first two full blocks shared, tails private
    assert t2.blocks[:2] == t1.blocks[:2]
    assert pool.refcount[t1.blocks[0]] == 2
    assert pool.refcount[t1.blocks[-1]] == 1
    cache.release(t2, pool)
    assert pool.refcount[t1.blocks[0]] == 1
    pool.check_invariants()


def _match_into(cache, prompt, pool):
    bids, n = cache.match(prompt, pool)
    return list(bids), n


def test_cow_preserves_shared_block():
    pool, cache = _pool()
    t1 = BlockTable()
    toks = [1, 2, 3, 4, 5, 6]          # partial tail (2/4)
    t1.extend(pool, toks, seq_tokens=toks, cache=cache)
    t2 = t1.fork(pool)
    tail = t1.blocks[-1]
    before = pool.content[tail]
    t2.extend(pool, [7], seq_tokens=toks + [7], cache=cache)
    assert pool.content[tail] == before, "CoW mutated a shared block"
    assert t2.blocks[-1] != tail
    assert pool.content[t2.blocks[-1]] == (5, 6, 7)
    assert pool.refcount[tail] == 1
    pool.check_invariants()


# ---------------------------------------------------------------------------
# eviction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eviction", ["fifo", "lru"])
def test_eviction_reclaims_cached_blocks(eviction):
    pool, cache = _pool(n=8, eviction=eviction)
    tables = []
    for i in range(2):                 # two cached 4-token prompts
        toks = list(range(10 * i, 10 * i + 5))
        t = BlockTable()
        t.extend(pool, toks, seq_tokens=toks, cache=cache)
        tables.append((t, toks))
    for t, _ in tables:
        cache.release(t, pool)
    assert pool.num_cached == 2 and len(cache) == 2
    pool.alloc(pool.num_free + 1)      # force one eviction
    assert pool.stats.evictions == 1 and len(cache) == 1
    pool.check_invariants()


def test_fifo_vs_lru_pick_different_victims():
    # block A allocated first but used recently; B allocated later, idle.
    results = {}
    for eviction in ("fifo", "lru"):
        pool, cache = _pool(n=8, eviction=eviction)
        ta, tb = BlockTable(), BlockTable()
        ta.extend(pool, [1, 2, 3, 4], seq_tokens=[1, 2, 3, 4], cache=cache)
        tb.extend(pool, [5, 6, 7, 8], seq_tokens=[5, 6, 7, 8], cache=cache)
        a0, b0 = ta.blocks[0], tb.blocks[0]
        pool.touch(a0)                 # A recently used
        cache.release(ta, pool)
        cache.release(tb, pool)
        pool.alloc(pool.num_free + 1)
        survivors = list(pool._evictable)
        assert len(survivors) == 1
        results[eviction] = survivors[0]
        pool.check_invariants()
    # FIFO evicts the first-allocated block A; LRU evicts the idle block B
    assert results["fifo"] != results["lru"]


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def _churn(placement, seed=0, n=256, n_live=12):
    rng = np.random.default_rng(seed)
    pool, _ = _pool(n=n, placement=placement)
    live = []
    for _ in range(300):
        if live and (len(live) >= n_live or rng.random() < 0.5):
            t = live.pop(int(rng.integers(len(live))))
            for b in t.blocks:
                pool.decref(b)
        else:
            t = BlockTable()
            for _ in range(int(rng.integers(2, 8))):
                t.blocks.append(pool.alloc(1, hint_blocks=t.blocks)[0])
            t.num_tokens = len(t.blocks) * pool.cfg.block_size
            live.append(t)
    while len(live) < n_live:
        t = BlockTable()
        for _ in range(int(rng.integers(2, 8))):
            t.blocks.append(pool.alloc(1, hint_blocks=t.blocks)[0])
        live.append(t)
    pool.check_invariants()
    return pool, live


def test_mars_placement_clusters_row_groups():
    spread = {}
    for placement in ("mars", "naive"):
        pool, live = _churn(placement)
        spread[placement] = np.mean([
            len(pool.placement.groups_of(t.blocks)) / len(t.blocks)
            for t in live])
    assert spread["mars"] < spread["naive"]


def test_mars_placement_bandwidth_at_least_naive():
    """Acceptance: MARS-placed >= naive-placed achieved bandwidth through
    the DRAM model (seed-averaged decode-batch gather)."""
    gbps = {"mars": [], "naive": []}
    for seed in (0, 1):
        for placement in gbps:
            _, live = _churn(placement, seed=seed)
            trace = ops.kv_read_trace(live, grant_beats=2)
            gbps[placement].append(dram.simulate(trace).achieved_gbps)
    assert np.mean(gbps["mars"]) >= np.mean(gbps["naive"])


def test_kernel_path_row_hits_at_least_gather():
    """Acceptance: the Pallas kernel's sequence-major page walk must hit
    the row buffer at least as often as the gather path's round-robin
    lane interleave — on both placements — and at least match its
    bandwidth (MARS placement finally reaching the kernel unflattened)."""
    import benchmarks.kvcache_bench as kb
    for placement in ("naive", "mars"):
        res = kb.decode_path_comparison(placement=placement)
        assert kb.row_hit_rate(res["kernel"]) >= \
            kb.row_hit_rate(res["gather"]), placement
        assert res["kernel"].achieved_gbps >= \
            res["gather"].achieved_gbps * 0.99, placement
    # sliding-window config: the kernel's window page gate shortens its
    # walk (out-of-window pages never fetched) while the gather path
    # still reads the whole table — the ordering must hold there too
    res = kb.decode_path_comparison(placement="mars", window_tokens=64)
    assert kb.row_hit_rate(res["kernel"]) >= kb.row_hit_rate(res["gather"])
    full = kb.decode_path_comparison(placement="mars")
    assert res["kernel"].n_requests < full["kernel"].n_requests, \
        "window page gate did not shorten the kernel's address stream"
    assert res["gather"].n_requests == full["gather"].n_requests


def test_sharded_placement_row_hits_at_least_single_pool():
    """Acceptance (PR 5): routing streams to per-shard memory devices
    before row-group packing must not lose locality — shard-routed MARS
    row-hit >= single-pool MARS >= naive, on the same churn schedule."""
    import benchmarks.kvcache_bench as kb
    for n_shards in (2, 4):
        res = kb.sharded_placement_comparison(n_shards=n_shards)
        sharded = kb.row_hit_rate(res["sharded/mars"])
        single = kb.row_hit_rate(res["single/mars"])
        naive = kb.row_hit_rate(res["single/naive"])
        assert sharded >= single >= naive, (n_shards, sharded, single, naive)
        # every shard served a non-empty slice of the decode batch
        assert len(res["sharded/mars"].per_shard) == n_shards
        # the same lanes were served either way: the sharded churn replays
        # the identical rng schedule, so the per-device traces exactly
        # partition the single device's request stream
        assert res["sharded/mars"].n_requests == \
            res["single/mars"].n_requests


def test_read_traces_accept_empty_batches():
    """A zero-sequence decode batch from an idle engine step must flow
    through trace -> reorder -> DRAM model without crashing (mirrors the
    PR-1 mars_reorder empty-input fix)."""
    from repro.core.reorder import mars_order
    from repro.core.streams import PAGE_SHIFT
    from repro.kvcache.prefix import BlockTable

    for tables in ([], [BlockTable([], 0)]):
        for trace_fn in (ops.kv_read_trace, ops.kv_read_trace_kernel):
            trace = trace_fn(tables)
            assert trace.shape == (0,) and trace.dtype == np.int32
            perm = np.asarray(mars_order(
                np.asarray(trace, np.int64) >> PAGE_SHIFT))
            assert perm.shape == (0,)
            res = dram.simulate(np.asarray(trace)[perm])
            assert res.n_requests == 0 and res.achieved_gbps == 0.0
    # empty lanes drop out of a mixed batch instead of poisoning it
    mixed = [BlockTable([], 0), BlockTable([3, 7], 30)]
    assert len(ops.kv_read_trace(mixed)) == 2 * 64
    assert len(ops.kv_read_trace_kernel(mixed)) == 2 * 64


def test_pool_page_tables_lane_padding():
    from repro.kvcache.prefix import BlockTable
    pt, ln = ops.pool_page_tables(
        [BlockTable([5, 2], 20), BlockTable([9], 4)],
        pad_to=4, pad_lanes=4)
    assert pt.shape == (4, 4) and ln.shape == (4,)
    assert list(pt[0][:2]) == [5, 2] and pt[1][0] == 9
    assert list(ln) == [20, 4, 0, 0]     # padded lanes are length-0
    # no tables at all: still a well-formed (possibly 0-lane) operand
    pt0, ln0 = ops.pool_page_tables([])
    assert pt0.shape == (0, 1) and ln0.shape == (0,)


# ---------------------------------------------------------------------------
# randomized alloc/share/free soak
# ---------------------------------------------------------------------------

def test_soak_invariants():
    """No leak, no double-free, exact refcounts, CoW never mutates a shared
    block, under randomized start/extend/fork/finish traffic."""
    from repro.analysis import refsan

    rng = np.random.default_rng(7)
    pool, cache = _pool(n=96, bs=4)
    san = refsan.attach(pool)           # shadow refcounts with provenance
    vocab = 30                          # small vocab -> heavy prefix reuse
    live: list[tuple[BlockTable, list]] = []
    shared_snapshots: dict[int, tuple] = {}

    def snapshot_shared():
        for bid in range(pool.cfg.num_blocks):
            if pool.refcount[bid] > 1:
                if bid in shared_snapshots:
                    assert pool.content[bid] == shared_snapshots[bid], \
                        f"shared block {bid} mutated"
                else:
                    shared_snapshots[bid] = pool.content[bid]
            else:
                shared_snapshots.pop(bid, None)

    def expected_refcounts():
        exp = np.zeros(pool.cfg.num_blocks, np.int32)
        for t, _ in live:
            for b in t.blocks:
                exp[b] += 1
        return exp

    for step in range(400):
        op = rng.random()
        if op < 0.35 and pool.can_alloc(6):
            toks = rng.integers(1, vocab, int(rng.integers(3, 14))).tolist()
            bids, n = cache.match(toks, pool)
            t = BlockTable(list(bids), n)
            try:
                t.extend(pool, toks[n:], seq_tokens=toks, cache=cache)
            except RuntimeError:        # pool momentarily full: roll back
                cache.release(t, pool)
                continue
            live.append((t, toks))
        elif op < 0.55 and live:
            t, toks = live[int(rng.integers(len(live)))]
            new = rng.integers(1, vocab, int(rng.integers(1, 4))).tolist()
            pre = t.num_tokens
            try:
                t.extend(pool, new, seq_tokens=toks + new, cache=cache)
                toks.extend(new)
            except RuntimeError:        # partial extension: resync tokens
                toks.extend(new[:t.num_tokens - pre])
        elif op < 0.7 and live:
            t, toks = live[int(rng.integers(len(live)))]
            live.append((t.fork(pool), list(toks)))
        elif live:
            t, _ = live.pop(int(rng.integers(len(live))))
            cache.release(t, pool)
        snapshot_shared()
        np.testing.assert_array_equal(pool.refcount, expected_refcounts())
        if step % 25 == 0:
            pool.check_invariants()

    for t, _ in live:
        cache.release(t, pool)
    pool.check_invariants()
    assert pool.num_live == 0
    assert pool.num_free + pool.num_cached == pool.cfg.num_blocks
    san.check(quiesced=True)            # no leaks, no double-frees, no UAF
    san.detach()
    # drain the cached set too: every block must come back
    pool.alloc(pool.cfg.num_blocks)
    assert pool.num_cached == 0 and len(cache) == 0
    pool.check_invariants()


# ---------------------------------------------------------------------------
# eviction under skewed prefix popularity (benchmarks/kvcache_bench)
# ---------------------------------------------------------------------------

def test_lru_beats_fifo_under_zipf_skew():
    """Hot prefixes are old prefixes: FIFO evicts them by arrival, LRU
    keeps them resident — the hit-rate gap is the point of the bench."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.kvcache_bench import eviction_comparison

    rates = eviction_comparison(zipf_a=1.3, n_requests=200, seed=0)
    assert 0.0 < rates["fifo"] <= 1.0 and 0.0 < rates["lru"] <= 1.0
    assert rates["lru"] >= rates["fifo"], rates
