"""fp8 KV-cache storage (§Perf B1): decode must stay numerically sane."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm


@pytest.mark.parametrize("arch", ["qwen1_5_0_5b", "deepseek_coder_33b"])
def test_fp8_kv_cache_decode_close_to_bf16(arch):
    cfg = configs.get_smoke(arch)
    cfg8 = dataclasses.replace(cfg, kv_dtype="float8_e4m3fn")
    params = lm.init(cfg, jax.random.key(0)).params
    tokens = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab)

    outs = {}
    for c in (cfg, cfg8):
        _, cache = lm.prefill(params, c, tokens[:, :4], max_seq=16)
        assert cache.k.dtype == c.kvdtype
        lg = None
        for t in range(4, 8):
            lg, cache = lm.decode_step(params, c, tokens[:, t:t + 1], cache)
        outs[c.kv_dtype] = np.asarray(lg[0, 0], np.float32)

    a, b = outs[""], outs["float8_e4m3fn"]
    # fp8 storage perturbs logits slightly; ranking of the top token should
    # survive whenever it is determined by more than the quantization
    # noise (random smoke weights can leave the top two in a near-tie)
    margin = np.sort(a)[-1] - np.sort(a)[-2]
    if margin > 2 * np.abs(a - b).max():
        assert np.argmax(a) == np.argmax(b)
    else:
        assert np.argmax(b) in np.argsort(a)[-2:]
    np.testing.assert_allclose(a, b, rtol=0.35, atol=0.35)


def test_fp8_cache_is_half_the_bytes():
    cfg = configs.get_smoke("qwen1_5_0_5b")
    cfg8 = dataclasses.replace(cfg, kv_dtype="float8_e4m3fn")
    c16 = lm.init_cache(cfg, batch=2, max_seq=32)
    c8 = lm.init_cache(cfg8, batch=2, max_seq=32)
    assert c8.cache.k.nbytes * 2 == c16.cache.k.nbytes
