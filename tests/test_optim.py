"""Optimizers: convergence on a quadratic, state dtype/shape contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw as optim


def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 1.5]),
            "b": {"x": jnp.asarray([[1.0, -1.0]], jnp.bfloat16)}}


def _loss(p):
    return (jnp.sum(p["w"] ** 2)
            + jnp.sum(p["b"]["x"].astype(jnp.float32) ** 2))


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_converges(kind):
    cfg = optim.OptConfig(kind=kind, lr=0.1, weight_decay=0.0,
                          warmup_steps=1, total_steps=200)
    params = _quadratic_params()
    state = optim.opt_init(params, cfg)
    l0 = float(_loss(params))
    for _ in range(150):
        g = jax.grad(_loss)(params)
        params, state, m = optim.opt_update(g, state, params, cfg)
    assert float(_loss(params)) < 0.05 * l0
    assert m["grad_norm"] >= 0


def test_adamw_bf16_params_keep_fp32_master():
    cfg = optim.OptConfig(kind="adamw", lr=0.05, weight_decay=0.0,
                          warmup_steps=1, total_steps=100)
    params = {"x": jnp.full((4,), 1.0, jnp.bfloat16)}
    state = optim.opt_init(params, cfg)
    assert state.master["x"].dtype == jnp.float32
    # tiny updates must accumulate in the master copy, not vanish in bf16
    for _ in range(20):
        g = {"x": jnp.full((4,), 1e-3, jnp.float32)}
        params, state, _ = optim.opt_update(g, state, params, cfg)
    assert float(jnp.abs(state.master["x"] - 1.0).max()) > 0


def test_adafactor_factors_large_matrices():
    cfg = optim.OptConfig(kind="adafactor", factored_min=128)
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 8))}
    st = optim.opt_init(params, cfg)
    assert st.vr["big"].shape == (256,)
    assert st.vc["big"].shape == (512,)
    assert st.vr["small"].shape == (4, 8)     # unfactored
    assert st.vc["small"].shape == (1,)       # dummy
    # memory: factored state is tiny vs AdamW's 2 full moments
    fact = st.vr["big"].size + st.vc["big"].size
    assert fact < 256 * 512 // 64


def test_grad_clip_engages():
    cfg = optim.OptConfig(kind="adamw", lr=1e-3, grad_clip=1.0,
                          warmup_steps=1)
    params = {"x": jnp.zeros((3,))}
    st = optim.opt_init(params, cfg)
    g = {"x": jnp.asarray([100.0, 0.0, 0.0])}
    p1, _, m = optim.opt_update(g, st, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(100.0)
    # effective update bounded as if |g| == 1
    assert float(jnp.abs(p1["x"]).max()) < 1e-2


def test_schedule_warmup_and_decay():
    cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(optim.schedule(cfg, 1)) < float(optim.schedule(cfg, 10))
    assert float(optim.schedule(cfg, 10)) == pytest.approx(1.0, rel=1e-3)
    assert float(optim.schedule(cfg, 100)) < 0.2
