"""Tiered KV memory: demote-on-evict / promote-on-miss round-trips,
MARS-reordered promotion batches, cost-aware eviction, the evict-while-
dirty staging regression (plain + sharded), tier-probe shard routing,
obs wiring, and end-to-end tiered serving parity under forced spill."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property test skips below; the rest collects
    given = settings = st = None

from repro.kvcache import BlockPool, BlockTable, PoolConfig, PrefixCache, \
    ShardedBlockPool, TierManager, TierSpec, row_group_of
from repro.kvcache.evict import EvictionPolicy
from repro.kvcache.tiers import promotion_order
from repro.serving.scheduler import MarsScheduler, Request


def _tiered_pool(num_blocks=8, block_size=4, specs=None, *, kv=True, **kw):
    """(pool, cache, tiers) with KV buffers unless ``kv=False``."""
    cfg = PoolConfig(num_blocks=num_blocks, block_size=block_size,
                     **(dict(n_kv_heads=1, head_dim=2) if kv else {}), **kw)
    pool = BlockPool(cfg)
    cache = PrefixCache(block_size)
    cache.attach(pool)
    return pool, cache, TierManager(pool, cache, specs)


def _seq(pool, cache, tokens, kv=None):
    """Prefill a sequence's block table, registering full blocks."""
    t = BlockTable()
    t.extend(pool, tokens, seq_tokens=tokens, cache=cache, kv=kv)
    return t


# ---------------------------------------------------------------------------
# demotion
# ---------------------------------------------------------------------------

def test_demote_on_evict_captures_payload():
    pool, cache, tiers = _tiered_pool(num_blocks=4)
    t = _seq(pool, cache, [1, 2, 3, 4, 5, 6, 7, 8])
    blk = np.full((pool.cfg.n_layers, pool.cfg.block_size,
                   pool.cfg.n_kv_heads, pool.cfg.head_dim), 7.5)
    pool.write_kv(t.blocks[0], 0, blk, blk)     # payload + pending staging
    k0 = np.array(pool.k_pages[:, t.blocks[0]])
    bid0 = t.blocks[0]
    cache.release(t, pool)
    grab = pool.alloc(4)                        # pressure: demote both
    assert tiers.stats.demotes == 2
    assert pool.num_cached == 0
    # an evicted id must not linger in the dirty set (satellite bugfix:
    # the drain consumer would re-scatter a dead slot after reuse)
    assert bid0 not in pool.dirty
    e = tiers.tiers[0].get((1, 2, 3, 4))
    assert e is not None and e.content == (1, 2, 3, 4)
    np.testing.assert_array_equal(e.k, k0)      # freshest payload captured
    assert tiers.tiers[0].holds((1, 2, 3, 4, 5, 6, 7, 8))
    for b in grab:
        pool.decref(b)
    tiers.check()
    pool.check_invariants()


def test_unregistered_blocks_evict_without_demotion():
    pool, cache, tiers = _tiered_pool(num_blocks=4)
    t = BlockTable()
    t.extend(pool, [1, 2, 3], seq_tokens=[1, 2, 3])    # no cache: private
    for b in t.blocks:
        pool.decref(b, cache=True)
    pool.alloc(4)
    assert tiers.stats.demotes == 0 and len(tiers.tiers[0]) == 0


def test_tier_overflow_cascades_then_drops():
    specs = (TierSpec("host", 2), TierSpec("remote", 2))
    pool, cache, tiers = _tiered_pool(num_blocks=4, specs=specs)
    for i in range(6):
        t = _seq(pool, cache, [10 * i + 1, 10 * i + 2, 10 * i + 3,
                               10 * i + 4, 99])
        cache.release(t, pool)
        grab = pool.alloc(pool.num_free + pool.num_cached)
        for b in grab:
            pool.decref(b)
    assert tiers.stats.demotes == 6
    assert len(tiers.tiers[0]) == 2 and len(tiers.tiers[1]) == 2
    assert tiers.stats.drops == 2               # oldest fell off the end
    # newest demotions sit in the top tier, next-newest below
    assert tiers.tiers[0].holds((51, 52, 53, 54))
    assert tiers.tiers[1].holds((31, 32, 33, 34))
    tiers.check()


# ---------------------------------------------------------------------------
# promotion
# ---------------------------------------------------------------------------

def test_promote_on_miss_is_bitwise_roundtrip():
    from repro.analysis import refsan

    pool, cache, tiers = _tiered_pool(num_blocks=6)
    san = refsan.attach(pool)           # demote/promote path under sanitizer
    tokens = [1, 2, 3, 4, 5, 6, 7, 8, 9]
    rng = np.random.default_rng(0)
    kv = (rng.standard_normal((1, 9, 1, 2), np.float32),
          rng.standard_normal((1, 9, 1, 2), np.float32))
    t = _seq(pool, cache, tokens, kv=kv)
    k_before = np.array(pool.k_pages[:, t.blocks[:2]])
    v_before = np.array(pool.v_pages[:, t.blocks[:2]])
    cache.release(t, pool)
    grab = pool.alloc(6)                        # demote the two full blocks
    for b in grab:
        pool.decref(b)
    assert tiers.stats.demotes == 2
    bids, n = tiers.match(tokens)
    assert n == 8 and len(bids) == 2            # both promoted from tier
    assert tiers.pending == 2
    dsts = tiers.flush_promotions()
    assert sorted(dsts) == sorted(bids)
    np.testing.assert_array_equal(pool.k_pages[:, bids], k_before)
    np.testing.assert_array_equal(pool.v_pages[:, bids], v_before)
    # promoted blocks are dirty (the staged mirror re-uploads them) and
    # re-registered (a second match hits the pool, not the tier)
    assert set(bids) <= pool.dirty
    assert cache.is_registered(bids[0]) and cache.is_registered(bids[1])
    promotes = tiers.stats.promotes
    bids2, n2 = tiers.match(tokens)
    assert n2 == 8 and tiers.pending == 0 and tiers.stats.promotes == promotes
    assert tiers.stats.promoted_tokens == 8
    tiers.check()
    pool.check_invariants()
    san.check()                         # no double-frees / UAF on the path
    san.detach()


def test_promotion_dedup_within_one_batch():
    pool, cache, tiers = _tiered_pool(num_blocks=6)
    t = _seq(pool, cache, [1, 2, 3, 4, 5])
    cache.release(t, pool)
    grab = pool.alloc(6)
    for b in grab:
        pool.decref(b)
    bids_a, na = tiers.match([1, 2, 3, 4, 6])
    bids_b, nb = tiers.match([1, 2, 3, 4, 7])   # same pending key
    assert na == nb == 4 and bids_a == bids_b
    assert tiers.pending == 1, "second row must reference, not re-promote"
    assert pool.refcount[bids_a[0]] == 2
    tiers.flush_promotions()
    assert tiers.stats.promotes == 1
    tiers.check()


def test_inclusive_tier_makes_reeviction_a_clean_drop():
    pool, cache, tiers = _tiered_pool(num_blocks=4)
    t = _seq(pool, cache, [1, 2, 3, 4, 5])
    cache.release(t, pool)
    grab = pool.alloc(4)
    for b in grab:
        pool.decref(b)
    bids, _ = tiers.match([1, 2, 3, 4, 9])
    tiers.flush_promotions()
    pool.decref(bids[0], cache=True)            # release the promoted block
    demotes = tiers.stats.demotes
    pool.alloc(4)                               # evict it again
    assert tiers.stats.demotes == demotes, "tier copy was clean"
    assert tiers.stats.clean_drops == 1
    assert tiers.tiers[0].holds((1, 2, 3, 4))   # entry survived
    tiers.check()


def test_match_stops_cleanly_on_pool_exhaustion():
    pool, cache, tiers = _tiered_pool(num_blocks=4)
    t = _seq(pool, cache, list(range(1, 17)))   # 4 full blocks
    cache.release(t, pool)
    grab = pool.alloc(4)                        # demote all four
    assert tiers.stats.demotes == 4
    # keep 3 blocks held: only 1 destination available for 4 promotions
    pool.decref(grab[0])
    bids, n = tiers.match(list(range(1, 17)) + [99])
    assert n == 4 and len(bids) == 1 and tiers.pending == 1
    tiers.flush_promotions()
    tiers.check()
    pool.check_invariants()


def test_cancel_promotions_rolls_back_clean():
    pool, cache, tiers = _tiered_pool(num_blocks=6)
    t = _seq(pool, cache, [1, 2, 3, 4, 5])
    cache.release(t, pool)
    grab = pool.alloc(6)
    for b in grab:
        pool.decref(b)
    bids, n = tiers.match([1, 2, 3, 4, 6])
    assert tiers.pending == 1
    tiers.cancel_promotions()                   # rollback path
    assert tiers.pending == 0
    pool.decref(bids[0])                        # caller's table rollback
    assert tiers.tiers[0].holds((1, 2, 3, 4)), "tier entry never removed"
    # the same prefix still promotes on the next (successful) batch
    bids2, n2 = tiers.match([1, 2, 3, 4, 7])
    assert n2 == 4
    tiers.flush_promotions()
    tiers.check()
    pool.check_invariants()


# ---------------------------------------------------------------------------
# MARS promotion reorder
# ---------------------------------------------------------------------------

def test_promotion_order_matches_core_mars_order():
    """``promotion_order`` must be the numpy rendering of the jax
    ``core.reorder.mars_order`` (first-arrival row groups, FIFO within)."""
    from repro.core.reorder import mars_order
    rng = np.random.default_rng(1)
    for n in (1, 7, 32):
        groups = rng.integers(0, 5, n)
        want = list(np.asarray(mars_order(groups, num_pages=5, window=n)))
        assert promotion_order([int(g) for g in groups]) == want


def test_flush_groups_by_destination_row_group():
    pool, cache, tiers = _tiered_pool(num_blocks=32, block_size=2,
                                      kv=False)
    prompts = []
    for i in range(8):
        p = [100 * i + 1, 100 * i + 2, 9]
        t = _seq(pool, cache, p)
        cache.release(t, pool)
        prompts.append(p)
    grab = pool.alloc(pool.num_free + pool.num_cached)
    for b in grab:
        pool.decref(b)
    # scatter the free list so destinations interleave row groups
    grab = pool.alloc(32)
    rng = np.random.default_rng(2)
    for i in rng.permutation(32)[:16]:
        pool.decref(grab[i])
    for p in prompts:
        tiers.match(p)
    dsts = tiers.flush_promotions()
    bpg = pool.cfg.blocks_per_group
    groups = [row_group_of(d, bpg) for d in dsts]
    # copy order visits each destination row group exactly once
    switches = sum(1 for a, b in zip(groups, groups[1:]) if a != b)
    assert switches == len(set(groups)) - 1, \
        f"promotion batch not group-coherent: {groups}"
    tiers.check()


def test_write_trace_interleaves_bounded_queue():
    from repro.kvcache.pool import LINES_PER_BLOCK
    tr = TierManager.write_trace([3, 9], chunk_lines=8, queue_depth=4)
    assert len(tr) == 2 * LINES_PER_BLOCK
    # both descriptors in flight: chunks alternate between the blocks
    assert tr[0] == 3 * LINES_PER_BLOCK
    assert tr[8] == 9 * LINES_PER_BLOCK
    assert tr[16] == 3 * LINES_PER_BLOCK + 8
    assert len(np.unique(tr)) == len(tr)
    assert len(TierManager.write_trace([])) == 0


# ---------------------------------------------------------------------------
# cost-aware eviction
# ---------------------------------------------------------------------------

def test_cost_policy_requires_mode_and_hook():
    with pytest.raises(ValueError, match="unknown eviction mode"):
        EvictionPolicy("bogus")
    pool, cache, tiers = _tiered_pool(num_blocks=4, eviction="cost")
    assert pool.eviction.cost_fn == tiers.evict_cost


def test_cost_eviction_beats_lru_on_recurring_deep_prefixes():
    """Recurring deep prefix chains + a sliding shallow window over a
    pool (and tier) below the working set: cost mode ranks victims by
    re-acquisition cost and protects the chains LRU throws away.  Reuses
    the deterministic bench workload so the gated bench row and this
    test can only move together."""
    from benchmarks.kvcache_bench import tiered_eviction_comparison
    out = tiered_eviction_comparison(rounds=12)
    assert out["cost"]["reuse"] > out["lru"]["reuse"] + 0.2, out
    assert out["cost"]["recompute_tokens"] < out["lru"]["recompute_tokens"]
    assert out["cost"]["drops"] < out["lru"]["drops"]


def test_evict_cost_tiers_full_scales_with_depth():
    specs = (TierSpec("host", 1),)
    pool, cache, tiers = _tiered_pool(num_blocks=8, specs=specs)
    t = _seq(pool, cache, list(range(1, 9)) + [99])
    shallow_bid, deep_bid = t.blocks[0], t.blocks[1]
    assert tiers.evict_cost(t.blocks[2]) == 0.0       # unregistered tail
    fetch = tiers.evict_cost(shallow_bid)
    assert 0 < fetch < 100                            # refetchable: cheap
    # fill the tier: costs switch to causal recompute, deeper = dearer
    t2 = _seq(pool, cache, [301, 302, 303, 304, 99])
    cache.release(t2, pool)
    pool.alloc(pool.num_free + 1)               # evict + demote t2's block
    assert len(tiers.tiers[0]) == 1
    c_shallow = tiers.evict_cost(shallow_bid)
    c_deep = tiers.evict_cost(deep_bid)
    assert c_deep > c_shallow > fetch
    cache.release(t, pool)


# ---------------------------------------------------------------------------
# evict-while-dirty staging regression (plain + sharded backends)
# ---------------------------------------------------------------------------

def _model(arch="qwen1_5_0_5b"):
    import jax
    from repro import configs
    from repro.models import lm
    cfg = configs.get_smoke(arch)
    return cfg, lm.init(cfg, jax.random.key(0)).params


def test_evicted_dirty_block_never_restaged_plain():
    """A block evicted while still in ``pool.dirty`` must not be
    re-scattered into the staged device mirror after its slot is reused
    — the mirror must converge to the host pool regardless."""
    from repro.kvcache.backend import PagedBackend
    cfg, params = _model()
    backend = PagedBackend(cfg, num_blocks=8, block_size=4,
                           decode_mode="gather", tiered=True)
    pool = backend.pool
    sid, _, _ = backend.new_seq(params, list(range(1, 10)))   # 3 blocks
    # blocks are dirty (never decoded -> never drained) when the free
    # below evicts them under the next prefill's pressure
    assert len(pool.dirty) > 0
    backend.free_seq(sid)
    sid2, _, _ = backend.new_seq(params, list(range(20, 48)))  # 7 blocks
    assert backend.tiers.stats.demotes > 0
    assert all(pool.used[b] for b in pool.dirty), \
        "freed block id lingering in pool.dirty"
    backend.decode(params, [sid2], [3])
    backend._staged_pages()                     # drain the decode's tail
    np.testing.assert_array_equal(np.asarray(backend._k_dev), pool.k_pages)
    backend.release()
    pool.check_invariants()


def test_evicted_dirty_block_never_restaged_sharded():
    from repro.kvcache.backend import ShardedPagedBackend
    cfg, params = _model()
    backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=16,
                                  block_size=4, decode_mode="gather",
                                  tiered=True)
    sid, _, _ = backend.new_seq(params, list(range(1, 10)), shard=0)
    p0 = backend.pool.shards[0]
    assert len(p0.dirty) > 0
    backend.free_seq(sid)
    sid2, _, _ = backend.new_seq(params, list(range(20, 48)), shard=0)
    assert backend.backends[0].tiers.stats.demotes > 0
    for p in backend.pool.shards:
        assert all(p.used[b] for b in p.dirty), \
            "freed block id lingering in a shard's dirty set"
    backend.decode(params, [sid2], [3])
    backend.backends[0]._staged_pages()         # drain the decode's tail
    np.testing.assert_array_equal(np.asarray(backend.backends[0]._k_dev),
                                  p0.k_pages)
    backend.release()
    backend.pool.check_invariants()


def test_backend_rollback_cancels_pending_promotions():
    """Prefill exhaustion with promotions queued must cancel the queue
    and leave pool + tiers consistent (nothing flushed into freed
    slots)."""
    from repro.kvcache.backend import PagedBackend
    cfg, params = _model()
    backend = PagedBackend(cfg, num_blocks=8, block_size=4,
                           decode_mode="gather", tiered=True)
    pool, tiers = backend.pool, backend.tiers
    sid, _, _ = backend.new_seq(params, list(range(1, 10)))
    backend.free_seq(sid)
    grab = pool.alloc(pool.num_free + pool.num_cached)   # demote prefix
    assert tiers.stats.demotes > 0
    for b in grab[:-6]:                                  # leave 2 free
        pool.decref(b)
    # prompt re-promotes 2 blocks then exhausts mid-prefill
    with pytest.raises(RuntimeError, match="pool exhausted"):
        backend.new_seq(params, list(range(1, 10)) + list(range(50, 80)))
    assert tiers.pending == 0, "rollback left promotions queued"
    tiers.check()
    pool.check_invariants()
    backend.release()


# ---------------------------------------------------------------------------
# sharded routing (tier probe)
# ---------------------------------------------------------------------------

def test_route_prefers_tier_hint_over_load():
    sp = ShardedBlockPool(PoolConfig(num_blocks=16, block_size=4),
                          n_shards=2)
    sp.reserve(2)
    # least-loaded would pick shard 0; the tier hint overrides
    assert sp.route(rid=0, page="a", n=2, tier_hint=1) == 1
    # a full hint shard falls back to load routing
    sp.reserve(8)
    assert sp.route(rid=1, page="b", n=8, tier_hint=1) == 0
    sp.unreserve(2, rid=0)
    sp.unreserve(8, rid=1)
    sp.check_invariants()


def test_tier_shard_for_and_scheduler_probe():
    from repro.kvcache.backend import ShardedPagedBackend
    cfg, params = _model()
    backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=16,
                                  block_size=4, decode_mode="gather",
                                  tiered=True)
    assert backend.tiered
    prompt = list(range(1, 10))
    sid, _, _ = backend.new_seq(params, prompt, shard=1)
    backend.free_seq(sid)
    p1 = backend.pool.shards[1]
    grab = p1.alloc(p1.num_free + p1.num_cached)         # demote on shard 1
    for b in grab:
        p1.decref(b)
    assert backend.backends[1].tiers.stats.demotes > 0
    assert backend.tier_shard_for(prompt) == 1
    assert backend.tier_shard_for(list(range(900, 920))) is None
    # the scheduler's probe routes the request to the holding shard
    sched = MarsScheduler(pool=backend.pool)
    sched.tier_probe = backend.tier_shard_for
    assert sched.offer(Request(rid=7, prompt=tuple(prompt), prefix_len=4,
                               max_new=2))
    batch = sched.schedule_batch(4)
    assert len(batch) == 1 and batch[0]._shard == 1
    backend.pool.unreserve(batch[0].blocks_needed(4), rid=7)
    backend.release()


# ---------------------------------------------------------------------------
# end-to-end tiered serving parity under forced spill
# ---------------------------------------------------------------------------

def _spill_requests(cfg, n=18, n_prefixes=6, prefix_len=8, max_new=3):
    rng = np.random.default_rng(5)
    prefixes = [tuple(int(t) for t in rng.integers(1, cfg.vocab, prefix_len))
                for _ in range(n_prefixes)]
    reqs = []
    for i in range(n):
        p = prefixes[i % n_prefixes]
        tail = tuple(int(t) for t in rng.integers(1, cfg.vocab, 2))
        reqs.append(Request(rid=i, prompt=p + tail, arrival=i * 1e-3,
                            prefix_len=prefix_len, max_new=max_new))
    return reqs


@pytest.mark.parametrize("shards", [1, 2])
def test_tiered_serving_token_parity_under_spill(shards):
    """Dense-vs-paged token parity must survive tiering: a pool too
    small for the prefix working set spills and re-promotes mid-serve,
    and every request's tokens still match the dense greedy path."""
    import jax.numpy as jnp
    from repro.kvcache.backend import PagedBackend, ShardedPagedBackend
    from repro.serve.engine import PagedLM, ServeEngine
    from repro.serve.step import greedy_generate

    cfg, params = _model()
    if shards == 1:
        backend = PagedBackend(cfg, num_blocks=10, block_size=4,
                               decode_mode="gather", tiered=True)
        managers = [backend.tiers]
    else:
        backend = ShardedPagedBackend(cfg, n_shards=2, num_blocks=20,
                                      block_size=4, decode_mode="gather",
                                      tiered=True)
        managers = [b.tiers for b in backend.backends]
    sched = MarsScheduler(pool=backend.pool)
    if shards > 1:
        sched.tier_probe = backend.tier_shard_for
    eng = ServeEngine(backend.pool, sched, PagedLM(params, cfg, backend),
                      max_lanes=3)
    reqs = _spill_requests(cfg)
    out = eng.run(reqs)
    assert sorted(out) == list(range(len(reqs)))
    assert sum(t.stats.demotes for t in managers) > 0, "never spilled"
    assert sum(t.stats.promotes for t in managers) > 0, "never promoted"
    for t in managers:
        t.check()
    backend.pool.check_invariants()
    for req in reqs:
        want = greedy_generate(params, cfg,
                               jnp.asarray([req.prompt], jnp.int32),
                               req.max_new,
                               max_seq=len(req.prompt) + req.max_new + 1)
        assert out[req.rid][0] == list(np.asarray(want[0])), \
            f"rid {req.rid} diverged under tiered spill"


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------

def test_observer_adopts_tier_stats_and_orders_events():
    from repro.kvcache.backend import PagedBackend
    from repro.obs import Observer
    from repro.serve.engine import PagedLM, ServeEngine

    cfg, params = _model()
    backend = PagedBackend(cfg, num_blocks=10, block_size=4,
                           decode_mode="gather", tiered=True)
    sched = MarsScheduler(pool=backend.pool)
    eng = ServeEngine(backend.pool, sched, PagedLM(params, cfg, backend),
                      max_lanes=3)
    obs = Observer(paranoid=True, paranoid_every=2).attach(eng)
    assert backend.tiers.obs is obs
    snap0 = obs.registry.snapshot()
    assert "tier.shard0.host.occupancy" in snap0["gauges"]
    eng.run(_spill_requests(cfg, n=12))
    assert backend.tiers.stats.demotes > 0
    snap = obs.registry.snapshot()
    assert snap["counters"]["tier.shard0.demotes"] \
        == backend.tiers.stats.demotes
    assert snap["counters"]["tier.shard0.promotes"] \
        == backend.tiers.stats.promotes
    assert 0.0 <= snap["gauges"]["tier.shard0.host.occupancy"] <= 1.0
    assert 0.0 <= snap["gauges"]["tier.promote_row_hit_pct"] <= 100.0
    # demote -> promote -> decode, per key, in the trace
    evs = list(obs.trace.events())
    demoted = {}
    saw_promote = False
    for e in evs:
        if e["ev"] == "tier.demote":
            demoted.setdefault(e["key"], e["ts"])
        elif e["ev"] == "tier.promote":
            saw_promote = True
            assert e["key"] in demoted and demoted[e["key"]] <= e["ts"]
    assert saw_promote
    first_promote = min(e["ts"] for e in evs if e["ev"] == "tier.promote")
    assert any(e["ev"] == "backend.decode" and e["ts"] >= first_promote
               for e in evs)
    backend.release()


# ---------------------------------------------------------------------------
# property: demote -> promote bitwise round-trip under interleaved
# sharing / CoW forks / eviction pressure
# ---------------------------------------------------------------------------

if st is not None:
    @settings(max_examples=12, deadline=None)
    @given(st.sampled_from(["float32", "bfloat16"]),   # KV dtype
           st.integers(2, 5),                          # block_size
           st.integers(1, 2),                          # kv heads
           st.integers(1, 3),                          # head_dim
           st.integers(2, 3),                          # layered pool depth
           st.integers(0, 10_000))                     # workload seed
    def test_tier_roundtrip_property(dtype, bs, hkv, dh, layers, seed):
        """Every promoted block's KV must be bitwise what was demoted,
        across dtypes and page shapes, while prompts share prefixes,
        fork CoW tails, and eviction pressure churns the pool —
        ``check_invariants`` + ``tiers.check`` clean after every round."""
        rng = np.random.default_rng(seed)
        pool = BlockPool(PoolConfig(num_blocks=8, block_size=bs,
                                    n_kv_heads=hkv, head_dim=dh,
                                    n_layers=layers, dtype=dtype))
        cache = PrefixCache(bs)
        cache.attach(pool)
        tiers = TierManager(pool, cache,
                            (TierSpec("host", 4), TierSpec("remote", 8)))
        from repro.analysis import refsan
        san = refsan.attach(pool)
        prompts = [[int(t) for t in rng.integers(1, 50, 2 * bs + 1)]
                   for _ in range(3)]
        prompts.append(list(prompts[0][:bs]) + [77])   # shared prefix
        golden: dict = {}                              # key -> (k, v)
        for _ in range(4):
            for p in prompts:
                bids, n = tiers.match(p)
                tiers.flush_promotions()       # payload lands before reads
                for j, bid in enumerate(bids):
                    key = tuple(p[:(j + 1) * bs])
                    if key in golden:                  # bitwise survival
                        np.testing.assert_array_equal(
                            pool.k_pages[:, bid], golden[key][0])
                        np.testing.assert_array_equal(
                            pool.v_pages[:, bid], golden[key][1])
                table = BlockTable(list(bids), n)
                kv = (rng.standard_normal(
                          (layers, len(p) - n, hkv, dh)).astype(dtype),
                      rng.standard_normal(
                          (layers, len(p) - n, hkv, dh)).astype(dtype))
                table.extend(pool, p[n:], seq_tokens=p, cache=cache, kv=kv)
                for j, bid in enumerate(table.blocks[:len(p) // bs]):
                    key = tuple(p[:(j + 1) * bs])
                    golden.setdefault(key,
                                      (np.array(pool.k_pages[:, bid]),
                                       np.array(pool.v_pages[:, bid])))
                if rng.random() < 0.4:                 # CoW fork churn
                    fork = table.fork(pool)
                    fork.extend(pool, [7], seq_tokens=p + [7])
                    for b in fork.blocks:
                        pool.decref(b)
                cache.release(table, pool)
                pool.check_invariants()
                tiers.check()
            # eviction pressure between rounds
            n_grab = rng.integers(1, pool.num_free + pool.num_cached + 1)
            grab = pool.alloc(int(n_grab))
            for b in grab:
                pool.decref(b)
            pool.check_invariants()
            tiers.check()
        san.check()
        san.detach()
else:
    def test_tier_roundtrip_property():
        pytest.importorskip("hypothesis")
