"""Kernel validation: Pallas (interpret=True) vs pure-jnp oracles, with
shape/dtype sweeps and hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests skip below; the rest collects
    given = settings = st = None

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.paged_attention import (decode_attend,
                                                           paged_attention)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_decode_ref)
from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.mars_gather.ops import (embedding_gather,
                                           embedding_grad_scatter)
from repro.kernels.mars_gather.ref import embedding_gather_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,D,bq,bk", [
    (1, 128, 2, 64, 64, 64),
    (2, 256, 4, 64, 128, 128),
    (1, 512, 1, 128, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, H, D), dtype)
    v = jax.random.normal(ks[2], (B, S, H, D), dtype)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                          interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,D,page,npages", [
    (2, 4, 2, 64, 16, 4),
    (3, 8, 1, 64, 32, 2),
    (1, 4, 4, 128, 16, 8),
])
def test_paged_attention_matches_ref(B, H, Hkv, D, page, npages):
    P = B * npages + 2
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (P, page, Hkv, D))
    vp = jax.random.normal(ks[2], (P, page, Hkv, D))
    rng = np.random.default_rng(0)
    pt = jnp.asarray(rng.permutation(P)[:B * npages].reshape(B, npages),
                     jnp.int32)
    lengths = jnp.asarray(rng.integers(1, page * npages + 1, B), jnp.int32)
    out = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    ref = paged_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_layered_pool():
    """The kernel reads plane ``layer`` of a layered (L, P, page, Hkv, D)
    pool buffer directly — one page table serves every layer."""
    L, B, H, Hkv, D, page, npages = 3, 2, 4, 2, 64, 16, 3
    P = B * npages + 1
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (L, P, page, Hkv, D))
    vp = jax.random.normal(ks[2], (L, P, page, Hkv, D))
    rng = np.random.default_rng(1)
    pt = jnp.asarray(rng.permutation(P)[:B * npages].reshape(B, npages),
                     jnp.int32)
    lengths = jnp.asarray(rng.integers(1, page * npages + 1, B), jnp.int32)
    for layer in range(L):
        out = paged_attention(q, kp, vp, pt, lengths, layer=layer,
                              interpret=True)
        ref = paged_attention_ref(q, kp, vp, pt, lengths, layer=layer)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
    # 4-D single-plane pages keep working (PR-1 ToyModel engine path)
    out4 = paged_attention(q, kp[1], vp[1], pt, lengths, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out4),
        np.asarray(paged_attention_ref(q, kp, vp, pt, lengths, layer=1)),
        rtol=2e-4, atol=2e-4)


def test_decode_attend_merges_inflight_token():
    """Kernel + one online-softmax merge step == flat softmax over
    [cached pages; in-flight token], including zero-length lanes (the
    token attends only itself)."""
    L, B, H, Hkv, D, page, npages = 2, 3, 8, 2, 32, 8, 2
    ks = jax.random.split(jax.random.key(8), 5)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (L, 7, page, Hkv, D))
    vp = jax.random.normal(ks[2], (L, 7, page, Hkv, D))
    kn = jax.random.normal(ks[3], (B, Hkv, D))
    vn = jax.random.normal(ks[4], (B, Hkv, D))
    pt = jnp.asarray([[1, 2], [3, 4], [5, 6]], jnp.int32)
    lengths = jnp.asarray([0, 5, page * npages], jnp.int32)
    for layer in range(L):
        out = decode_attend(q, kn, vn, kp, vp, pt, lengths, layer=layer,
                            interpret=True)
        ref = paged_decode_ref(q, kn, vn, kp, vp, pt, lengths, layer=layer)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [1, 3, 8, 11, 100])
def test_paged_attention_window_mask(window):
    """Sliding-window kernel decode vs the windowed oracle: the query at
    position ``lengths[b]`` sees only the last ``window`` positions.
    Covers window == 1 (no cached key valid — the kernel must return the
    empty state, not a saturated softmax) and window > length (inactive)."""
    B, H, Hkv, D, page, npages = 3, 4, 2, 32, 8, 3
    P = B * npages + 1
    ks = jax.random.split(jax.random.key(12), 5)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (P, page, Hkv, D))
    vp = jax.random.normal(ks[2], (P, page, Hkv, D))
    kn = jax.random.normal(ks[3], (B, Hkv, D))
    vn = jax.random.normal(ks[4], (B, Hkv, D))
    rng = np.random.default_rng(3)
    pt = jnp.asarray(rng.permutation(P)[:B * npages].reshape(B, npages),
                     jnp.int32)
    lengths = jnp.asarray([2, 13, page * npages], jnp.int32)
    full = decode_attend(q, kn, vn, kp, vp, pt, lengths, window=window,
                         interpret=True)
    ref = paged_decode_ref(q, kn, vn, kp, vp, pt, lengths, window=window)
    assert np.isfinite(np.asarray(full)).all()
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    if window > page * npages:
        # window wider than every cache: identical to the global mask
        np.testing.assert_allclose(
            np.asarray(full),
            np.asarray(paged_decode_ref(q, kn, vn, kp, vp, pt, lengths)),
            rtol=2e-4, atol=2e-4)


def test_paged_attention_window_per_layer_hybrid_layout():
    """global_every hybrid layout: the same layered pool, window flipped
    per layer (0 on global layers) — the traced-window kernel must match
    the oracle on every plane."""
    L, B, H, Hkv, D, page, npages = 4, 2, 4, 2, 32, 8, 2
    ge, w = 2, 5                    # layers 0, 2 global; 1, 3 windowed
    P = B * npages + 1
    ks = jax.random.split(jax.random.key(13), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (L, P, page, Hkv, D))
    vp = jax.random.normal(ks[2], (L, P, page, Hkv, D))
    rng = np.random.default_rng(4)
    pt = jnp.asarray(rng.permutation(P)[:B * npages].reshape(B, npages),
                     jnp.int32)
    lengths = jnp.asarray([7, page * npages], jnp.int32)
    for li in range(L):
        wl = 0 if li % ge == 0 else w
        out = paged_attention(q, kp, vp, pt, lengths, layer=li,
                              window=jnp.asarray(wl, jnp.int32),
                              interpret=True)
        ref = paged_attention_ref(q, kp, vp, pt, lengths, layer=li,
                                  window=wl)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


if st is not None:
    @settings(max_examples=16, deadline=None)
    @given(st.integers(2, 3),            # layer count (>= 2: layered pool)
           st.integers(1, 3),            # batch lanes
           st.integers(1, 3),            # pages per sequence
           st.integers(1, 2),            # kv heads
           st.integers(1, 2),            # GQA repetition
           st.integers(0, 25),           # sliding window (0 = global)
           st.integers(0, 3),            # global_every (hybrid layout)
           st.integers(0, 1000),         # seed for ragged lengths
           )
    def test_kernel_decode_property(L, B, npages, Hkv, n_rep, window,
                                    global_every, seed):
        """Property: kernel-path decode attention (paged_attention +
        in-flight merge) matches both the page-walk oracle and the dense
        flat-softmax math across random ragged lengths, page counts,
        layer counts, window sizes (incl. window == 1: no cached key
        valid, and window > length: inactive) and ``global_every``
        hybrid layouts (global layers decode unmasked)."""
        page, D = 8, 32
        H = Hkv * n_rep
        P = B * npages + 1
        rng = np.random.default_rng(seed)
        ks = jax.random.split(jax.random.key(seed), 5)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (L, P, page, Hkv, D))
        vp = jax.random.normal(ks[2], (L, P, page, Hkv, D))
        kn = jax.random.normal(ks[3], (B, Hkv, D))
        vn = jax.random.normal(ks[4], (B, Hkv, D))
        pt = jnp.asarray(rng.permutation(P)[:B * npages]
                         .reshape(B, npages), jnp.int32)
        lengths = jnp.asarray(rng.integers(0, page * npages + 1, B),
                              jnp.int32)
        layer = int(rng.integers(L))
        # the hybrid per-layer flag: global layers drop the window
        is_global = bool(global_every) and layer % global_every == 0
        wl = 0 if (is_global or not window) else window
        if wl != 1:
            # cached-only attention is undefined over zero valid keys
            # (softmax of an empty set) — clamp length for this
            # comparison (window 1 admits no cached key at any length;
            # decode_attend below covers its true semantics: the token
            # attends itself alone)
            ln1 = jnp.maximum(lengths, 1)
            cached = paged_attention(q, kp, vp, pt, ln1, layer=layer,
                                     window=wl, interpret=True)
            np.testing.assert_allclose(
                np.asarray(cached),
                np.asarray(paged_attention_ref(q, kp, vp, pt, ln1,
                                               layer=layer, window=wl)),
                rtol=2e-4, atol=2e-4)
        full = decode_attend(q, kn, vn, kp, vp, pt, lengths, layer=layer,
                             window=wl, interpret=True)
        assert np.isfinite(np.asarray(full)).all()
        np.testing.assert_allclose(
            np.asarray(full),
            np.asarray(paged_decode_ref(q, kn, vn, kp, vp, pt, lengths,
                                        layer=layer, window=wl)),
            rtol=2e-4, atol=2e-4)
else:
    def test_kernel_decode_property():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 96, 1, 8, 4, 32),
])
def test_ssd_scan_matches_sequential_ref(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    b = jax.random.normal(ks[1], (B, S, N))
    c = jax.random.normal(ks[2], (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    la = -jnp.exp(jax.random.normal(ks[4], (B, S, H)) * 0.3) * dt
    y, s = ssd_scan(x, b, c, la, dt, chunk=chunk, interpret=True)
    yr, sr = ssd_ref(x, b, c, la, dt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


def test_ssd_models_layer_uses_same_math():
    """models/ssm.ssd_chunked must agree with the sequential oracle too."""
    from repro.models.ssm import ssd_chunked
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab=16,
                      ssm_state=8, d_ssm_head=8, ssm_chunk=16)
    ks = jax.random.split(jax.random.key(4), 5)
    B, S, H, P, N = 2, 64, 4, 8, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    b = jax.random.normal(ks[1], (B, S, N))
    c = jax.random.normal(ks[2], (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    la = -jnp.exp(jax.random.normal(ks[4], (B, S, H)) * 0.3) * dt
    y, s = ssd_chunked(x, b, c, la, dt, cfg)
    yr, sr = ssd_ref(x, b, c, la, dt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MARS gather
# ---------------------------------------------------------------------------

if st is not None:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 300), st.integers(2, 50))
    def test_gather_sorted_equals_plain(n_ids, vocab):
        ids = jax.random.randint(jax.random.key(n_ids), (n_ids,), 0, vocab)
        table = jax.random.normal(jax.random.key(vocab), (vocab, 8))
        a = embedding_gather(table, ids, mode="sorted")
        b = embedding_gather_ref(table, ids)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
else:
    def test_gather_sorted_equals_plain():
        pytest.importorskip("hypothesis")


def test_gather_batch_shape():
    table = jax.random.normal(jax.random.key(0), (64, 16))
    ids = jax.random.randint(jax.random.key(1), (4, 7), 0, 64)
    out = embedding_gather(table, ids, mode="sorted")
    assert out.shape == (4, 7, 16)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(table[ids]))


def test_grad_scatter_matches_dense():
    V, D, T = 32, 8, 100
    ids = jax.random.randint(jax.random.key(5), (T,), 0, V)
    g = jax.random.normal(jax.random.key(6), (T, D))
    want = jnp.zeros((V, D)).at[ids].add(g)
    got = embedding_grad_scatter(ids, g, V)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
