"""Fault-tolerance: checkpoint save/restore roundtrip + atomicity,
heartbeat/straggler detection, elastic re-mesh planning."""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft import checkpoint as ckpt
from repro.ft.manager import (ElasticPlan, HeartbeatMonitor,
                              StragglerDetector, optimal_ckpt_interval_steps,
                              plan_elastic_mesh)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (16, 8)),
            "b": {"x": jnp.arange(10, dtype=jnp.float32),
                  "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, 5, tmp_path)
    assert ckpt.latest_step(tmp_path) == 5
    r = ckpt.restore(jax.eval_shape(lambda: t), 5, tmp_path)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, r)


def test_checkpoint_gc_keeps_last_three(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(_tree(s), s, tmp_path)
    steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(steps) == 3
    assert ckpt.latest_step(tmp_path) == 5


def test_checkpoint_corruption_detected(tmp_path):
    t = _tree()
    d = ckpt.save(t, 1, tmp_path)
    shard = next((d / "shards").glob("*.npy"))
    arr = np.load(shard)
    arr.flat[0] += 1.0
    np.save(shard, arr)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(jax.eval_shape(lambda: t), 1, tmp_path)


def test_checkpoint_resharding_on_restore(tmp_path):
    """Restore onto a different mesh (elastic restart)."""
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4)}
    ckpt.save(t, 1, tmp_path)
    from repro.launch.mesh import auto_axis_types
    mesh = jax.make_mesh((1,), ("data",), **auto_axis_types(1))
    sh = {"w": jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))}
    r = ckpt.restore(jax.eval_shape(lambda: t), 1, tmp_path, shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding.spec == jax.sharding.PartitionSpec("data")


def test_heartbeat_dead_host_detection(tmp_path):
    a = HeartbeatMonitor(tmp_path, host_id=0, timeout_s=0.2)
    b = HeartbeatMonitor(tmp_path, host_id=1, timeout_s=0.2)
    a.beat(1)
    b.beat(1)
    assert a.dead_hosts() == []
    time.sleep(0.3)
    a.beat(2)                      # host 0 alive, host 1 silent
    assert a.dead_hosts() == [1]


def test_straggler_detection():
    d = StragglerDetector(n_hosts=4, factor=1.5, patience=3)
    for step in range(10):
        for h in range(4):
            d.observe(h, 1.0 if h != 2 else 3.0)
        s = d.stragglers()
    assert s == [2]


def test_elastic_plan_preserves_model_axis():
    p = plan_elastic_mesh((2, 16, 16), ("pod", "data", "model"), 256)
    assert dict(zip(p.axis_names, p.mesh_shape))["model"] == 16
    assert np.prod(p.mesh_shape) <= 256
    # losing one pod keeps a full single-pod mesh
    assert p.mesh_shape == (1, 16, 16)


def test_elastic_plan_partial_loss():
    p = plan_elastic_mesh((2, 16, 16), ("pod", "data", "model"), 480)
    used = int(np.prod(p.mesh_shape))
    assert used <= 480 and used >= 448
    assert dict(zip(p.axis_names, p.mesh_shape))["model"] == 16


def test_elastic_plan_rejects_too_few():
    with pytest.raises(ValueError):
        plan_elastic_mesh((2, 16, 16), ("pod", "data", "model"), 8)


def test_young_daly_interval():
    # 1s steps, 30s checkpoints, 24h MTBF/host, 512 hosts
    n = optimal_ckpt_interval_steps(1.0, 30.0, 24.0, 512)
    assert 50 <= n <= 200, n
    # more hosts -> checkpoint more often
    n2 = optimal_ckpt_interval_steps(1.0, 30.0, 24.0, 2048)
    assert n2 < n


def test_train_resume_exact(tmp_path):
    """Kill-and-resume reproduces the uninterrupted run exactly."""
    from repro.launch import train as train_mod
    argv = ["--arch", "qwen1_5_0_5b", "--smoke", "--steps", "6",
            "--batch", "2", "--seq", "32", "--ckpt-interval", "3",
            "--workdir", str(tmp_path / "a"), "--log-every", "100"]
    full = train_mod.main(argv)
    argv2 = [a if a != str(tmp_path / "a") else str(tmp_path / "b")
             for a in argv]
    part = train_mod.main(argv2[:-2] + ["--steps", "3"][0:0] + argv2[-2:]
                          if False else
                          ["--arch", "qwen1_5_0_5b", "--smoke", "--steps",
                           "3", "--batch", "2", "--seq", "32",
                           "--ckpt-interval", "3",
                           "--workdir", str(tmp_path / "b"),
                           "--log-every", "100"])
    resumed = train_mod.main(
        ["--arch", "qwen1_5_0_5b", "--smoke", "--steps", "6", "--batch",
         "2", "--seq", "32", "--ckpt-interval", "3", "--workdir",
         str(tmp_path / "b"), "--resume", "--log-every", "100"])
    np.testing.assert_allclose(resumed[-1], full[-1], rtol=1e-4)
