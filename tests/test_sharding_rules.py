"""Sharding rules: divisibility fallbacks, memory accounting, cache specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import rules


@pytest.fixture(scope="module")
def mesh():
    n = len(jax.devices())
    from repro.launch.mesh import auto_axis_types
    return jax.make_mesh((1, 1), ("data", "model"), **auto_axis_types(2))


def _spec(axes, shape, mesh, fsdp=True):
    return rules.spec_for(axes, shape, rules.logical_rules(mesh, fsdp), mesh)


def _norm(spec):
    # older jax does not canonicalize PartitionSpec('x') == P(('x',))
    return tuple(e if isinstance(e, tuple) or e is None else (e,)
                 for e in spec)


def test_divisible_dims_get_primary_mapping(mesh):
    # 16-way mesh axes of size 1 always divide: primary mappings hold
    s = _spec(("embed", "heads", "head"), (1024, 16, 64), mesh)
    assert _norm(s) == _norm(P(("data",), "model", None))


def test_nondivisible_heads_fall_back_to_head_dim():
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices() * 16)[:16].reshape(1, 16), ("data", "model"))
    s = rules.spec_for(("embed", "heads", "head"), (7168, 56, 128),
                       rules.logical_rules(mesh16), mesh16)
    assert s[1] is None and s[2] == "model"   # heads 56 % 16 != 0 -> head dim


def test_nondivisible_vocab_replicates():
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices() * 16)[:16].reshape(1, 16), ("data", "model"))
    s = rules.spec_for(("vocab", "embed"), (50280, 1024),
                       rules.logical_rules(mesh16, fsdp=False), mesh16)
    assert s[0] is None  # vocab replicated; model falls back to embed dim
    assert s[1] == "model"


def test_no_axis_used_twice():
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices() * 32)[:32].reshape(2, 16), ("data", "model"))
    s = rules.spec_for(("expert", "embed", "mlp"), (128, 7168, 4864),
                       rules.logical_rules(mesh16), mesh16)
    used = [a for a in s if a is not None]
    flat = []
    for a in used:
        flat.extend(a if isinstance(a, tuple) else (a,))
    assert len(flat) == len(set(flat))


def test_sharded_bytes_accounting():
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices() * 16)[:16].reshape(1, 16), ("data", "model"))
    tree = [jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)]
    sh = [jax.NamedSharding(mesh16, P(None, "model"))]
    b = rules.sharded_bytes_per_device(tree, sh, mesh16)
    assert b == 64 * 8 * 2
    # padding: 56 over 16 -> ceil = 4 rows/device
    tree = [jax.ShapeDtypeStruct((56, 10), jnp.float32)]
    sh = [jax.NamedSharding(mesh16, P("model", None))]
    assert rules.sharded_bytes_per_device(tree, sh, mesh16) == 4 * 10 * 4


def test_batch_sharding_divisibility(mesh):
    assert rules.batch_sharding(mesh, 4).spec == P(("data",))
    assert rules.batch_sharding(mesh, 1).spec == P(("data",))  # 1 % 1 == 0


def test_cache_shardings_kv_vs_seq():
    from repro import configs
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices() * 256)[:256].reshape(16, 16),
        ("data", "model"))
    qwen = configs.get("qwen1_5_0_5b")       # kv=16 divides
    cs = rules.cache_shardings(mesh16, qwen, batch=128)
    assert cs.k.spec == P(None, ("data",), None, "model", None)
    dsk = configs.get("deepseek_coder_33b")  # kv=8 doesn't -> seq sharding
    cs = rules.cache_shardings(mesh16, dsk, batch=128)
    assert cs.k.spec == P(None, ("data",), "model", None, None)


def test_cache_shardings_rejects_non_dense_backends():
    """Regression: the specs assume the dense (L,B,S,K,dh) lm.Cache
    layout — a paged pool's (L,P,page,K,dh) buffer would silently
    mis-shard its page axis as if it were the sequence axis, so any
    non-dense backend must raise, pointing at ShardedBlockPool."""
    from repro import configs
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices() * 256)[:256].reshape(16, 16),
        ("data", "model"))
    qwen = configs.get("qwen1_5_0_5b")
    with pytest.raises(NotImplementedError, match="ShardedBlockPool"):
        rules.cache_shardings(mesh16, qwen, batch=128, backend="paged")
    # the dense default is untouched (dryrun.py call site)
    assert rules.cache_shardings(mesh16, qwen, batch=128,
                                 backend="dense").k is not None


def test_pool_shard_count_uses_model_axis():
    mesh16 = jax.sharding.Mesh(
        np.array(jax.devices() * 16)[:16].reshape(1, 16),
        ("data", "model"))
    assert rules.pool_shard_count(mesh16) == 16
    assert rules.pool_shard_count(None) == 1
    no_model = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    assert rules.pool_shard_count(no_model) == 1
