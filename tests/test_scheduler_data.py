"""Serving scheduler (software MARS) + data pipeline tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests skip below; the rest collects
    given = settings = st = None

from repro.data.pipeline import BucketReorderBuffer, DataConfig, TokenStream
from repro.serving.scheduler import (MarsScheduler, Request,
                                     unique_prefix_blocks)


def _requests(n, n_prefixes=8, seed=0):
    rng = np.random.default_rng(seed)
    prefixes = [tuple(rng.integers(1, 100, 16).tolist())
                for _ in range(n_prefixes)]
    return [Request(rid=i, prompt=prefixes[i % n_prefixes]
                    + tuple(rng.integers(1, 100, 4).tolist()),
                    arrival=i * 1e-3, prefix_len=16)
            for i in range(n)]


def test_mars_scheduler_improves_page_coherence():
    reqs = _requests(128, n_prefixes=16)
    res = {}
    for mars in (False, True):
        sched = MarsScheduler(mars=mars)
        pend = list(reqs)
        blocks, batches = 0, 0
        while pend or len(sched):
            while pend and sched.offer(pend[0]):
                pend.pop(0)
            b = sched.schedule_batch(8, now=1.0)
            if not b:
                break
            blocks += unique_prefix_blocks(b)
            batches += 1
        res[mars] = blocks / batches
    # the whole point: MARS batches touch far fewer unique prefix blocks
    assert res[True] < 0.5 * res[False], res


def test_scheduler_serves_everything_once():
    reqs = _requests(64)
    sched = MarsScheduler(mars=True)
    pend = list(reqs)
    seen = []
    while pend or len(sched):
        while pend and sched.offer(pend[0]):
            pend.pop(0)
        b = sched.schedule_batch(8, now=1.0)
        if not b:
            break
        seen.extend(r.rid for r in b)
    assert sorted(seen) == list(range(64))


def test_scheduler_no_starvation():
    """Oldest-page-first: a lone request on a cold page is not starved by
    a flood of hot-page requests."""
    sched = MarsScheduler(mars=True)
    cold = Request(rid=999, prompt=tuple(range(16)), arrival=0.0,
                   prefix_len=16)
    sched.offer(cold)
    hot = _requests(63, n_prefixes=1, seed=1)
    for r in hot:
        sched.offer(r)
    first = sched.schedule_batch(8, now=1.0)
    assert 999 in [r.rid for r in first]   # cold page drained first (oldest)


def test_scheduler_backpressure():
    sched = MarsScheduler(request_q=16, mars=True)
    reqs = _requests(32)
    accepted = sum(sched.offer(r) for r in reqs)
    assert accepted == 16
    assert sched.stats.stall_rejects == 16


if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 200), st.integers(1, 12))
    def test_scheduler_property_conservation(n, n_prefixes):
        reqs = _requests(n, n_prefixes=max(1, n_prefixes))
        sched = MarsScheduler(mars=True)
        pend = list(reqs)
        got = 0
        for _ in range(10 * n + 10):
            while pend and sched.offer(pend[0]):
                pend.pop(0)
            b = sched.schedule_batch(7, now=1.0)
            got += len(b)
            if not pend and len(sched) == 0:
                break
        assert got == n
else:
    def test_scheduler_property_conservation():
        pytest.importorskip("hypothesis")


def test_tokenstream_deterministic_and_sharded():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2,
                     host_id=0)
    a = next(TokenStream(cfg))
    b = next(TokenStream(cfg))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    cfg1 = DataConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2,
                      host_id=1)
    c = next(TokenStream(cfg1))
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 16)
    # next-token alignment
    full = next(TokenStream(cfg, start_step=0))
    np.testing.assert_array_equal(full["tokens"][:, 1:], full["labels"][:, :-1])


def test_tokenstream_resume():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    s = TokenStream(cfg)
    next(s)
    second = next(s)
    resumed = next(TokenStream(cfg, start_step=1))
    np.testing.assert_array_equal(second["tokens"], resumed["tokens"])


def test_bucket_buffer_reduces_padding():
    rng = np.random.default_rng(0)
    lens = rng.integers(10, 2000, 256)
    samples = [np.ones(l, np.int32) for l in lens]
    buf = BucketReorderBuffer(window=256)
    for s in samples:
        assert buf.offer(s)
    waste = []
    while True:
        out = buf.take_batch(16)
        if out is None:
            break
        arr, mask = out
        waste.append(1.0 - mask.mean())
    # naive batching pads everything to 2048
    naive = 1.0 - lens.mean() / 2048
    assert np.mean(waste) < 0.6 * naive
