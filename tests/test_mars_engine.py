"""MARS engine: jitted scan vs python oracle + invariants."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests skip below; the rest collects
    given = settings = st = None

from repro.core import mars, streams


def _runs(x):
    x = np.asarray(x)
    if len(x) == 0:
        return np.array([0])
    return np.diff(np.flatnonzero(np.concatenate(
        [[True], x[1:] != x[:-1], [True]])))


@pytest.mark.parametrize("wl", streams.WORKLOADS)
def test_engine_matches_oracle(wl):
    gpu = streams.GpuConfig(n_cores=16, cores_per_group=8)
    s = streams.make_workload(wl, gpu, reqs_per_core=64)
    ports = np.asarray(s.source) // gpu.cores_per_group
    perm, _ = mars.mars_reorder(s.addr, ports, src=np.asarray(s.source))
    ref = mars.mars_reorder_reference(s.addr, ports, src=np.asarray(s.source))
    np.testing.assert_array_equal(perm, ref)


def test_permutation_and_grouping():
    s = streams.make_workload("WL1", reqs_per_core=64)
    ports = np.asarray(s.source) // 8
    perm, stats = mars.mars_reorder(s.addr, ports, src=np.asarray(s.source))
    n = s.n
    assert sorted(perm) == list(range(n))
    pages = np.asarray(s.addr) >> streams.PAGE_SHIFT
    # MARS must not reduce page-run length on average
    assert _runs(pages[perm]).mean() >= _runs(pages).mean()
    assert stats["total_cycles"] >= n


def test_fifo_within_page():
    """Requests of one page must leave MARS in arrival order."""
    s = streams.make_workload("WL2", reqs_per_core=64)
    ports = np.asarray(s.source) // 8
    perm, _ = mars.mars_reorder(s.addr, ports, src=np.asarray(s.source))
    pages = np.asarray(s.addr) >> streams.PAGE_SHIFT
    pos = np.argsort(perm)  # original idx -> output position
    port_of = np.asarray(ports)
    for pg in np.unique(pages)[:50]:
        for p in np.unique(port_of):
            idx = np.flatnonzero((pages == pg) & (port_of == p))
            # same page, same port => FIFO preserved
            assert np.all(np.diff(pos[idx]) > 0)


if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 40), min_size=1, max_size=300),
           st.integers(1, 4))
    def test_random_streams_always_drain(page_list, ways):
        """Property: any input drains completely into a valid permutation."""
        pages = np.asarray(page_list, np.int32)
        addr = pages << streams.PAGE_SHIFT
        cfg = mars.MarsConfig(request_q=64, page_entries=16, ways=ways,
                              n_ports=2, mshr_per_core=8)
        perm, _ = mars.mars_reorder(addr, cfg=cfg)
        assert sorted(perm) == list(range(len(addr)))
        ref = mars.mars_reorder_reference(addr, cfg=cfg)
        np.testing.assert_array_equal(perm, ref)
else:
    def test_random_streams_always_drain():
        pytest.importorskip("hypothesis")


def test_single_page_stream_is_identity():
    addr = np.arange(50, dtype=np.int32)  # all within page 0
    perm, _ = mars.mars_reorder(addr, ports=np.zeros(50, np.int64))
    np.testing.assert_array_equal(perm, np.arange(50))


def test_page_set_hash_spreads_strides():
    for stride in (1, 2, 8, 64, 128, 4096):
        pages = np.arange(0, 64 * stride, stride)
        sets = np.array([mars._page_set_py(int(p), 64) for p in pages])
        # a decent hash puts 64 strided pages into >= 24 distinct sets
        assert len(np.unique(sets)) >= 24, (stride, len(np.unique(sets)))


def test_mshr_cap_bounds_inflight():
    """No core may ever exceed its MSHR allowance inside the queue."""
    gpu = streams.GpuConfig(n_cores=16, cores_per_group=8)
    s = streams.make_workload("WL1", gpu, reqs_per_core=64)
    cfg = mars.MarsConfig(mshr_per_core=4)
    ports = np.asarray(s.source) // gpu.cores_per_group
    perm, _ = mars.mars_reorder(s.addr, ports, cfg, src=np.asarray(s.source))
    # reconstruct occupancy: at any emission step, per-core inserted-minus
    # -drained <= cap.  Insertion order == per-port FIFO; emission = perm.
    # A conservative check: within any window of `request_q` emissions, one
    # core contributes at most mshr_per_core + (drains inside window).
    pos = np.argsort(perm)
    src = np.asarray(s.source)
    for c in np.unique(src)[:8]:
        emits = np.sort(pos[src == c])
        # consecutive emissions of one core can't jump more than cap ahead
        # of its own drain point
        gaps = emits[cfg.mshr_per_core:] - emits[:-cfg.mshr_per_core]
        assert np.all(gaps > 0)
