"""Split-phase decode pipeline: dispatch/sync/commit lifecycle parity,
flush-barrier semantics (fork, free, release), sharded issue-then-gather
ordering, pool-exhaustion rollback with work in flight, and the
construction-surface deprecations that rode the API redesign."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hypothesis is optional in CI
    st = None

from repro import configs
from repro.kvcache.backend import (DenseBackend, PagedBackend,
                                   ShardedPagedBackend, make_backend)
from repro.models import lm

ARCH = "qwen1_5_0_5b"


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke(ARCH)
    params = lm.init(cfg, jax.random.key(0)).params
    return cfg, params


def _greedy(logits) -> list:
    return [int(np.argmax(np.asarray(lg, np.float32))) for lg in logits]


# ---------------------------------------------------------------------------
# pipelined vs sequential parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("decode_mode", ["gather", "kernel"])
def test_pipelined_matches_sequential_bitwise_ragged(model, decode_mode):
    """Same decode mode, same operand values, same jitted function — the
    pipeline only reorders work, so pipelined logits are BITWISE equal
    to the sequential wrapper's, over ragged lanes, every step."""
    cfg, params = model
    prompts = [list(range(1, 6)), list(range(10, 19)),
               list(range(30, 44)), list(range(50, 67))]
    backends, sids = [], []
    for _ in range(2):
        b = PagedBackend(cfg, num_blocks=64, block_size=4,
                         decode_mode=decode_mode, share_prefixes=False)
        backends.append(b)
        sids.append([b.new_seq(params, p)[0] for p in prompts])
    seq_b, pipe_b = backends
    last_s = last_p = [p[-1] for p in prompts]
    for _ in range(4):
        lg_seq = seq_b.decode(params, sids[0], last_s)
        pipe_b.flush()
        step = pipe_b.dispatch_decode(params, last_p, sids=sids[1])
        assert pipe_b.inflight_steps == 1
        lg_pipe = pipe_b.sync(step)
        assert pipe_b.inflight_steps == 1      # synced, commit deferred
        np.testing.assert_array_equal(np.asarray(lg_seq),
                                      np.asarray(lg_pipe))
        last_s, last_p = _greedy(lg_seq), _greedy(lg_pipe)
    pipe_b.flush()
    assert pipe_b.inflight_steps == 0
    for b, ss in zip(backends, sids):
        for s, p in zip(ss, prompts):
            assert b.table(s).num_tokens == len(p) + 4
        b.release()


def test_deferred_commit_lands_one_step_late(model):
    """sync() returns logits with the KV write-back still pending; the
    backend's tables advance only at flush/next-dispatch."""
    cfg, params = model
    b = PagedBackend(cfg, num_blocks=32, block_size=4,
                     decode_mode="gather", share_prefixes=False)
    sid, _, _ = b.new_seq(params, list(range(1, 10)))
    step = b.dispatch_decode(params, [5], sids=[sid])
    lg = b.sync(step)
    assert lg.shape[0] == 1 and step.synced and not step.committed
    assert b.table(sid).num_tokens == 9       # still the prompt
    # the NEXT dispatch commits the previous step before launching
    step2 = b.dispatch_decode(params, _greedy(lg), sids=[sid])
    assert step.committed and b.table(sid).num_tokens == 10
    b.sync(step2)
    b.flush()
    assert step2.committed and b.table(sid).num_tokens == 11
    b.release()


def test_dispatch_while_inflight_raises(model):
    cfg, params = model
    b = PagedBackend(cfg, num_blocks=32, block_size=4,
                     decode_mode="gather")
    sid, _, _ = b.new_seq(params, [1, 2, 3, 4, 5])
    step = b.dispatch_decode(params, [7], sids=[sid])
    with pytest.raises(RuntimeError, match="already in flight"):
        b.dispatch_decode(params, [7], sids=[sid])
    lg = b.sync(step)
    np.testing.assert_array_equal(np.asarray(b.sync(step)),
                                  np.asarray(lg))   # sync is idempotent
    # a step belonging to another backend is rejected by both phases
    b2 = PagedBackend(cfg, num_blocks=32, block_size=4,
                      decode_mode="gather")
    sid2, _, _ = b2.new_seq(params, [1, 2, 3, 4, 5])
    foreign = b2.dispatch_decode(params, [7], sids=[sid2])
    with pytest.raises(RuntimeError, match="not in flight"):
        b.sync(foreign)
    b2.sync(foreign)
    with pytest.raises(RuntimeError, match="not pending"):
        b.commit(foreign)
    b2.release()
    b.release()


# ---------------------------------------------------------------------------
# sharded issue-then-gather
# ---------------------------------------------------------------------------

def test_sharded_dispatch_all_before_sync_any(model):
    """The sharded pipeline launches EVERY shard's kernel before blocking
    on any — in the trace, both shards' ``backend.dispatch`` events
    precede the first ``backend.decode`` sync span."""
    from repro.obs import Observer
    cfg, params = model
    obs = Observer()
    b = ShardedPagedBackend(cfg, n_shards=2, num_blocks=32, block_size=4,
                            decode_mode="gather")
    for i, inner in enumerate(b.backends):
        inner.obs = obs
        inner.obs_shard = i
    sa, _, _ = b.new_seq(params, list(range(1, 8)), shard=0)
    sb, _, _ = b.new_seq(params, list(range(20, 28)), shard=1)
    step = b.dispatch_decode(params, [3, 4], sids=[sa, sb])
    lg = b.sync(step)
    assert lg.shape[0] == 2
    b.flush()
    evs = obs.trace.events()
    di = [i for i, e in enumerate(evs) if e["ev"] == "backend.dispatch"]
    si = [i for i, e in enumerate(evs) if e["ev"] == "backend.decode"]
    ci = [i for i, e in enumerate(evs) if e["ev"] == "backend.commit"]
    assert {evs[i]["shard"] for i in di} == {0, 1}
    assert len(si) == len(ci) == 2
    assert max(di) < min(si), "a shard synced before all shards dispatched"
    assert max(si) < min(ci), "a shard committed before all shards synced"
    b.release()


def test_sharded_pipelined_matches_sequential(model):
    cfg, params = model
    prompts = [list(range(1, 8)), list(range(20, 31)),
               list(range(40, 45))]
    backends, sids = [], []
    for _ in range(2):
        b = ShardedPagedBackend(cfg, n_shards=2, num_blocks=64,
                                block_size=4, decode_mode="gather")
        backends.append(b)
        sids.append([b.new_seq(params, p, shard=i % 2)[0]
                     for i, p in enumerate(prompts)])
    seq_b, pipe_b = backends
    last_s = last_p = [p[-1] for p in prompts]
    for _ in range(3):
        lg_seq = seq_b.decode(params, sids[0], last_s)
        pipe_b.flush()
        step = pipe_b.dispatch_decode(params, last_p, sids=sids[1])
        lg_pipe = pipe_b.sync(step)
        np.testing.assert_array_equal(np.asarray(lg_seq),
                                      np.asarray(lg_pipe))
        last_s, last_p = _greedy(lg_seq), _greedy(lg_pipe)
    for b in backends:
        b.release()


# ---------------------------------------------------------------------------
# flush barriers: fork / free / release
# ---------------------------------------------------------------------------

def test_fork_mid_stream_forces_flush_barrier(model):
    """fork_seq on a backend with a deferred write-back must flush first:
    the CoW fork sees the committed KV, and both lanes keep decoding the
    tokens a fully sequential twin produces."""
    cfg, params = model
    prompt = list(range(1, 10))
    pipe = PagedBackend(cfg, num_blocks=64, block_size=4,
                        decode_mode="gather", share_prefixes=False)
    seq = PagedBackend(cfg, num_blocks=64, block_size=4,
                       decode_mode="gather", share_prefixes=False)
    ps, _, _ = pipe.new_seq(params, prompt)
    ss, _, _ = seq.new_seq(params, prompt)
    # pipelined: leave the step's write-back pending, then fork
    step = pipe.dispatch_decode(params, [5], sids=[ps])
    tok_p = _greedy(pipe.sync(step))
    assert pipe.table(ps).num_tokens == 9     # deferred...
    pf = pipe.fork_seq(ps)
    assert step.committed and pipe.inflight_steps == 0
    assert pipe.table(ps).num_tokens == 10    # ...until the fork barrier
    assert pipe.table(pf).num_tokens == 10
    # sequential twin: committed decode, then fork
    tok_s = _greedy(seq.decode(params, [ss], [5]))
    sf = seq.fork_seq(ss)
    assert tok_p == tok_s
    last_p, last_s = tok_p * 2, tok_s * 2     # both lanes advance
    for _ in range(3):
        pipe.flush()
        st2 = pipe.dispatch_decode(params, last_p, sids=[ps, pf])
        last_p = _greedy(pipe.sync(st2))
        last_s = _greedy(seq.decode(params, [ss, sf], last_s))
        assert last_p == last_s
    pipe.release()
    seq.release()


def test_free_seq_drains_pending_write_back(model):
    cfg, params = model
    b = PagedBackend(cfg, num_blocks=32, block_size=4,
                     decode_mode="gather", share_prefixes=False)
    s1, _, _ = b.new_seq(params, list(range(1, 9)))
    s2, _, _ = b.new_seq(params, list(range(20, 26)))
    step = b.dispatch_decode(params, [3, 4], sids=[s1, s2])
    b.sync(step)
    b.free_seq(s1)                # flush barrier, then the free
    assert step.committed
    assert b.table(s2).num_tokens == 7        # s2's token committed
    b.pool.check_invariants()
    b.release()


@pytest.mark.parametrize("sharded", [False, True])
def test_release_drains_pending_write_back(model, sharded):
    """Regression (flush ordered against release): a backend released
    with a deferred write-back commits it — on_alloc fires, the step
    handle reads committed — before the storage is dropped; flush()
    afterwards still raises the released error."""
    cfg, params = model
    if sharded:
        b = ShardedPagedBackend(cfg, n_shards=2, num_blocks=32,
                                block_size=4, decode_mode="gather")
        sid, _, _ = b.new_seq(params, list(range(1, 9)), shard=1)
    else:
        b = PagedBackend(cfg, num_blocks=32, block_size=4,
                         decode_mode="gather", share_prefixes=False)
        sid, _, _ = b.new_seq(params, list(range(1, 9)))
    allocs = []
    # 8-token prompt, block_size 4: the tail block is full, so the
    # deferred commit must allocate — observable through on_alloc
    step = b.dispatch_decode(params, [5], sids=[sid],
                             on_alloc=lambda s, n: allocs.append((s, n)))
    b.sync(step)
    assert not step.committed and allocs == []
    b.release()
    assert step.committed and allocs == [(sid, 1)]
    with pytest.raises(RuntimeError, match="released"):
        b.flush()
    with pytest.raises(RuntimeError, match="released"):
        b.dispatch_decode(params, [5], sids=[sid])


@pytest.mark.parametrize("sharded", [False, True])
def test_flush_is_idempotent(model, sharded):
    cfg, params = model
    if sharded:
        b = ShardedPagedBackend(cfg, n_shards=2, num_blocks=32,
                                block_size=4, decode_mode="gather")
    else:
        b = PagedBackend(cfg, num_blocks=32, block_size=4,
                         decode_mode="gather")
    sid, _, _ = b.new_seq(params, [1, 2, 3, 4, 5])
    b.flush()                                  # nothing outstanding: no-op
    step = b.dispatch_decode(params, [7], sids=[sid])
    b.flush()                                  # syncs AND commits
    assert step.synced and step.committed
    n = b.table(sid).num_tokens
    b.flush()                                  # second flush: no-op
    b.flush()
    assert b.table(sid).num_tokens == n == 6
    assert b.inflight_steps == 0
    b.release()


# ---------------------------------------------------------------------------
# pool exhaustion with work in flight
# ---------------------------------------------------------------------------

def test_pool_exhaustion_rolls_back_with_pending_step(model):
    """Dispatch first drains the pending commit (capacity can only grow
    between dispatch and commit), then prechecks capacity BEFORE any
    side effect: on exhaustion the pending step's write-back has landed,
    nothing is in flight, and the pool is untouched and serviceable."""
    cfg, params = model
    b = PagedBackend(cfg, num_blocks=5, block_size=4,
                     decode_mode="gather", share_prefixes=False)
    sa, _, _ = b.new_seq(params, list(range(1, 9)))     # 2 blocks
    sb, _, _ = b.new_seq(params, list(range(20, 28)))   # 2 blocks
    step = b.dispatch_decode(params, [5], sids=[sa])    # needs the last
    b.sync(step)                                        # free block
    free0 = b.pool.num_free
    with pytest.raises(RuntimeError, match="pool exhausted"):
        # drains sa's commit (takes the last block), then sb's full tail
        # has nowhere to grow
        b.dispatch_decode(params, [5, 6], sids=[sa, sb])
    assert step.committed and b.table(sa).num_tokens == 9
    assert b.inflight_steps == 0
    assert b.pool.num_free == 0 and free0 == 1
    b.pool.check_invariants()
    b.free_seq(sb)                     # capacity returns; decode resumes
    lg = b.decode(params, [sa], [5])
    assert lg.shape[0] == 1
    b.release()


def test_sharded_exhaustion_is_all_or_nothing(model):
    """Cross-shard capacity precheck runs before ANY shard dispatches:
    when one shard is exhausted, no shard launches and no shard is left
    holding an in-flight step."""
    cfg, params = model
    b = ShardedPagedBackend(cfg, n_shards=2, num_blocks=4, block_size=4,
                            decode_mode="gather")
    sa, _, _ = b.new_seq(params, [1, 2, 3, 4], shard=0)        # 1 block
    sb, _, _ = b.new_seq(params, list(range(20, 28)), shard=1)  # 2 = all
    with pytest.raises(RuntimeError, match="pool exhausted on shard 1"):
        b.dispatch_decode(params, [5, 6], sids=[sa, sb])
    assert b.inflight_steps == 0
    assert all(inner.inflight_steps == 0 for inner in b.backends)
    b.pool.check_invariants()
    lg = b.decode(params, [sa], [5])   # the healthy shard still serves
    assert lg.shape[0] == 1
    b.release()


# ---------------------------------------------------------------------------
# dense backend lifecycle + construction surface
# ---------------------------------------------------------------------------

def test_dense_split_phase_lifecycle(model):
    cfg, params = model
    be = make_backend(cfg, "dense", batch=1, max_seq=16)
    be.prefill(params, jnp.asarray([[1, 2, 3, 4]], jnp.int32))
    with pytest.raises(ValueError, match="sids"):
        be.dispatch_decode(params, jnp.ones((1, 1), jnp.int32),
                           sids=[0])
    step = be.dispatch_decode(params, jnp.ones((1, 1), jnp.int32))
    assert be.inflight_steps == 0      # dense never defers
    lg = be.sync(step)
    assert step.synced and step.committed and lg.shape[0] == 1
    be.commit(step)                    # no-ops, in any order
    be.flush()
    be.release()
    with pytest.raises(RuntimeError, match="released"):
        be.flush()


def test_make_backend_routes_shards(model):
    cfg, _ = model
    b = make_backend(cfg, "paged", shards=2, num_blocks=32, block_size=4,
                     decode_mode="gather")
    assert isinstance(b, ShardedPagedBackend)
    assert b.pool.n_shards == 2
    b.release()
    b1 = make_backend(cfg, "paged", shards=1, num_blocks=16, block_size=4)
    assert isinstance(b1, PagedBackend)
    b1.release()
    with pytest.raises(ValueError, match="devices"):
        make_backend(cfg, "sharded-paged", device="cpu:0")


def test_positional_pool_construction_deprecated(model):
    cfg, _ = model
    donor = PagedBackend(cfg, num_blocks=16, block_size=4)
    pool = donor.pool
    with pytest.warns(DeprecationWarning, match="positionally"):
        b = PagedBackend(cfg, pool)             # lint: ok(positional-pool)
    b.release()
    with pytest.raises(TypeError, match="at most one pool"):
        PagedBackend(cfg, pool, pool=pool)      # lint: ok(positional-pool)
    donor.release()


def test_dense_kv_compat_reads_deprecated(model):
    cfg, params = model
    be = DenseBackend(cfg, 1, 8)
    be.prefill(params, jnp.asarray([[1, 2, 3]], jnp.int32))
    with pytest.warns(DeprecationWarning, match="README"):
        _ = be.k                                # lint: ok(dense-kv-read)
    with pytest.warns(DeprecationWarning, match="README"):
        _ = be.v                                # lint: ok(dense-kv-read)
    be.release()


# ---------------------------------------------------------------------------
# property: flush placement never changes tokens
# ---------------------------------------------------------------------------

if st is not None:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(1, 3),                     # decode steps
           st.sampled_from([1, 2]),               # shard count
           st.sampled_from(["gather", "kernel"]),  # decode mode
           st.integers(0, 10_000))                # flush-placement seed
    def test_flush_placement_never_changes_tokens(n_steps, n_shards,
                                                  decode_mode, seed):
        """flush() is a pure barrier: sprinkling it anywhere in the
        dispatch/sync stream (or nowhere — the next dispatch commits)
        yields exactly the synchronous wrapper's tokens."""
        cfg = configs.get_smoke(ARCH)
        params = lm.init(cfg, jax.random.key(0)).params
        rng = np.random.default_rng(seed)
        prompts = [[int(t) for t in rng.integers(1, cfg.vocab, ln)]
                   for ln in rng.integers(5, 13, size=2)]

        def build():
            if n_shards == 1:
                b = PagedBackend(cfg, num_blocks=32, block_size=4,
                                 decode_mode=decode_mode,
                                 share_prefixes=False)
                sids = [b.new_seq(params, p)[0] for p in prompts]
            else:
                b = ShardedPagedBackend(cfg, n_shards=2, num_blocks=64,
                                        block_size=4,
                                        decode_mode=decode_mode)
                sids = [b.new_seq(params, p, shard=i % 2)[0]
                        for i, p in enumerate(prompts)]
            return b, sids

        ref_b, ref_sids = build()
        pipe_b, pipe_sids = build()
        last_r = last_p = [p[-1] for p in prompts]
        for _ in range(n_steps):
            last_r = _greedy(ref_b.decode(params, ref_sids, last_r))
            if rng.random() < 0.5:
                pipe_b.flush()                   # maybe a pre-barrier
            step = pipe_b.dispatch_decode(params, last_p, sids=pipe_sids)
            lg = pipe_b.sync(step)
            for _ in range(int(rng.integers(0, 3))):
                pipe_b.flush()                   # 0..2 post-barriers
            last_p = _greedy(lg)
            assert last_p == last_r
        pipe_b.flush()
        for rs, ps in zip(ref_sids, pipe_sids):
            assert ref_b.table(rs).num_tokens \
                == pipe_b.table(ps).num_tokens
        ref_b.release()
        pipe_b.release()
else:
    def test_flush_placement_never_changes_tokens():
        pytest.importorskip("hypothesis")
