"""Traffic-class scheduling under overload: SMS staged admission
(per-class quotas, queue depths, latency-first with an aging escape
hatch), decode preemption (pause -> demote -> bitwise resume, single and
sharded, gather and kernel decode), per-class wait accounting, and a
500-step overload soak with the refcount sanitizer attached."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hypothesis is optional in CI
    st = None

from repro import configs
from repro.analysis import refsan
from repro.kvcache import BlockPool, PoolConfig
from repro.kvcache.backend import PagedBackend, ShardedPagedBackend
from repro.models import lm
from repro.serve.engine import PagedLM, ServeEngine
from repro.serving.scheduler import (MarsScheduler, Request, TrafficClass,
                                     default_classes)

ARCH = "qwen1_5_0_5b"


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke(ARCH)
    params = lm.init(cfg, jax.random.key(0)).params
    return cfg, params


def _greedy(logits) -> list:
    return [int(np.argmax(np.asarray(lg, np.float32))) for lg in logits]


def _req(rid, prompt, *, cls="default", arrival=0.0, max_new=4):
    return Request(rid=rid, prompt=tuple(prompt), arrival=arrival,
                   prefix_len=4, max_new=max_new, traffic_class=cls)


def _prefix(i):
    return (i * 10 + 1, i * 10 + 2, i * 10 + 3, i * 10 + 4)


# ---------------------------------------------------------------------------
# SMS stage 2: class-aware batch scheduling policy
# ---------------------------------------------------------------------------

def _classed_sched(**kw):
    return MarsScheduler(classes=(
        TrafficClass("interactive", latency=True),
        TrafficClass("batch", quota=2, max_age=8.0),
    ), **kw)


def test_latency_class_scheduled_ahead_of_older_batch():
    sched = _classed_sched()
    for i in range(3):                       # batch arrives FIRST
        assert sched.offer(_req(i, _prefix(i) + (1,), cls="batch",
                                arrival=0.0))
    assert sched.offer(_req(9, _prefix(9) + (1,), cls="interactive",
                            arrival=1.0))
    out = sched.schedule_batch(8, now=2.0)
    assert [r.rid for r in out][0] == 9
    # stage-1 quota: at most 2 batch admissions rode along
    assert sum(r.traffic_class == "batch" for r in out) == 2
    assert len(sched) == 1                   # the third batch req waits


def test_quota_zero_means_unbounded():
    sched = MarsScheduler(classes=(TrafficClass("bulk", quota=0),))
    for i in range(6):
        assert sched.offer(_req(i, _prefix(i % 2) + (i,), cls="bulk"))
    assert len(sched.schedule_batch(16, now=1.0)) == 6


def test_aging_escape_hatch_beats_latency_first():
    """A batch request older than max_age drains ahead of the latency
    class — SMS's no-starvation bound on bandwidth streams."""
    sched = _classed_sched()
    assert sched.offer(_req(0, _prefix(0) + (1,), cls="batch", arrival=0.0))
    assert sched.offer(_req(1, _prefix(1) + (1,), cls="interactive",
                            arrival=8.5))
    out = sched.schedule_batch(8, now=9.0)   # batch head aged 9.0 >= 8.0
    assert [r.rid for r in out] == [0, 1]


def test_class_queue_depth_backpressure():
    sched = MarsScheduler(classes=(TrafficClass("bulk", queue_depth=2),))
    assert sched._offer(_req(0, _prefix(0) + (1,), cls="bulk")) == (True, "ok")
    assert sched._offer(_req(1, _prefix(1) + (2,), cls="bulk")) == (True, "ok")
    ok, reason = sched._offer(_req(2, _prefix(2) + (3,), cls="bulk"))
    assert (ok, reason) == (False, "class_depth")
    assert sched.class_stats["bulk"].reject == 1


def test_latency_capacity_bounce_raises_preempt_hint():
    pool = BlockPool(PoolConfig(num_blocks=2, block_size=4))
    sched = _classed_sched(pool=pool)
    ok, reason = sched._offer(_req(0, _prefix(0) + (1, 2), cls="batch",
                                   max_new=8))
    assert (ok, reason) == (False, "pool_capacity")
    assert not sched.take_preempt_hint()     # throughput bounce: no hint
    ok, reason = sched._offer(_req(1, _prefix(1) + (1, 2), cls="interactive",
                                   max_new=8))
    assert (ok, reason) == (False, "pool_capacity")
    assert sched.take_preempt_hint()         # latency bounce: hint raised
    assert not sched.take_preempt_hint()     # ...and consumed exactly once


def test_unknown_traffic_class_falls_back_to_default_stream():
    sched = _classed_sched()
    assert sched.offer(_req(0, _prefix(0) + (1,), cls="no-such-class"))
    out = sched.schedule_batch(4, now=1.0)
    assert [r.rid for r in out] == [0]
    assert sched.class_stats["interactive"].admit == 1


# ---------------------------------------------------------------------------
# per-class wait accounting (regression: the old aggregate mean let a
# deferred batch request inflate the interactive latency numbers)
# ---------------------------------------------------------------------------

def test_deferred_batch_wait_cannot_inflate_interactive_histogram():
    sched = _classed_sched()
    assert sched.offer(_req(0, _prefix(0) + (1,), cls="interactive",
                            arrival=0.0))
    assert sched.offer(_req(1, _prefix(1) + (1,), cls="batch", arrival=0.0))
    out = sched.schedule_batch(1, now=1.0)   # budget 1: interactive only
    assert [r.rid for r in out] == [0]
    ih, bh = sched.wait_hist["interactive"], sched.wait_hist["batch"]
    i_p99_before = ih.quantile(0.99)
    i_wait_before = sched.class_stats["interactive"].wait_sum
    # the batch request sits for 99 more fake-clock seconds, then drains
    out = sched.schedule_batch(4, now=100.0)
    assert [r.rid for r in out] == [1]
    # its 100s wait landed in the batch stream only
    assert sched.class_stats["batch"].wait_sum == pytest.approx(100.0)
    assert bh.quantile(0.50) >= 1e4          # ms
    # ...and the interactive stream is untouched, bitwise
    assert ih.quantile(0.99) == i_p99_before
    assert sched.class_stats["interactive"].wait_sum == i_wait_before
    assert sched.class_stats["interactive"].mean_wait == pytest.approx(1.0)
    # the aggregate stays what it always was: a capacity summary over
    # ALL classes (and so it does move)
    assert sched.stats.mean_wait == pytest.approx((1.0 + 100.0) / 2)


# ---------------------------------------------------------------------------
# decode preemption: pause -> demote -> bitwise resume (backend level)
# ---------------------------------------------------------------------------

def _build_pair(cfg, params, prompts, decode_mode, sharded,
                num_blocks=64, **kw):
    """Two identical backends + their sids (control, candidate)."""
    out = []
    for _ in range(2):
        if sharded:
            b = ShardedPagedBackend(cfg, n_shards=2, num_blocks=num_blocks,
                                    block_size=4, decode_mode=decode_mode,
                                    **kw)
            sids = [b.new_seq(params, p, shard=i % 2)[0]
                    for i, p in enumerate(prompts)]
        else:
            kw.setdefault("share_prefixes", False)
            b = PagedBackend(cfg, num_blocks=num_blocks, block_size=4,
                             decode_mode=decode_mode, **kw)
            sids = [b.new_seq(params, p)[0] for p in prompts]
        out.append((b, sids))
    return out


def _step_lanes(params, b, sids, toks, lanes):
    """One committed decode round for the given lane subset."""
    lg = b.decode(params, [sids[i] for i in lanes],
                  [toks[i][-1] for i in lanes])
    for i, t in zip(lanes, _greedy(lg)):
        toks[i].append(t)


@pytest.mark.parametrize("decode_mode", ["gather", "kernel"])
@pytest.mark.parametrize("sharded", [False, True])
def test_pause_resume_round_trip_is_bitwise(model, decode_mode, sharded):
    """pause_seq captures the victim's KV verbatim and releases its blocks
    to evictable cache; resume_seq restores it with zero recompute.  The
    control runs the IDENTICAL decode schedule (same lane sets per round)
    without ever pausing — so any token difference is state the round trip
    failed to preserve."""
    cfg, params = model
    prompts = [list(range(1, 9)), list(range(20, 31))]
    (ctl, ctl_sids), (pre, pre_sids) = _build_pair(
        cfg, params, prompts, decode_mode, sharded)
    toks_c = [list(p) for p in prompts]
    toks_p = [list(p) for p in prompts]
    rounds = [[0, 1], [0, 1]]                # joint warm-up
    for lanes in rounds:
        _step_lanes(params, ctl, ctl_sids, toks_c, lanes)
        _step_lanes(params, pre, pre_sids, toks_p, lanes)
    free0 = pre.pool.num_free + pre.pool.num_cached
    rec = pre.pause_seq(pre_sids[0])
    # the demotion is real: the victim's blocks are reclaimable now
    assert pre.pool.num_free + pre.pool.num_cached > free0
    for lanes in ([1], [1]):                 # survivor decodes alone
        _step_lanes(params, ctl, ctl_sids, toks_c, lanes)
        _step_lanes(params, pre, pre_sids, toks_p, lanes)
    pre_sids[0] = pre.resume_seq(rec)
    for lanes in ([0], [0], [0, 1]):         # catch up, then rejoin
        _step_lanes(params, ctl, ctl_sids, toks_c, lanes)
        _step_lanes(params, pre, pre_sids, toks_p, lanes)
    assert toks_p[0] == toks_c[0]
    assert toks_p[1] == toks_c[1]
    want_num = ctl.table(ctl_sids[0]).num_tokens
    for (b, sids) in ((ctl, ctl_sids), (pre, pre_sids)):
        assert b.table(sids[0]).num_tokens == want_num
        b.pool.check_invariants()
        b.release()


def test_pause_demote_to_tier_resume_promotes(model):
    """The paused sequence's released blocks can spill all the way to the
    host tier under pool pressure; resume promotes them back through
    ``TierManager.match`` and the token stream is still bitwise."""
    cfg, params = model
    prompts = [list(range(1, 9))]
    (ctl, ctl_sids), (pre, pre_sids) = _build_pair(
        cfg, params, prompts, "gather", False, num_blocks=16,
        tiered=True, share_prefixes=True)
    toks_c = [list(prompts[0])]
    toks_p = [list(prompts[0])]
    for _ in range(2):
        _step_lanes(params, ctl, ctl_sids, toks_c, [0])
        _step_lanes(params, pre, pre_sids, toks_p, [0])
    rec = pre.pause_seq(pre_sids[0])
    # pressure: churn big throwaway sequences until eviction demotes the
    # paused blocks out of the pool into the host tier
    for i in range(4):
        filler = list(range(100 + 60 * i, 160 + 60 * i))
        fsid, _, _ = pre.new_seq(params, filler)
        pre.free_seq(fsid)
    demotes = pre.tiers.stats.demotes
    assert demotes > 0, "pressure never demoted the paused blocks"
    promotes0 = pre.tiers.stats.promotes
    pre_sids[0] = pre.resume_seq(rec)
    assert pre.tiers.stats.promotes > promotes0
    for _ in range(2):
        _step_lanes(params, ctl, ctl_sids, toks_c, [0])
        _step_lanes(params, pre, pre_sids, toks_p, [0])
    assert toks_p[0] == toks_c[0]
    pre.pool.check_invariants()
    ctl.release()
    pre.release()


def test_resume_rolls_back_cleanly_on_exhausted_pool(model):
    cfg, params = model
    b = PagedBackend(cfg, num_blocks=6, block_size=4,
                     decode_mode="gather", share_prefixes=False)
    sid, _, _ = b.new_seq(params, list(range(1, 9)))      # 2 blocks
    rec = b.pause_seq(sid)
    hog, _, _ = b.new_seq(params, list(range(20, 40)))    # 5 blocks
    free0, cached0 = b.pool.num_free, b.pool.num_cached
    with pytest.raises(RuntimeError, match="pool exhausted"):
        b.resume_seq(rec)
    assert (b.pool.num_free, b.pool.num_cached) == (free0, cached0)
    b.pool.check_invariants()
    b.free_seq(hog)
    sid2 = b.resume_seq(rec)                 # headroom back: resume works
    assert b.table(sid2).num_tokens == rec["num_tokens"]
    b.release()


# ---------------------------------------------------------------------------
# property: pause/resume placement never changes tokens
# ---------------------------------------------------------------------------

if st is not None:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(1, 3),                    # pause point (decode steps)
           st.integers(1, 2),                    # paused rounds
           st.sampled_from([1, 2]),              # shard count
           st.sampled_from(["gather", "kernel"]),
           st.integers(0, 10_000))               # prompt seed
    def test_pause_placement_never_changes_tokens(pause_at, down, n_shards,
                                                  decode_mode, seed):
        """Wherever the pause lands in the decode stream, however long
        the sequence stays demoted, and whichever shard it lives on, the
        resumed lane's tokens are the never-paused control's, bitwise."""
        cfg = configs.get_smoke(ARCH)
        params = lm.init(cfg, jax.random.key(0)).params
        rng = np.random.default_rng(seed)
        prompts = [[int(t) for t in rng.integers(1, cfg.vocab, ln)]
                   for ln in rng.integers(5, 13, size=2)]
        (ctl, ctl_sids), (pre, pre_sids) = _build_pair(
            cfg, params, prompts, decode_mode, n_shards == 2)
        toks_c = [list(p) for p in prompts]
        toks_p = [list(p) for p in prompts]
        schedule = [[0, 1]] * pause_at + [["pause"]] + [[1]] * down \
            + [["resume"]] + [[0]] * down + [[0, 1]]
        for lanes in schedule:
            if lanes == ["pause"]:
                rec = pre.pause_seq(pre_sids[0])
            elif lanes == ["resume"]:
                pre_sids[0] = pre.resume_seq(rec)
            else:
                _step_lanes(params, ctl, ctl_sids, toks_c, lanes)
                _step_lanes(params, pre, pre_sids, toks_p, lanes)
        assert toks_p == toks_c
        ctl.release()
        pre.release()
else:
    def test_pause_placement_never_changes_tokens():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# engine-level preemption under overload (LM driver)
# ---------------------------------------------------------------------------

def _lm_engine(cfg, params, *, shards, num_blocks, max_lanes=4,
               classes=None):
    if shards > 1:
        backend = ShardedPagedBackend(cfg, n_shards=shards,
                                      num_blocks=num_blocks, block_size=16,
                                      decode_mode="gather")
    else:
        backend = PagedBackend(cfg, num_blocks=num_blocks, block_size=16,
                               decode_mode="gather")
    pool = backend.pool
    sched = MarsScheduler(pool=pool, classes=classes)
    eng = ServeEngine(pool, sched, PagedLM(params, cfg, backend),
                      max_lanes=max_lanes)
    return eng, sched


def _solo_tokens(cfg, params, prompt, max_new):
    """The request served alone on an uncontended engine — the bitwise
    reference for whatever batching/preemption the overloaded run did."""
    eng, _ = _lm_engine(cfg, params, shards=1, num_blocks=64)
    got = eng.run([_req(0, prompt, max_new=max_new)])
    eng.model.backend.release()
    return got[0][0]


@pytest.mark.parametrize("shards", [1, 2])
def test_engine_preempts_batch_and_stays_bitwise(model, shards):
    """Overload with long batch decodes resident: interactive arrivals
    bounce on capacity, the engine pauses a batch decode (freeing its
    blocks), serves the interactive burst, resumes the victim — and every
    request's tokens equal its solo, never-preempted run."""
    cfg, params = model
    # 8 blocks total (4 per shard when sharded): the two resident batch
    # decodes hold 6, so the interactive burst must bounce on capacity
    eng, sched = _lm_engine(cfg, params, shards=shards, num_blocks=8,
                            classes=default_classes(2))
    batch = [_req(i, _prefix(i) + tuple(range(5, 25)), cls="batch",
                  arrival=0.0, max_new=24) for i in range(2)]
    chat = [_req(10 + i, _prefix(6) + (40 + i,), cls="interactive",
                 arrival=2.0, max_new=4) for i in range(6)]
    pending = batch + chat
    for step in range(400):
        now = float(step)
        pending = [r for r in pending
                   if r.arrival > now or not eng.submit(r)]
        eng.step(now=now)
        if not pending and not eng.running and not eng.paused \
                and not len(sched):
            break
    else:
        pytest.fail("overloaded engine did not drain")
    assert sched.class_stats["batch"].preempt >= 1
    assert eng.paused == []
    for r in batch + chat:
        want = _solo_tokens(cfg, params, r.prompt, r.max_new)
        assert eng.finished[r.rid] == [want], f"rid {r.rid} diverged"
    eng.pool.check_invariants()
    eng.model.backend.release()


def test_preemption_is_noop_without_latency_pressure(model):
    """Same engine, no interactive traffic: nothing is ever paused."""
    cfg, params = model
    eng, sched = _lm_engine(cfg, params, shards=1, num_blocks=16,
                            classes=default_classes(2))
    reqs = [_req(i, _prefix(i % 3) + (i,), cls="batch", max_new=4)
            for i in range(6)]
    eng.run(reqs)
    assert all(cs.preempt == 0 for cs in sched.class_stats.values())
    eng.model.backend.release()


# ---------------------------------------------------------------------------
# overload soak: 500 mixed-class steps at ~2x pool capacity
# ---------------------------------------------------------------------------

def test_overload_soak_no_starvation_latency_ordering():
    """Sustained 2x-capacity mixed traffic through the toy engine with the
    refcount sanitizer shadowing every pool op: every offered request
    either serves or rejects with a named reason (no silent starvation),
    pool invariants hold throughout, and the class-aware scheduler keeps
    interactive p99 under batch p99 on the fake clock."""
    pool = BlockPool(PoolConfig(num_blocks=32, block_size=4,
                                n_kv_heads=2, head_dim=64))
    sched = MarsScheduler(pool=pool, classes=default_classes(3))
    eng = ServeEngine(pool, sched, max_lanes=4)
    san = refsan.attach(pool)
    rng = np.random.default_rng(7)
    spec = {"interactive": (1, 2), "batch": (8, 10), "stream": (4, 6)}
    arrivals, outcomes = {}, {}
    rid = 0
    steps = 500
    try:
        for step in range(steps + 200):      # 500 offered + drain tail
            now = float(step)
            if step < steps:
                for cls in ("interactive", "batch", "interactive",
                            "stream")[: 2 + step % 3]:
                    tail, max_new = spec[cls]
                    prompt = _prefix(int(rng.integers(0, 4))) \
                        + tuple(int(t) for t in rng.integers(50, 99, tail))
                    r = _req(rid, prompt, cls=cls, arrival=now,
                             max_new=max_new)
                    ok, reason = sched._offer(r)
                    arrivals[rid] = (now, cls)
                    if ok:
                        outcomes[rid] = "accepted"
                    else:
                        assert reason in ("queue_full", "class_depth",
                                          "pool_capacity", "page_ways")
                        outcomes[rid] = reason
                    rid += 1
            eng.step(now=now)
            for fid in eng.finished:
                if outcomes.get(fid) == "accepted":
                    outcomes[fid] = ("served", now)
            if step % 8 == 0:
                pool.check_invariants()
                assert san.findings == [], \
                    [f.msg for f in san.findings[:5]]
            if step >= steps and not eng.running \
                    and not len(sched):
                break
        else:
            pytest.fail("soak did not drain after offers stopped")
        # no starvation: every accepted request was served
        stuck = [r for r, o in outcomes.items() if o == "accepted"]
        assert stuck == [], f"{len(stuck)} accepted requests never served"
        assert rid > 800                     # the load was real...
        rejected = sum(1 for o in outcomes.values() if isinstance(o, str))
        assert rejected > 100                # ...and actually overloaded
        lat = {"interactive": [], "batch": []}
        for r, o in outcomes.items():
            _, cls = arrivals[r]
            if isinstance(o, tuple) and cls in lat:
                lat[cls].append(o[1] - arrivals[r][0])
        assert len(lat["interactive"]) > 50 and len(lat["batch"]) > 50
        assert np.percentile(lat["interactive"], 99) \
            < np.percentile(lat["batch"], 99)
        san.check(quiesced=True)             # nothing leaked
    finally:
        san.detach()
    pool.check_invariants()
