"""HLO collective-accounting parser: synthetic-module ground truth."""
import numpy as np

from repro.utils import hlo

_MODULE = """
HloModule jit_step, entry_computation_layout={()->()}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%loop_body.2 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg = (s32[], f32[128,256]) parameter(0)
  %x = f32[128,256] get-tuple-element(%arg), index=1
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add.1
  ROOT %t = (s32[], f32[128,256]) tuple(%x, %ar)
}

%loop_cond.3 (arg: (s32[], f32[128,256])) -> pred[] {
  %arg = (s32[], f32[128,256]) parameter(0)
  ROOT %p = pred[] constant(false)
}

ENTRY %main.4 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  %ag = f32[256,256]{1,0} all-gather(%p0), dimensions={0}
  %init = (s32[], f32[128,256]) tuple(s32[] constant(0), %p0)
  %w = (s32[], f32[128,256]) while(%init), condition=%loop_cond.3, body=%loop_body.2, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_loop_body_collectives_weighted_by_trip_count():
    res = hlo.collective_bytes(_MODULE)
    # all-gather in entry: 256*256*4 bytes, once
    assert res["all-gather"] == 256 * 256 * 4
    assert res["all-gather_count"] == 1
    # all-reduce inside the while body: 128*256*4 bytes x 10 trips
    assert res["all-reduce"] == 128 * 256 * 4 * 10
    assert res["all-reduce_count"] == 10
    assert res["total"] == res["all-gather"] + res["all-reduce"]


def test_shape_bytes_tuple_and_dtypes():
    assert hlo._shape_bytes("bf16[4,8]") == 64
    assert hlo._shape_bytes("(f32[2,2], s8[16])") == 32
    assert hlo._shape_bytes("pred[]") == 1   # scalar: dims empty


def test_execution_counts_entry_is_one():
    counts, entry = hlo._execution_counts(_MODULE)
    assert counts[entry] == 1
    assert counts["loop_body.2"] == 10


def test_no_collectives_module():
    res = hlo.collective_bytes("ENTRY %m () -> f32[] {\n"
                               "  ROOT %c = f32[] constant(1)\n}")
    assert res["total"] == 0
