"""Protocol sanitizer suite self-tests: lint rules against the fixture
corpus (and the shipped tree), the decode-pipeline race detector in both
in-process and trace-replay modes, and the refcount sanitizer."""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import lint
from repro.analysis.races import (Ev, analyze_trace, check_history,
                                  interleavings, shard_chain)
from repro.analysis import refsan
from repro.kvcache.pool import BlockPool, PoolConfig
from repro.kvcache.sharded_pool import ShardedBlockPool

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "lint_fixtures")

# rule -> (bad fixture, ok fixture, relpath the fixture pretends to be at)
CORPUS = {
    "pool-kv-mutation": ("bad_pool_mutation.py", "ok_pool_mutation.py", None),
    "flush-barrier": ("bad_flush_barrier.py", "ok_flush_barrier.py", None),
    "pallas-fetch-gate": ("bad_pallas_gate.py", "ok_pallas_gate.py", None),
    "positional-pool": ("bad_positional_pool.py", "ok_positional_pool.py",
                        None),
    "dense-kv-read": ("bad_dense_read.py", "ok_dense_read.py", None),
    "drain-dirty-consumer": ("bad_drain_dirty.py", "ok_drain_dirty.py",
                             "src/repro/fake/{name}"),
}


def _lint_fixture(name, rel_tmpl):
    path = os.path.join(FIXTURES, name)
    rel = rel_tmpl.format(name=name) if rel_tmpl else path
    return lint.lint_file(path, rel)


# ---------------------------------------------------------------------------
# lint: fixture corpus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", sorted(CORPUS))
def test_bad_fixture_trips_exactly_its_rule(rule):
    bad, _, rel = CORPUS[rule]
    findings = _lint_fixture(bad, rel)
    assert findings, f"{bad} should trip {rule}"
    assert {f.rule for f in findings} == {rule}
    # findings are anchored: real line numbers and a str() rendering a
    # CI annotation can point at
    for f in findings:
        assert f.line > 0
        assert f"[{rule}]" in str(f)


@pytest.mark.parametrize("rule", sorted(CORPUS))
def test_ok_fixture_is_clean(rule):
    _, ok, rel = CORPUS[rule]
    assert _lint_fixture(ok, rel) == []


def test_every_rule_has_corpus_coverage():
    assert set(CORPUS) == set(lint.RULES)


def test_suppression_pragma_silences_one_rule():
    src = "def f(pool, bid):\n    pool.dirty.discard(bid)\n"
    assert len(lint.lint_source(src, "x.py")) == 1
    ok = ("def f(pool, bid):\n"
          "    pool.dirty.discard(bid)  # lint: ok(pool-kv-mutation)\n")
    assert lint.lint_source(ok, "x.py") == []
    wrong = ("def f(pool, bid):\n"
             "    pool.dirty.discard(bid)  # lint: ok(dense-kv-read)\n")
    assert len(lint.lint_source(wrong, "x.py")) == 1


def test_shipped_tree_lints_clean():
    findings = lint.lint_paths(["src", "tests"], ROOT)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_json_summary_and_exit_codes(tmp_path):
    out = tmp_path / "lint.json"
    # the bad corpus through the CLI: nonzero exit + machine-readable
    # summary (bench --json conventions)
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "lint.py"),
         os.path.join(FIXTURES, "bad_positional_pool.py"),
         "--json", str(out)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    summary = json.loads(out.read_text())
    assert summary["ok"] is False
    assert summary["counts"] == {"positional-pool": 2}
    assert all({"path", "line", "col", "rule", "msg"} <= set(f)
               for f in summary["findings"])
    # clean input: exit 0, ok summary
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "lint.py"),
         os.path.join(FIXTURES, "ok_positional_pool.py"),
         "--json", str(out)],
        capture_output=True, text=True)
    assert proc.returncode == 0
    assert json.loads(out.read_text())["ok"] is True


# ---------------------------------------------------------------------------
# race detector: in-process interleaving exploration
# ---------------------------------------------------------------------------

def test_legal_chains_accept_every_interleaving():
    c0, c1 = shard_chain(0, 2), shard_chain(1, 2)
    n = 0
    for il in interleavings(c0, c1):
        n += 1
        assert check_history(il) == []
    # C(16, 8): both chains' relative orders preserved, all merges seen
    assert n == 12870


def test_seeded_commit_before_sync_caught_in_every_interleaving():
    c0, c1 = shard_chain(0, 2), shard_chain(1, 1)
    mut = list(c0)
    si = next(i for i, e in enumerate(mut)
              if e.kind == "sync" and e.step == 1)
    ci = next(i for i, e in enumerate(mut)
              if e.kind == "commit" and e.step == 1)
    mut[si], mut[ci] = mut[ci], mut[si]
    seen = 0
    for il in interleavings(mut, c1):
        seen += 1
        codes = {v.code for v in check_history(il)}
        assert "commit-before-sync" in codes
    assert seen > 100


def test_seeded_fork_without_flush_caught_in_every_interleaving():
    c0, c1 = shard_chain(0, 2), shard_chain(1, 1)
    mut = list(c0)
    si = next(i for i, e in enumerate(mut)
              if e.kind == "sync" and e.step == 1)
    mut.insert(si, Ev("fork", 0))        # fork lands mid-step: no barrier
    for il in interleavings(mut, c1):
        codes = {v.code for v in check_history(il)}
        assert "barrier-missed" in codes


def test_barrier_between_steps_is_legal():
    evs = shard_chain(0, 1) + [Ev("fork", 0), Ev("free", 0)] \
        + [Ev("dispatch", 0, 1), Ev("sync", 0, 1), Ev("commit", 0, 1)]
    assert check_history(evs) == []


def test_double_dispatch_and_lag_exceeded():
    evs = [Ev("dispatch", 0, 0), Ev("dispatch", 0, 1)]
    assert {v.code for v in check_history(evs)} >= {"double-dispatch"}
    evs = [Ev("dispatch", 0, 0), Ev("sync", 0, 0), Ev("dispatch", 0, 1),
           Ev("sync", 0, 1)]
    assert any(v.code == "lag-exceeded" for v in check_history(evs))


def test_lost_commit_flagged_at_stream_end():
    evs = [Ev("dispatch", 0, 0), Ev("sync", 0, 0)]
    assert [v.code for v in check_history(evs)] == ["lost-commit"]


def test_pause_between_steps_is_legal_in_every_interleaving():
    """A drained pause (+ its resume barrier) slots anywhere between
    committed steps; cross-shard merges cannot make it a violation."""
    c0 = shard_chain(0, 1) + [Ev("pause", 0), Ev("resume", 0)] \
        + [Ev("dispatch", 0, 1), Ev("sync", 0, 1), Ev("commit", 0, 1)]
    c1 = shard_chain(1, 1)
    n = 0
    for il in interleavings(c0, c1):
        n += 1
        assert check_history(il) == []
    assert n > 100


@pytest.mark.parametrize("where", ["inflight", "pending"])
def test_seeded_pause_inside_pipeline_caught_everywhere(where):
    """pause before the flush barrier: with the step still in flight
    (dispatch->sync window) or its write-back still deferred
    (sync->commit window), block demotion races the device — the
    detector's dedicated preempt-during-dispatch code, in EVERY
    interleaving with an innocent shard."""
    mut = list(shard_chain(0, 2))
    if where == "inflight":
        at = next(i for i, e in enumerate(mut)
                  if e.kind == "sync" and e.step == 1)
    else:
        at = next(i for i, e in enumerate(mut)
                  if e.kind == "commit" and e.step == 1)
    mut.insert(at, Ev("pause", 0))
    for il in interleavings(mut, shard_chain(1, 1)):
        codes = {v.code for v in check_history(il)}
        assert "preempt-during-dispatch" in codes
        assert "barrier-missed" not in codes     # pause has its OWN code


def test_resume_is_a_flush_barrier():
    evs = [Ev("dispatch", 0, 0), Ev("sync", 0, 0), Ev("resume", 0)]
    assert any(v.code == "barrier-missed" for v in check_history(evs))


def test_issue_then_gather_round_ordering():
    good = [Ev("dispatch", 0, 0, round=0), Ev("dispatch", 1, 0, round=0),
            Ev("sync", 0, 0, round=0), Ev("sync", 1, 0, round=0),
            Ev("commit", 0, 0), Ev("commit", 1, 0)]
    assert check_history(good) == []
    # shard 0 gathered before shard 1's kernel was issued
    bad = [Ev("dispatch", 0, 0, round=0), Ev("sync", 0, 0, round=0),
           Ev("dispatch", 1, 0, round=0), Ev("sync", 1, 0, round=0),
           Ev("commit", 0, 0), Ev("commit", 1, 0)]
    assert any(v.code == "gather-before-issue"
               for v in check_history(bad))


# ---------------------------------------------------------------------------
# race detector: trace replay
# ---------------------------------------------------------------------------

def _trace(steps=3, shard=0, t0=0):
    """A legal pipelined TraceLog slice: commit of step k emitted at
    dispatch of step k+1 (the one-step lag), token after each sync."""
    evs, ts = [], t0
    for k in range(steps):
        if k > 0:
            evs.append({"ts": ts, "ev": "backend.commit", "shard": shard,
                        "step": k - 1})
            ts += 1
        evs.append({"ts": ts, "ev": "backend.dispatch", "shard": shard,
                    "step": k}); ts += 1
        evs.append({"ts": ts, "ev": "backend.decode", "shard": shard,
                    "step": k, "dur_us": 1}); ts += 2
        evs.append({"ts": ts, "ev": "engine.token", "rid": 0}); ts += 1
    evs.append({"ts": ts, "ev": "backend.commit", "shard": shard,
                "step": steps - 1})
    return evs


def _lines(evs):
    return [json.dumps(e) for e in evs]


def test_replay_accepts_legal_pipelined_trace():
    report = analyze_trace(_lines(_trace()), require_pipeline=True)
    assert report.ok, [v.msg for v in report.violations]
    assert report.stats["lag_tokens"] >= 1
    assert json.loads(report.to_json())["ok"] is True


def test_replay_catches_timestamp_level_commit_before_sync():
    evs = _trace()
    sync1 = next(e for e in evs if e["ev"] == "backend.decode"
                 and e["step"] == 1)
    commit1 = next(e for e in evs if e["ev"] == "backend.commit"
                   and e["step"] == 1)
    commit1["ts"] = sync1["ts"] - 1      # write-back ahead of its logits
    report = analyze_trace(_lines(evs), require_pipeline=True)
    assert any(v.code == "commit-before-sync" for v in report.violations)


def test_replay_catches_prefill_inside_undrained_pipeline():
    evs = _trace()
    sync1 = next(e for e in evs if e["ev"] == "backend.decode"
                 and e["step"] == 1)
    evs.append({"ts": sync1["ts"] + 1, "ev": "backend.prefill",
                "shard": 0, "dur_us": 0})
    report = analyze_trace(_lines(evs))
    assert any(v.code == "barrier-missed" for v in report.violations)


def test_replay_tolerates_ring_buffer_truncation():
    evs = _trace(steps=4)
    # ring overflow dropped the head: stream starts mid-step
    report = analyze_trace(_lines(evs[4:]), require_pipeline=True)
    assert report.ok, [v.msg for v in report.violations]


def test_replay_require_pipeline_distinguishes_off_from_sequential():
    # no dispatch events at all -> pipeline never ran
    report = analyze_trace(_lines([{"ts": 0, "ev": "engine.token",
                                    "rid": 0}]), require_pipeline=True)
    assert [v.code for v in report.violations] == ["no-pipeline"]
    # dispatches but every token outside the sync->commit window ->
    # write-back never lagged
    evs = []
    ts = 0
    for k in range(2):
        evs.append({"ts": ts, "ev": "backend.dispatch", "shard": 0,
                    "step": k}); ts += 1
        evs.append({"ts": ts, "ev": "backend.decode", "shard": 0,
                    "step": k, "dur_us": 1}); ts += 1
        evs.append({"ts": ts, "ev": "backend.commit", "shard": 0,
                    "step": k}); ts += 1
        evs.append({"ts": ts, "ev": "engine.token", "rid": 0}); ts += 1
    report = analyze_trace(_lines(evs), require_pipeline=True)
    assert [v.code for v in report.violations] == ["no-lag"]


def test_replay_accepts_legal_pause_resume_trace():
    """The engine's preemption flow as it lands in a real trace: flush
    drained the pipeline (commit emitted) BEFORE backend.pause, the
    bitwise restore is a backend.resume barrier, decode continues."""
    evs = _trace(steps=2)
    ts = evs[-1]["ts"] + 1
    evs.append({"ts": ts, "ev": "backend.pause", "shard": 0, "sid": 3})
    evs.append({"ts": ts + 1, "ev": "backend.resume", "shard": 0})
    evs.append({"ts": ts + 2, "ev": "backend.dispatch", "shard": 0,
                "step": 2})
    evs.append({"ts": ts + 3, "ev": "backend.decode", "shard": 0,
                "step": 2, "dur_us": 1})
    evs.append({"ts": ts + 4, "ev": "engine.token", "rid": 0})
    evs.append({"ts": ts + 5, "ev": "backend.commit", "shard": 0,
                "step": 2})
    report = analyze_trace(_lines(evs), require_pipeline=True)
    assert report.ok, [v.msg for v in report.violations]


def test_replay_catches_pause_before_write_back_commit():
    """Seeded violation: a backend.pause stamped inside the sync->commit
    window — the demoted blocks would race the deferred KV write-back."""
    evs = _trace(steps=3)
    sync1 = next(e for e in evs if e["ev"] == "backend.decode"
                 and e["step"] == 1)
    evs.append({"ts": sync1["ts"] + 1, "ev": "backend.pause", "shard": 0})
    report = analyze_trace(_lines(evs))
    assert any(v.code == "preempt-during-dispatch"
               for v in report.violations)
    assert "flush barrier" in next(
        v.msg for v in report.violations
        if v.code == "preempt-during-dispatch")


def test_replay_two_shard_trace():
    evs = _trace(steps=3, shard=0) + _trace(steps=3, shard=1, t0=1000)
    report = analyze_trace(_lines(evs), require_pipeline=True)
    assert report.ok
    assert report.stats["shards"] == 2


# ---------------------------------------------------------------------------
# refcount sanitizer
# ---------------------------------------------------------------------------

def _pool(n=16, bs=4):
    return BlockPool(PoolConfig(num_blocks=n, block_size=bs))


def test_refsan_clean_on_legal_lifecycle():
    pool = _pool()
    san = refsan.attach(pool)
    a = pool.alloc(3)
    pool.incref(a[0])
    pool.decref(a[0])
    pool.decref(a[0], cache=True)        # -> cached
    pool.reuse_cached(a[0])              # prefix hit revives it
    for bid in a:
        pool.decref(bid)
    san.check(quiesced=True)             # no findings, no leaks
    san.detach()
    pool.check_invariants()


def test_refsan_catches_double_free():
    pool = _pool()
    san = refsan.attach(pool)
    (bid,) = pool.alloc(1)
    pool.decref(bid)                     # freed
    pool._free_block(bid)                # seeded double-free
    kinds = [f.kind for f in san.findings]
    assert "double-free" in kinds
    san.detach()


def test_refsan_catches_use_after_free_by_id_reuse():
    pool = _pool(n=4)
    san = refsan.attach(pool)
    (stale,) = pool.alloc(1)
    pool.decref(stale)                   # freed; holder keeps the id
    (fresh,) = pool.alloc(1)             # id recycled to a new owner
    assert fresh == stale
    pool.decref(fresh)                   # new owner finishes with it
    pool.touch(stale)                    # stale holder pokes the dead slot
    f = next(f for f in san.findings if f.kind == "use-after-free")
    assert "reuse" in f.msg              # provenance names the recycling
    assert f.gen == 2                    # two generations lived in this slot
    san.detach()


def test_refsan_catches_write_to_freed_block():
    import numpy as np
    pool = BlockPool(PoolConfig(num_blocks=4, block_size=2,
                                n_kv_heads=1, head_dim=2, n_layers=1))
    san = refsan.attach(pool)
    (bid,) = pool.alloc(1)
    pool.decref(bid)
    kv = np.zeros((1, 2, 1, 2))
    pool.write_kv(bid, 0, kv, kv)        # seeded UAF write
    assert any(f.kind == "use-after-free" and f.op == "write_kv"
               for f in san.findings)
    with pytest.raises(AssertionError, match="freed block"):
        san.check()
    san.detach()


def test_refsan_reports_leaks_with_alloc_provenance():
    pool = _pool()
    san = refsan.attach(pool)
    pool.alloc(2)                        # never freed
    rep = san.report(quiesced=True)
    assert not rep["ok"]
    leaks = [f for f in rep["findings"] if f["kind"] == "leak"]
    assert len(leaks) == 2
    assert all("test_analysis.py" in f["history"] for f in leaks)
    san.detach()


def test_refsan_detach_restores_methods():
    pool = _pool()
    san = refsan.attach(pool)
    assert pool.alloc.__name__ == "refsan_alloc"
    san.detach()
    assert pool.alloc.__name__ == "alloc"
    pool.decref(pool.alloc(1)[0])        # plain pool still works


def test_refsan_attaches_per_shard_on_sharded_pool():
    sp = ShardedBlockPool(PoolConfig(num_blocks=16, block_size=4),
                          n_shards=2)
    san = refsan.attach(sp)
    a = sp.shards[0].alloc(2)
    sp.shards[1].alloc(1)
    for bid in a:
        sp.shards[0].decref(bid)
    rep = san.report(quiesced=True)
    leaks = [f for f in rep["findings"] if f["kind"] == "leak"]
    assert len(leaks) == 1               # the shard-1 block
    san.detach()
