"""Observability layer: registry/facade semantics, trace spans, the
incremental open-row model vs the DRAM reference, shard load snapshots,
the O(dirty) incremental pool sweep, and the Observer end-to-end."""
import json

import numpy as np
import pytest

from repro.core import dram
from repro.kernels.paged_attention import ops
from repro.kvcache.pool import BlockPool, PoolConfig, PoolStats
from repro.kvcache.prefix import BlockTable
from repro.kvcache.sharded_pool import ShardedBlockPool
from repro.obs import (Counter, Histogram, MetricsRegistry, Observer,
                       OpenRowCounter, StatGroup, TraceLog,
                       shard_load_snapshot)
from repro.serve.engine import ServeEngine
from repro.serving.scheduler import MarsScheduler, Request


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_is_monotonic():
    c = Counter()
    c.inc(); c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 3


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    assert reg.counter("a.b") is reg.counter("a.b")
    with pytest.raises(TypeError):
        reg.gauge("a.b")
    with pytest.raises(TypeError):
        reg.histogram("a.b")


def test_histogram_bucket_edges_and_quantiles():
    h = Histogram(edges=(1.0, 2.0, 4.0))
    for v in [0.5] * 50 + [3.0] * 50:
        h.observe(v)
    assert h.counts == [50, 0, 50, 0]
    # p50 sits at the top of the first bucket (0..1), p99 interpolates
    # inside the (2..4] bucket: 2 + 2 * (99-50)/50
    assert h.quantile(0.50) == pytest.approx(1.0)
    assert h.quantile(0.99) == pytest.approx(3.96)
    # an exact edge value lands in the bucket it bounds (bisect_left)
    h2 = Histogram(edges=(1.0, 2.0))
    h2.observe(2.0)
    assert h2.counts == [0, 1, 0]


def test_histogram_overflow_clamps_to_last_edge():
    h = Histogram(edges=(1.0, 2.0))
    h.observe(100.0)
    assert h.counts[-1] == 1
    assert h.quantile(0.99) == 2.0
    snap = h.to_snapshot()
    assert snap["count"] == 1 and snap["sum"] == 100.0


def test_snapshot_is_deterministic_across_insertion_order():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("x.one", 2); a.set("y.g", 0.25); a.observe("z.h", 1.5)
    b.observe("z.h", 1.5); b.inc("x.one", 2); b.set("y.g", 0.25)
    assert json.dumps(a.snapshot(), sort_keys=True) == \
        json.dumps(b.snapshot(), sort_keys=True)


def test_adopt_aliases_the_live_counters():
    class S(StatGroup):
        FIELDS = {"allocs": 0}
    reg = MetricsRegistry()
    s = S()
    reg.adopt("pool", s)
    s.allocs += 3
    assert reg.snapshot()["counters"]["pool.allocs"] == 3
    reg.adopt("pool", s)                      # idempotent
    with pytest.raises(ValueError):           # same name, different group
        reg.adopt("pool", S())


def test_statgroup_facade_keeps_dataclass_ergonomics():
    s = PoolStats(allocs=2)
    assert s.allocs == 2 and s.frees == 0
    s.evictions += 5
    assert s.as_dict()["evictions"] == 5
    assert s == PoolStats(allocs=2, evictions=5)
    assert "evictions=5" in repr(s)
    assert set(s.fields()) == set(PoolStats.FIELDS)
    with pytest.raises(TypeError):
        PoolStats(bogus=1)
    with pytest.raises(AttributeError):
        s.bogus = 1
    with pytest.raises(AttributeError):
        s.bogus


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------

def _fake_clock(step_us: float = 10.0):
    t = [0.0]

    def clk():
        t[0] += step_us * 1e-6
        return t[0]
    return clk


def test_trace_spans_nest_and_time_deterministically():
    t = TraceLog(clock=_fake_clock())
    with t.span("outer") as sp:
        sp["k"] = 1
        t.event("point", rid=7)
        with t.span("inner"):
            pass
    evs = t.events()
    assert [e["ev"] for e in evs] == ["outer", "point", "inner"]
    outer, point, inner = evs
    assert outer["depth"] == 0 and inner["depth"] == 1
    assert outer["k"] == 1 and point["rid"] == 7
    # fake clock ticks 10us per read: spans carry entry ts + duration
    assert outer["ts"] < point["ts"] < inner["ts"]
    assert outer["dur_us"] > inner["dur_us"] > 0


def test_trace_ring_drops_oldest_and_counts():
    t = TraceLog(capacity=4, clock=_fake_clock())
    for i in range(6):
        t.event("e", i=i)
    assert t.total == 6 and t.dropped == 2
    assert [e["i"] for e in t.events()] == [2, 3, 4, 5]


def test_trace_flush_appends_jsonl_and_clears(tmp_path):
    t = TraceLog(clock=_fake_clock())
    t.event("a"); t.event("b")
    path = str(tmp_path / "trace.jsonl")
    assert t.flush(path) == 2
    assert t.events() == []
    t.event("c")
    assert t.flush(path) == 1
    lines = [json.loads(l) for l in open(path)]
    assert [e["ev"] for e in lines] == ["a", "b", "c"]
    assert all(isinstance(e["ts"], int) for e in lines)


# ---------------------------------------------------------------------------
# incremental open-row model vs the DRAM reference
# ---------------------------------------------------------------------------

def _churned_tables(placement="mars", num_blocks=256, n_live=12, seed=0):
    """Fragment a pool realistically, return (pool, live decode tables)."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(PoolConfig(num_blocks=num_blocks, placement=placement))
    live = []

    def start():
        t = BlockTable()
        for _ in range(int(rng.integers(2, 7))):
            t.blocks.append(pool.alloc(1, hint_blocks=t.blocks)[0])
        t.num_tokens = len(t.blocks) * pool.cfg.block_size
        live.append(t)

    for _ in range(200):
        if len(live) >= n_live or (live and rng.random() < 0.5):
            for b in live.pop(int(rng.integers(len(live)))).blocks:
                pool.decref(b)
        else:
            start()
    while len(live) < n_live:
        start()
    return pool, live


def _sim_hit_rate(trace) -> float:
    res = dram.simulate(trace)
    return 1.0 - res.n_act / max(res.n_requests, 1)


def test_inorder_model_matches_dram_on_kernel_walk():
    """On the kernel decode path's sequence-major page walk the stream has
    no interleaving left for FR-FCFS lookahead to exploit, so the O(n)
    in-order model must match the full windowed controller replay —
    this is what licenses the live gauge (pinned to within 0.1%)."""
    pool, tables = _churned_tables()
    trace = np.asarray(ops.kv_read_trace_kernel(
        tables, block_size=pool.cfg.block_size))
    rc = OpenRowCounter()
    for i in range(0, len(trace), 173):      # incremental, odd chunking
        rc.observe(trace[i:i + 173])
    assert rc.served == len(trace)
    assert abs(rc.row_hit_rate - _sim_hit_rate(trace)) < 1e-3


def test_inorder_model_is_chunking_invariant():
    pool, tables = _churned_tables(seed=3)
    trace = np.asarray(ops.kv_read_trace_kernel(
        tables, block_size=pool.cfg.block_size))
    one = OpenRowCounter(); one.observe(trace)
    chunked = OpenRowCounter()
    for i in range(0, len(trace), 7):
        chunked.observe(trace[i:i + 7])
    assert (one.hits, one.served) == (chunked.hits, chunked.served)


def test_windowed_model_matches_dram_on_interleaved_trace():
    """The gather path's round-robin interleave is where in-order and
    FR-FCFS genuinely diverge; the windowed replay mode must still
    reproduce the controller's hit accounting exactly."""
    pool, tables = _churned_tables(seed=1)
    trace = np.asarray(ops.kv_read_trace(tables, grant_beats=4))
    inorder = OpenRowCounter(); inorder.observe(trace)
    win = OpenRowCounter(window=int(dram.DramConfig().window))
    for i in range(0, len(trace), 61):
        win.observe(trace[i:i + 61])
    win.drain()
    assert win.served == len(trace)
    assert win.row_hit_rate == pytest.approx(_sim_hit_rate(trace), abs=1e-9)
    # and lookahead really buys hits on this trace
    assert win.row_hit_rate > inorder.row_hit_rate


def test_rowsim_rejects_bad_window_and_handles_empty():
    with pytest.raises(ValueError):
        OpenRowCounter(window=0)
    rc = OpenRowCounter()
    rc.observe(np.empty(0, np.int64))
    assert rc.row_hit_rate == 0.0 and rc.served == 0


# ---------------------------------------------------------------------------
# shard load snapshot
# ---------------------------------------------------------------------------

def test_shard_load_snapshot_single_pool():
    pool = BlockPool(PoolConfig(num_blocks=16, block_size=4))
    pool.alloc(3)
    pool.reserve(2)
    reg = MetricsRegistry()
    (row,) = shard_load_snapshot(pool, reg)
    assert row == {"shard": 0, "blocks": 16, "live": 3, "cached": 0,
                   "free": 13, "reserved": 2, "load": 5, "headroom": 11,
                   "occupancy": 3 / 16}
    g = reg.snapshot()["gauges"]
    assert g["pool.shard0.load"] == 5
    assert g["pool.shard0.occupancy"] == pytest.approx(3 / 16)


def test_shard_load_snapshot_headroom_is_can_reserve():
    sp = ShardedBlockPool(PoolConfig(num_blocks=32, block_size=4),
                          n_shards=2)
    sp.shards[0].alloc(5)
    sp.shards[1].reserve(3)
    rows = shard_load_snapshot(sp)
    assert [r["shard"] for r in rows] == [0, 1]
    for row, shard in zip(rows, sp.shards):
        # the headroom column is definitionally the reservation capacity
        assert shard.can_reserve(row["headroom"])
        assert not shard.can_reserve(row["headroom"] + 1)
        assert row["load"] == shard.num_live + shard.reserved


# ---------------------------------------------------------------------------
# incremental pool invariants (--paranoid)
# ---------------------------------------------------------------------------

def test_incremental_sweep_is_o_dirty_and_clears():
    pool = BlockPool(PoolConfig(num_blocks=32, block_size=4))
    bids = pool.alloc(4)
    assert set(bids) <= pool._meta_dirty
    pool.check_invariants(incremental=True)
    assert not pool._meta_dirty               # consumed by the sweep
    pool.decref(bids[0])
    assert pool._meta_dirty == {bids[0]}      # only the touched block
    pool.check_invariants(incremental=True)
    pool.check_invariants()                   # full sweep still clean


def test_incremental_sweep_catches_planted_corruption():
    pool = BlockPool(PoolConfig(num_blocks=32, block_size=4))
    bids = pool.alloc(2)
    pool.check_invariants(incremental=True)
    pool.refcount[bids[1]] = 0                # live block, refcount zeroed
    pool._meta_dirty.add(bids[1])
    with pytest.raises(AssertionError):
        pool.check_invariants(incremental=True)
    pool.refcount[bids[1]] = 1                # repair; sweep passes again
    pool._meta_dirty.add(bids[1])
    pool.check_invariants(incremental=True)


def test_incremental_sweep_catches_aggregate_drift():
    pool = BlockPool(PoolConfig(num_blocks=16, block_size=4))
    pool.alloc(2)
    pool.used[5] = True                       # used without leaving free
    with pytest.raises(AssertionError):
        pool.check_invariants(incremental=True)


# ---------------------------------------------------------------------------
# Observer end-to-end (toy engine)
# ---------------------------------------------------------------------------

class _RecObserver(Observer):
    """Observer that also records every kv walk it is fed, so tests can
    replay the exact concatenated stream through ``dram.simulate``."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.walks = []

    def observe_kv_walk(self, shard, addrs):
        self.walks.append(np.asarray(addrs))
        super().observe_kv_walk(shard, addrs)


def _toy_served(obs_cls=Observer, **obs_kw):
    pool = BlockPool(PoolConfig(num_blocks=96, block_size=16,
                                n_kv_heads=2, head_dim=32))
    eng = ServeEngine(pool, MarsScheduler(pool=pool), max_lanes=4)
    obs = obs_cls(**obs_kw).attach(eng)
    rng = np.random.default_rng(0)
    pref = tuple(int(t) for t in rng.integers(1, 100, 20))
    reqs = [Request(rid=i,
                    prompt=pref + tuple(int(t) for t in
                                        rng.integers(1, 100, 3)),
                    arrival=i * 1e-3, prefix_len=16, max_new=5,
                    n_samples=3 if i == 2 else 1)
            for i in range(8)]
    out = eng.run(reqs)
    assert sorted(out) == list(range(8))
    return eng, obs


def test_observer_live_row_gauge_matches_dram_replay():
    """The ISSUE parity gate: the running row-hit gauge (incremental
    in-order model, open rows carried across steps) must agree with a
    ``dram.simulate`` replay of the concatenated per-step kernel walks
    to within 0.1%."""
    eng, obs = _toy_served(_RecObserver, paranoid=True, paranoid_every=2)
    gauge = obs.registry.gauge("dram.row_hit_pct").value
    replay = 100.0 * _sim_hit_rate(np.concatenate(obs.walks))
    assert abs(gauge - replay) < 0.1
    assert obs.registry.counter("dram.kv_lines").value == \
        sum(len(w) for w in obs.walks)


def test_observer_snapshot_aliases_component_stats():
    eng, obs = _toy_served()
    snap = obs.snapshot()
    c, g, h = snap["counters"], snap["gauges"], snap["histograms"]
    # adopted counters read the very numbers the components hold
    assert c["engine.decode_tokens"] == eng.stats.decode_tokens == 10 * 5
    assert c["engine.prefill_tokens"] == eng.stats.prefill_tokens
    assert c["pool.allocs"] == eng.pool.stats.allocs
    assert c["sched.scheduled"] == eng.scheduler.stats.scheduled == 8
    assert h["engine.step_ms"]["count"] == eng.stats.steps
    assert h["engine.step_ms"]["p50"] <= h["engine.step_ms"]["p99"]
    assert 0.0 <= g["kvcache.prefix_hit_rate"] <= 1.0
    assert g["kvcache.prefix_hit_rate"] > 0   # the shared prefix was hit
    assert snap["trace"]["events"] == obs.trace.total
    assert snap["trace"]["dropped"] == 0


def test_observer_trace_reconstructs_request_lifecycle():
    eng, obs = _toy_served()
    evs = [e for e in obs.trace.events() if e.get("rid") == 2]
    names = [e["ev"] for e in evs]
    order = [names.index(k) for k in ("sched.offer", "engine.admit",
                                      "engine.prefill", "engine.token",
                                      "engine.free")]
    assert order == sorted(order)
    assert names.count("engine.token") == 3 * 5      # 3 forks x 5 tokens
    assert names.count("engine.free") == 3
    prefill = next(e for e in evs if e["ev"] == "engine.prefill")
    assert prefill["lanes"] == 3 and prefill["dur_us"] >= 0


def test_observer_off_leaves_no_trace_hooks():
    """Uninstrumented serving must not grow any obs state (the hot-path
    contract: one attribute test when obs is None)."""
    pool = BlockPool(PoolConfig(num_blocks=96, block_size=16,
                                n_kv_heads=2, head_dim=32))
    eng = ServeEngine(pool, MarsScheduler(pool=pool), max_lanes=4)
    assert eng.obs is None and pool.obs is None
    eng.run([Request(rid=0, prompt=tuple(range(1, 20)), prefix_len=16,
                     max_new=3)])
    assert eng.obs is None
    assert eng.stats.decode_tokens == 3
