"""Serving scheduler coverage (page-major order, starvation, pool-capacity
admission) and the continuous-batching engine over the paged pool."""
import numpy as np
import pytest

from repro.kvcache import BlockPool, PoolConfig
from repro.serve.engine import ServeEngine
from repro.serving.scheduler import MarsScheduler, Request


def _req(rid, prompt, max_new=4, arrival=None):
    return Request(rid=rid, prompt=tuple(prompt),
                   arrival=rid * 1e-3 if arrival is None else arrival,
                   prefix_len=4, max_new=max_new)


def _prefix(i):
    return (i * 1000 + 1, i * 1000 + 2, i * 1000 + 3, i * 1000 + 4)


# ---------------------------------------------------------------------------
# scheduler: page-major batch order
# ---------------------------------------------------------------------------

def test_batches_are_page_major_oldest_first():
    sched = MarsScheduler(mars=True)
    # interleaved arrivals: pages 0,1,2,0,1,2,...
    reqs = [_req(i, _prefix(i % 3) + (100 + i,)) for i in range(12)]
    for r in reqs:
        assert sched.offer(r)
    batch = sched.schedule_batch(8, now=1.0)
    pages = [r.page for r in batch]
    # page-major: each page appears as one contiguous run
    runs = [p for i, p in enumerate(pages) if i == 0 or pages[i - 1] != p]
    assert len(runs) == len(set(pages))
    # oldest page first, FIFO within a page
    assert batch[0].page == reqs[0].page
    rids = [r.rid for r in batch if r.page == reqs[0].page]
    assert rids == sorted(rids)


def test_no_starvation_under_adversarial_arrival():
    """A lone cold request must not wait forever while a hot page keeps
    refilling (oldest-page-first drains to exhaustion, then moves on)."""
    sched = MarsScheduler(mars=True)
    hot = 0
    cold = _req(999, _prefix(7) + (5,))
    assert sched.offer(_req(hot, _prefix(1) + (hot,))); hot += 1
    assert sched.offer(cold)
    waited = 0
    for _ in range(50):
        # adversary: keep the hot page full
        for _ in range(4):
            sched.offer(_req(hot, _prefix(1) + (hot,))); hot += 1
        batch = sched.schedule_batch(4, now=1.0)
        assert batch
        if any(r.rid == 999 for r in batch):
            break
        waited += 1
    else:
        pytest.fail("cold request starved")
    assert waited <= 2   # bounded delay: scheduled once its page is oldest


# ---------------------------------------------------------------------------
# scheduler: pool-capacity admission
# ---------------------------------------------------------------------------

def test_pool_admission_bounds_accepts():
    pool = BlockPool(PoolConfig(num_blocks=16, block_size=4))
    sched = MarsScheduler(pool=pool)
    # each request needs ceil((6 + 4)/4) = 3 blocks -> only 5 fit
    reqs = [_req(i, _prefix(i) + (1, 2)) for i in range(10)]
    accepted = [r for r in reqs if sched.offer(r)]
    assert len(accepted) == 5
    assert sched.stats.pool_rejects == 5
    assert pool.reserved == 15
    # reservations outlive scheduling: the engine converts them into real
    # allocations as sequences grow and releases the rest at finish
    batch = sched.schedule_batch(8, now=1.0)
    assert len(batch) == 5 and pool.reserved == 15


def test_admission_accounts_live_blocks():
    pool = BlockPool(PoolConfig(num_blocks=16, block_size=4))
    pool.alloc(12)                      # live KV already in the pool
    sched = MarsScheduler(pool=pool)
    assert sched.offer(_req(0, _prefix(0) + (1, 2)))     # needs 3: fits
    assert not sched.offer(_req(1, _prefix(1) + (1, 2)))  # needs 3 more: no
    assert sched.stats.pool_rejects == 1


# ---------------------------------------------------------------------------
# engine: continuous batching end-to-end
# ---------------------------------------------------------------------------

def _engine(num_blocks=96, max_lanes=4):
    pool = BlockPool(PoolConfig(num_blocks=num_blocks, block_size=16,
                                n_kv_heads=2, head_dim=32))
    return ServeEngine(pool, MarsScheduler(pool=pool), max_lanes=max_lanes)


def test_engine_serves_all_and_frees_everything():
    rng = np.random.default_rng(0)
    pref = tuple(rng.integers(1, 100, 20).tolist())
    reqs = [_req(i, pref + tuple(rng.integers(1, 100, 3).tolist()),
                 max_new=5) for i in range(12)]
    eng = _engine()
    out = eng.run(reqs)
    assert sorted(out) == list(range(12))
    assert all(len(v[0]) == 5 for v in out.values())
    eng.pool.check_invariants()
    assert eng.pool.num_live == 0
    assert eng.pool.stats.prefix_hits > 0       # shared prompt prefix


def test_engine_prefix_sharing_is_transparent():
    """Served tokens are identical with and without a cache-warm pool."""
    prompt = tuple(range(1, 25))
    cold = _engine().run([_req(0, prompt, max_new=6)])
    warm_eng = _engine()
    warm = warm_eng.run([_req(0, prompt, max_new=6),
                         _req(1, prompt, max_new=6)])
    assert warm_eng.pool.stats.prefix_hits > 0
    assert cold[0] == warm[0] == warm[1]


def test_engine_forks_cow_and_diverge():
    r = Request(rid=0, prompt=tuple(range(1, 20)), prefix_len=4,
                max_new=5, n_samples=3)
    eng = _engine()
    out = eng.run([r])
    assert len(out[0]) == 3
    assert len({tuple(t) for t in out[0]}) == 3  # salts diverge the samples
    assert eng.pool.stats.cow_copies > 0         # forked tails were CoW'd
    eng.pool.check_invariants()
    assert eng.pool.num_live == 0


def test_engine_reservation_covers_lazy_decode_blocks():
    """Admission must not over-commit: blocks a running sequence will
    allocate mid-decode stay reserved until it finishes (regression for a
    crash where reservations were dropped at schedule time)."""
    pool = BlockPool(PoolConfig(num_blocks=4, block_size=16,
                                n_kv_heads=2, head_dim=32))
    eng = ServeEngine(pool, MarsScheduler(pool=pool), max_lanes=4)
    a = _req(0, tuple(range(100, 116)), max_new=18)   # needs 3 blocks
    b = _req(1, tuple(range(200, 215)), max_new=16)   # needs 2 blocks
    out = eng.run([a, b])
    assert sorted(out) == [0, 1]
    pool.check_invariants()
    assert pool.reserved == 0 and pool.num_live == 0


def test_engine_fork_reservation_counts_every_sample():
    """n_samples multiplies the worst-case block need at admission
    (regression for a mid-decode pool-exhausted crash on forks)."""
    # needs 2 blocks x 3 samples = 6 > 3: rejected up front, clean error
    pool = BlockPool(PoolConfig(num_blocks=3, block_size=16,
                                n_kv_heads=2, head_dim=32))
    eng = ServeEngine(pool, MarsScheduler(pool=pool), max_lanes=4)
    r = Request(rid=0, prompt=tuple(range(1, 17)), max_new=4, n_samples=3)
    with pytest.raises(RuntimeError, match="needs 6 blocks"):
        eng.run([r])
    # exactly enough capacity: must serve all forks without exhaustion
    pool = BlockPool(PoolConfig(num_blocks=6, block_size=16,
                                n_kv_heads=2, head_dim=32))
    eng = ServeEngine(pool, MarsScheduler(pool=pool), max_lanes=4)
    out = eng.run([Request(rid=0, prompt=tuple(range(1, 17)), max_new=4,
                           n_samples=3)])
    assert len(out[0]) == 3
    pool.check_invariants()
    assert pool.reserved == 0 and pool.num_live == 0


def test_engine_lane_budget_counts_forked_samples():
    """running lanes never exceed max_lanes even when requests fan out
    into n_samples forks (regression: forks used to multiply the batch)."""
    eng = _engine(num_blocks=96, max_lanes=4)
    reqs = [Request(rid=i, prompt=tuple(range(10 * i + 1, 10 * i + 17)),
                    max_new=4, n_samples=4) for i in range(4)]
    for r in reqs:
        assert eng.submit(r)
    for step_i in range(200):
        eng.step(now=float(step_i))
        assert len(eng.running) <= 4
        if not eng.running and not len(eng.scheduler):
            break
    assert sorted(eng.finished) == [0, 1, 2, 3]
    # a fan-out wider than the lane budget can never run: clean error
    eng = _engine(max_lanes=2)
    with pytest.raises(RuntimeError, match="max_lanes"):
        eng.run([Request(rid=9, prompt=tuple(range(1, 17)), max_new=2,
                         n_samples=3)])


def test_step_is_noop_when_idle():
    """No active sequences and nothing queued: step() must not run an
    empty prefill/decode round (scheduler stats untouched, 0 returned)."""
    eng = _engine()
    for _ in range(3):
        assert eng.step(now=1.0) == 0
    assert eng.stats.steps == 0 and eng.stats.prefills == 0
    assert eng.scheduler.stats.batches == 0
    assert eng.scheduler.stats.scheduled == 0


def test_stats_count_prefill_and_decode_tokens_separately():
    reqs = [_req(i, _prefix(i) + tuple(range(10 + i, 16)), max_new=3)
            for i in range(4)]
    eng = _engine()
    eng.run(reqs)
    assert eng.stats.prefill_tokens == sum(len(r.prompt) for r in reqs)
    assert eng.stats.decode_tokens == 4 * 3


def test_token_accounting_counts_forked_lanes_once_each():
    """The commit-path contract: prompt tokens count once per *request*
    (forks share the prefill), decode tokens once per *sequence stepped*
    — so a 3-sample fork contributes 3x max_new decode tokens."""
    reqs = [_req(0, _prefix(0) + (7, 8), max_new=4),
            Request(rid=1, prompt=_prefix(1) + (9, 10), arrival=1e-3,
                    prefix_len=4, max_new=4, n_samples=3)]
    eng = _engine()
    out = eng.run(reqs)
    assert len(out[1]) == 3
    assert eng.stats.prefills == 2
    assert eng.stats.prefill_tokens == sum(len(r.prompt) for r in reqs)
    assert eng.stats.decode_tokens == (1 + 3) * 4


# ---------------------------------------------------------------------------
# engine: real multi-layer LM through PagedBackend
# ---------------------------------------------------------------------------

def _lm_engine(num_blocks=96, max_lanes=3, block_size=8,
               decode_mode="gather", f32=False):
    import dataclasses
    import jax
    from repro import configs
    from repro.kvcache.backend import PagedBackend
    from repro.models import lm
    from repro.serve.engine import PagedLM

    cfg = configs.get_smoke("qwen1_5_0_5b")
    if f32:
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
    params = lm.init(cfg, jax.random.key(0)).params
    backend = PagedBackend(cfg, num_blocks=num_blocks,
                           block_size=block_size, decode_mode=decode_mode)
    eng = ServeEngine(backend.pool, MarsScheduler(pool=backend.pool),
                      PagedLM(params, cfg, backend), max_lanes=max_lanes)
    return eng, cfg, params


def test_engine_real_lm_matches_dense_greedy():
    """Continuous-batched paged serving of a real 2-layer config must emit
    exactly the dense backend's greedy tokens, lane for lane (gather-path
    decode: bit-identical math to the dense backend)."""
    import jax.numpy as jnp
    from repro.serve.step import greedy_generate

    eng, cfg, params = _lm_engine()
    rng = np.random.default_rng(3)
    shared = tuple(int(t) for t in rng.integers(1, cfg.vocab, 16))
    prompts = [shared + tuple(int(t) for t in rng.integers(1, cfg.vocab, 2))
               for _ in range(6)]
    reqs = [Request(rid=i, prompt=p, arrival=i * 1e-3, prefix_len=8,
                    max_new=4) for i, p in enumerate(prompts)]
    out = eng.run(reqs)
    assert sorted(out) == list(range(6))
    assert eng.pool.stats.prefix_hits > 0      # storage-shared hot prefix
    for i, p in enumerate(prompts):
        want = greedy_generate(params, cfg, jnp.asarray([p], jnp.int32),
                               4, max_seq=len(p) + 5)
        assert out[i][0] == list(np.asarray(want[0])), f"lane {i} diverged"
    eng.pool.check_invariants()
    assert eng.pool.num_live == 0 and eng.pool.reserved == 0


def test_engine_real_lm_kernel_decode_matches_dense_greedy():
    """Kernel-path decode (per-layer Pallas paged_attention over the pool)
    through the full engine loop must emit exactly the dense backend's
    greedy tokens in f32 compute — the tentpole invariant end-to-end."""
    import jax.numpy as jnp
    from repro.serve.step import greedy_generate

    eng, cfg, params = _lm_engine(decode_mode="kernel", f32=True)
    assert eng.use_kernel
    rng = np.random.default_rng(5)
    shared = tuple(int(t) for t in rng.integers(1, cfg.vocab, 16))
    prompts = [shared + tuple(int(t) for t in rng.integers(1, cfg.vocab, 2))
               for _ in range(4)]
    reqs = [Request(rid=i, prompt=p, arrival=i * 1e-3, prefix_len=8,
                    max_new=3) for i, p in enumerate(prompts)]
    out = eng.run(reqs)
    assert sorted(out) == list(range(4))
    for i, p in enumerate(prompts):
        want = greedy_generate(params, cfg, jnp.asarray([p], jnp.int32),
                               3, max_seq=len(p) + 4)
        assert out[i][0] == list(np.asarray(want[0])), f"lane {i} diverged"
    eng.pool.check_invariants()
    assert eng.pool.num_live == 0 and eng.pool.reserved == 0


def test_engine_real_lm_forks_cow_and_diverge():
    eng, cfg, _ = _lm_engine()
    r = Request(rid=0, prompt=tuple(range(1, 20)), prefix_len=8,
                max_new=4, n_samples=3)
    out = eng.run([r])
    assert len(out[0]) == 3
    assert len({tuple(t) for t in out[0]}) == 3  # salts diverge the samples
    assert eng.pool.stats.cow_copies > 0         # forked tails were CoW'd
    eng.pool.check_invariants()
    assert eng.pool.num_live == 0


@pytest.mark.parametrize("decode_mode", ["gather", "kernel"])
def test_token_accounting_identical_across_decode_modes(decode_mode):
    """Regression pin for the prefill/decode token split: both decode
    paths (dense gather and Pallas kernel), forks included, must account
    exactly sum(prompts) prefill tokens and lanes x max_new decode
    tokens — the single ``_commit_token`` path counts per sequence
    stepped, never per batch or per backend call."""
    eng, cfg, _ = _lm_engine(decode_mode=decode_mode)
    rng = np.random.default_rng(7)
    prompts = [tuple(int(t) for t in rng.integers(1, cfg.vocab, 10 + i))
               for i in range(3)]
    reqs = [Request(rid=i, prompt=p, arrival=i * 1e-3, prefix_len=8,
                    max_new=4, n_samples=2 if i == 1 else 1)
            for i, p in enumerate(prompts)]
    out = eng.run(reqs)
    assert sorted(out) == [0, 1, 2] and len(out[1]) == 2
    lanes = sum(r.n_samples for r in reqs)
    assert eng.stats.prefills == len(reqs)
    assert eng.stats.prefill_tokens == sum(len(p) for p in prompts)
    assert eng.stats.decode_tokens == lanes * 4
    assert all(len(t) == 4 for lane in out.values() for t in lane)
    eng.pool.check_invariants()


def test_engine_backpressure_tiny_pool():
    """More requests than the pool fits at once: admission defers, engine
    drains, everything is eventually served exactly once."""
    rng = np.random.default_rng(1)
    reqs = [_req(i, tuple(rng.integers(1, 50, 18).tolist()), max_new=4)
            for i in range(10)]
    eng = _engine(num_blocks=12, max_lanes=3)
    out = eng.run(reqs)
    assert sorted(out) == list(range(10))
    assert eng.scheduler.stats.pool_rejects > 0
    eng.pool.check_invariants()
    assert eng.pool.num_live == 0
