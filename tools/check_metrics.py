"""Schema validator for ``launch/serve.py --metrics`` output (CI obs-smoke).

``python tools/check_metrics.py metrics_out/metrics.json \
        metrics_out/trace.jsonl``

Checks, all offline:

  * the snapshot is valid JSON with the three metric sections plus trace
    meta, and the headline serving metrics exist with sane values:
    ``dram.row_hit_pct`` in [0, 100], ``pool.shardN.occupancy`` in
    [0, 1] for every shard, ``kvcache.prefix_hit_rate`` in [0, 1], and
    an ``engine.step_ms`` histogram with count > 0 and p50 <= p99;
  * counters are non-negative, and a served run actually counted work
    (``engine.decode_tokens`` > 0);
  * every trace line parses as one JSON event with integer ``ts`` and
    string ``ev``, timestamps non-decreasing;
  * at least one request's timeline reconstructs admit -> free: a rid
    with ``sched.offer``, ``engine.admit``, ``engine.prefill``,
    ``engine.token`` and ``engine.free`` events in timestamp order;
  * tiered-KV telemetry, when present (always with ``--require-tiers``,
    the CI tiered-serve smoke's mode): ``tier.shardN.<tier>.occupancy``
    gauges in [0, 1], ``tier.promote_row_hit_pct`` in [0, 100], tier
    counters non-negative; and the demote -> promote -> decode lifecycle
    in the trace — every ``tier.promote`` key was demoted earlier on the
    same shard (tiers start empty, so promotion without a prior demotion
    is a bookkeeping bug), and a ``backend.decode`` follows a promotion
    (promoted pages re-enter decode through the staged mirror);
  * traffic-class telemetry (``--require-classes``, the CI overloaded
    ``--classes 3`` serve smoke's mode): the ``class.<name>.*`` counter
    catalogue exists for >= 2 classes, per-class admission quotas were
    respected in every ``sched.batch`` event (``classes[c] <=
    quotas[c]`` whenever the quota is non-zero), overload actually
    preempted at least one decode (``engine.pause`` present, preempt
    counters > 0), and each rid's pause/resume events strictly
    alternate starting with a pause (``backend.pause`` additionally
    feeds the ``preempt-during-dispatch`` check under
    ``--require-pipeline``);
  * split-phase decode-pipeline telemetry (``--require-pipeline``, the
    CI pipelined-serve smoke's mode): the
    ``engine.{dispatch,sync,commit}_ms`` phase histograms counted work
    and the ``backend.inflight_steps`` gauge exists; the trace ordering
    itself (dispatch -> sync -> commit per shard, one-step write-back
    lag, ≥1 token between a sync and its commit) is replayed through the
    ``repro.analysis.races`` happens-before checker — the same model the
    in-process interleaving tests explore.

Exits non-zero listing every violation.
"""
from __future__ import annotations

import json
import os
import sys

# CI invokes this script bare (no PYTHONPATH=src); the pipeline checks
# live in repro.analysis.races, so bootstrap the import path ourselves
_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.analysis import races  # noqa: E402

# per-rid lifecycle, in required timeline order
LIFECYCLE = ("sched.offer", "engine.admit", "engine.prefill",
             "engine.token", "engine.free")
# per-prefix-key tier lifecycle, in required timeline order
TIER_LIFECYCLE = ("tier.demote", "tier.promote")


def check_tier_snapshot(snap: dict, require_tiers: bool) -> list:
    """Tiered-KV metric catalogue: validated whenever tier gauges are
    present; with ``require_tiers`` they must be present and the run must
    have actually spilled (demotes and promotes both counted)."""
    bad = []
    gauges = snap.get("gauges", {})
    counters = snap.get("counters", {})
    occ = [n for n in gauges
           if n.startswith("tier.shard") and n.endswith(".occupancy")]
    if require_tiers and not occ:
        bad.append("snapshot: --require-tiers but no "
                   "tier.shardN.<tier>.occupancy gauges")
    for name in occ:
        v = gauges[name]
        if not 0.0 <= v <= 1.0:
            bad.append(f"snapshot: {name} out of range: {v}")
        blocks = gauges.get(name.replace(".occupancy", ".blocks"))
        if blocks is None or blocks < 0:
            bad.append(f"snapshot: {name} has no matching non-negative "
                       ".blocks gauge")
    for name in [n for n in gauges if n.endswith("promote_row_hit_pct")]:
        v = gauges[name]
        if not 0.0 <= v <= 100.0:
            bad.append(f"snapshot: {name} out of range: {v}")
    if require_tiers:
        for field in ("demotes", "promotes"):
            total = sum(v for n, v in counters.items()
                        if n.startswith("tier.shard")
                        and n.endswith(f".{field}"))
            if total <= 0:
                bad.append(f"snapshot: --require-tiers but no tier "
                           f"{field} counted (spill never triggered — "
                           "shrink the pool or add prefixes)")
    return bad


def check_snapshot(snap: dict) -> list:
    bad = []

    def need(section: str, name: str):
        v = snap.get(section, {}).get(name)
        if v is None:
            bad.append(f"snapshot: missing {section[:-1]} {name!r}")
        return v

    for section in ("counters", "gauges", "histograms", "trace"):
        if section not in snap:
            bad.append(f"snapshot: missing section {section!r}")
    rh = need("gauges", "dram.row_hit_pct")
    if rh is not None and not 0.0 <= rh <= 100.0:
        bad.append(f"snapshot: dram.row_hit_pct out of range: {rh}")
    for name in ("kvcache.prefix_hit_rate", "kvcache.eviction_rate"):
        v = need("gauges", name)
        if v is not None and not 0.0 <= v <= 1.0:
            bad.append(f"snapshot: {name} out of range: {v}")
    occ = [n for n in snap.get("gauges", {})
           if n.startswith("pool.shard") and n.endswith(".occupancy")]
    if not occ:
        bad.append("snapshot: no pool.shardN.occupancy gauges")
    for name in occ:
        v = snap["gauges"][name]
        if not 0.0 <= v <= 1.0:
            bad.append(f"snapshot: {name} out of range: {v}")
    hist = need("histograms", "engine.step_ms")
    if hist is not None:
        if hist.get("count", 0) <= 0:
            bad.append("snapshot: engine.step_ms histogram is empty")
        if hist.get("p50", 0.0) > hist.get("p99", 0.0):
            bad.append(f"snapshot: engine.step_ms p50 {hist['p50']} > "
                       f"p99 {hist['p99']}")
    for name, v in snap.get("counters", {}).items():
        if v < 0:
            bad.append(f"snapshot: counter {name} is negative: {v}")
    if snap.get("counters", {}).get("engine.decode_tokens", 0) <= 0:
        bad.append("snapshot: engine.decode_tokens == 0 (nothing served?)")
    return bad


def check_trace(lines: list) -> list:
    bad = []
    events = []
    last_ts = None
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            bad.append(f"trace line {i}: not JSON ({e})")
            continue
        if not isinstance(ev.get("ts"), int) or ev["ts"] < 0:
            bad.append(f"trace line {i}: bad ts {ev.get('ts')!r}")
            continue
        if not isinstance(ev.get("ev"), str):
            bad.append(f"trace line {i}: bad ev {ev.get('ev')!r}")
            continue
        if last_ts is not None and ev["ts"] < last_ts:
            bad.append(f"trace line {i}: ts went backwards "
                       f"({last_ts} -> {ev['ts']})")
        last_ts = ev["ts"]
        events.append(ev)
    if not events:
        bad.append("trace: no events")
        return bad
    # one request must reconstruct its full admit->free timeline
    by_rid: dict = {}
    for ev in events:
        if "rid" in ev:
            by_rid.setdefault(ev["rid"], []).append(ev)
    complete = 0
    for rid, evs in by_rid.items():
        stages = [min(e["ts"] for e in evs if e["ev"] == k)
                  for k in LIFECYCLE
                  if any(e["ev"] == k for e in evs)]
        if len(stages) == len(LIFECYCLE) and stages == sorted(stages):
            complete += 1
    if complete == 0:
        bad.append("trace: no rid reconstructs the full "
                   f"{' -> '.join(LIFECYCLE)} timeline")
    return bad


def check_tier_trace(lines: list, require_tiers: bool) -> list:
    """Demote -> promote -> decode lifecycle ordering over the tier
    events (keys are the ``TierManager`` prefix tags; shard-local by
    construction, so ordering is checked per (shard, key))."""
    bad = []
    events = []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            ev = json.loads(line)
        except json.JSONDecodeError:
            continue                 # check_trace already reported it
        events.append(ev)
    demoted: dict = {}               # (shard, key) -> first demote ts
    promotes = []
    for ev in events:
        if ev.get("ev") == "tier.demote":
            demoted.setdefault((ev.get("shard"), ev.get("key")),
                               ev["ts"])
        elif ev.get("ev") == "tier.promote":
            promotes.append(ev)
            k = (ev.get("shard"), ev.get("key"))
            if k not in demoted:
                bad.append(f"trace: tier.promote of key {ev.get('key')} "
                           f"on shard {ev.get('shard')} with no earlier "
                           "tier.demote (tiers start empty)")
            elif demoted[k] > ev["ts"]:
                bad.append(f"trace: tier.demote of key {ev.get('key')} "
                           f"at ts {demoted[k]} after its promote at "
                           f"ts {ev['ts']}")
        elif ev.get("ev") == "tier.stall":
            if ev.get("us", 0) < 0 or ev.get("blocks", 0) <= 0:
                bad.append(f"trace: malformed tier.stall {ev}")
    if require_tiers:
        if not demoted:
            bad.append("trace: --require-tiers but no tier.demote events")
        if not promotes:
            bad.append("trace: --require-tiers but no tier.promote events")
    if promotes:
        # a promoted page re-enters decode through the staged mirror: at
        # least one backend.decode on the promoting shard after the
        # promotion
        first = min(p["ts"] for p in promotes)
        shards = {p.get("shard") for p in promotes}
        if not any(ev.get("ev") == "backend.decode"
                   and ev.get("shard") in shards and ev["ts"] >= first
                   for ev in events):
            bad.append("trace: no backend.decode follows any tier.promote "
                       "(promotion never reached a decode batch)")
    return bad


def check_class_snapshot(snap: dict) -> list:
    """Per-traffic-class metric catalogue: counters for >= 2 classes,
    non-negative, with at least one preemption counted (the overloaded
    smoke must actually have triggered the pause path)."""
    bad = []
    counters = snap.get("counters", {})
    classes = {n.split(".")[1] for n in counters
               if n.startswith("class.") and n.count(".") >= 2}
    if len(classes) < 2:
        bad.append("snapshot: --require-classes but fewer than 2 "
                   f"class.<name>.* counter groups found ({sorted(classes)})")
    for c in sorted(classes):
        for field in ("admit", "reject", "defer", "preempt", "scheduled"):
            if f"class.{c}.{field}" not in counters:
                bad.append(f"snapshot: class {c} missing counter {field}")
    total_admit = sum(v for n, v in counters.items()
                      if n.startswith("class.") and n.endswith(".admit"))
    if total_admit <= 0:
        bad.append("snapshot: --require-classes but no class admissions "
                   "counted")
    total_preempt = sum(v for n, v in counters.items()
                        if n.startswith("class.")
                        and n.endswith(".preempt"))
    if total_preempt <= 0:
        bad.append("snapshot: --require-classes but no preemption counted "
                   "(overload never triggered the pause path — raise "
                   "--requests or shrink --pool-blocks)")
    return bad


def check_class_trace(lines: list) -> list:
    """Traffic-class trace ordering: per-batch quota respected, at least
    one ``engine.pause``, and each rid's pause/resume events strictly
    alternate starting with a pause (a resume without its pause, or two
    pauses back to back, is lost-sequence bookkeeping)."""
    bad = []
    events = []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            continue                 # check_trace already reported it
    pauses = 0
    state: dict = {}                 # rid -> "paused" | "running"
    for ev in events:
        name = ev.get("ev")
        if name == "sched.batch":
            classes = ev.get("classes", {})
            quotas = ev.get("quotas", {})
            for c, n in classes.items():
                q = quotas.get(c, 0)
                if q and n > q:
                    bad.append(f"trace: sched.batch admitted {n} of class "
                               f"{c} past its quota {q}")
        elif name == "engine.pause":
            pauses += 1
            rid = ev.get("rid")
            if state.get(rid) == "paused":
                bad.append(f"trace: rid {rid} paused twice without a "
                           "resume in between")
            state[rid] = "paused"
        elif name == "engine.resume":
            rid = ev.get("rid")
            if state.get(rid) != "paused":
                bad.append(f"trace: rid {rid} resumed without a "
                           "preceding pause")
            state[rid] = "running"
    if pauses == 0:
        bad.append("trace: --require-classes but no engine.pause events "
                   "(overload never preempted a decode)")
    return bad


def check_pipeline_snapshot(snap: dict) -> list:
    """Split-phase engine telemetry: the three phase histograms counted
    work and the pipeline-depth gauge exists."""
    bad = []
    for name in ("engine.dispatch_ms", "engine.sync_ms",
                 "engine.commit_ms"):
        hist = snap.get("histograms", {}).get(name)
        if hist is None or hist.get("count", 0) <= 0:
            bad.append(f"snapshot: --require-pipeline but {name} "
                       "histogram missing or empty")
    depth = snap.get("gauges", {}).get("backend.inflight_steps")
    if depth is None:
        bad.append("snapshot: --require-pipeline but no "
                   "backend.inflight_steps gauge")
    elif not 0 <= depth <= 2:
        bad.append(f"snapshot: backend.inflight_steps out of range: "
                   f"{depth}")
    return bad


def check_pipeline_trace(lines: list) -> list:
    """Split-phase decode lifecycle ordering, delegated to the
    happens-before checker in ``repro.analysis.races``: per shard, every
    step's dispatch precedes its sync, its commit lands after the sync
    and before the next dispatch (one-step write-back lag), prefill only
    enters a drained pipeline, and at least one ``engine.token`` lands
    strictly between a sync and its commit — the engine sampled a token
    whose KV write-back was still deferred.
    """
    report = races.analyze_trace(lines, require_pipeline=True)
    return [f"trace: {v.msg}" for v in report.violations]


def main(argv: list) -> int:
    require_tiers = "--require-tiers" in argv
    require_pipeline = "--require-pipeline" in argv
    require_classes = "--require-classes" in argv
    argv = [a for a in argv
            if a not in ("--require-tiers", "--require-pipeline",
                         "--require-classes")]
    if len(argv) != 2:
        print("usage: check_metrics.py <metrics.json> <trace.jsonl> "
              "[--require-tiers] [--require-pipeline] [--require-classes]",
              file=sys.stderr)
        return 2
    snap_path, trace_path = argv
    failures = []
    try:
        snap = json.load(open(snap_path, encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        failures.append(f"{snap_path}: unreadable ({e})")
        snap = None
    if snap is not None:
        failures.extend(check_snapshot(snap))
        failures.extend(check_tier_snapshot(snap, require_tiers))
        if require_classes:
            failures.extend(check_class_snapshot(snap))
        if require_pipeline:
            failures.extend(check_pipeline_snapshot(snap))
    try:
        lines = open(trace_path, encoding="utf-8").readlines()
    except OSError as e:
        failures.append(f"{trace_path}: unreadable ({e})")
        lines = None
    if lines is not None:
        failures.extend(check_trace(lines))
        failures.extend(check_tier_trace(lines, require_tiers))
        if require_classes:
            failures.extend(check_class_trace(lines))
        if require_pipeline:
            failures.extend(check_pipeline_trace(lines))
    for msg in failures:
        print(f"[metrics] BAD {msg}", file=sys.stderr)
    if failures:
        return 1
    print(f"[metrics] ok: {snap_path} + {trace_path} "
          f"({len(lines)} trace events)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
