"""Offline markdown link checker for the CI docs job.

``python tools/check_links.py README.md docs/ARCHITECTURE.md ROADMAP.md``

Checks two things, both resolvable without network access:

  * relative markdown links ``[text](path)`` — the target file must
    exist (resolved against the markdown file's directory; http(s) and
    mailto links are skipped, fragments are stripped);
  * backtick-quoted repo paths like ``src/repro/kvcache/pool.py`` — any
    `...`-quoted token that contains a ``/`` and ends in a known source
    extension must exist relative to the repo root, or (the docs'
    shorthand convention) relative to ``src/repro/`` (keeps the
    architecture doc's concept table honest as files move).

Exits non-zero listing every broken reference.
"""
from __future__ import annotations

import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODEPATH = re.compile(r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+"
                      r"\.(?:py|md|json|yml|txt))(?:[:#][^`]*)?`")
SKIP = re.compile(r"^(https?:|mailto:)")


def check_file(path: str, root: str) -> list[str]:
    text = open(path, encoding="utf-8").read()
    bad = []
    for m in LINK.finditer(text):
        target = m.group(1).split("#")[0]
        if not target or SKIP.match(m.group(1)):
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            bad.append(f"{path}: broken link -> {m.group(1)}")
    for m in CODEPATH.finditer(text):
        candidates = (os.path.join(root, m.group(1)),
                      os.path.join(root, "src", "repro", m.group(1)))
        if not any(os.path.exists(c) for c in candidates):
            bad.append(f"{path}: missing code path -> {m.group(1)}")
    return bad


def main(argv: list[str]) -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    failures = []
    for path in argv or ["README.md"]:
        if not os.path.exists(path):
            failures.append(f"{path}: file not found")
            continue
        failures.extend(check_file(path, root))
    for msg in failures:
        print(f"[links] BROKEN {msg}", file=sys.stderr)
    if failures:
        return 1
    print(f"[links] ok: {len(argv)} file(s) checked")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
