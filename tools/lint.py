#!/usr/bin/env python
"""Repo-specific protocol lint (see docs/ANALYSIS.md for the rules).

Usage:
    python tools/lint.py src tests [--json out.json] [--list-rules]

Prints ``path:line:col: [rule] message`` per finding and exits 1 when
anything is found (0 on a clean tree).  ``--json`` writes a machine-
readable summary alongside, matching the bench ``--json`` conventions.

Fixture directories named ``lint_fixtures`` are skipped — they hold the
known-bad corpus the linter's own tests run against.
"""
import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis import lint  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src tests)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write a machine-readable findings summary")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, desc in sorted(lint.RULES.items()):
            print(f"{name:22s} {desc}")
        return 0

    paths = args.paths or ["src", "tests"]
    nfiles = 0
    findings = []
    for full, rel in lint.iter_py_files(paths, _ROOT):
        nfiles += 1
        findings.extend(lint.lint_file(full, rel))

    for f in findings:
        print(f)

    counts = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    summary = {
        "ok": not findings,
        "files": nfiles,
        "findings": [f.to_dict() for f in findings],
        "counts": counts,
    }
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(summary, fh, indent=2, sort_keys=True)
            fh.write("\n")

    if findings:
        print(f"[lint] BAD {len(findings)} finding(s) across {nfiles} files")
        return 1
    print(f"[lint] OK {nfiles} files clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
