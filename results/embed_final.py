"""Embed the final roofline tables into EXPERIMENTS.md (run after sweep)."""
import io, sys, json
from contextlib import redirect_stdout
sys.argv = ['x', 'results/dryrun_final.jsonl']
sys.path.insert(0, 'src')
from repro.launch import roofline

def render(mesh):
    sys.argv = ['x', 'results/dryrun_final.jsonl', '--mesh', mesh]
    buf = io.StringIO()
    with redirect_stdout(buf):
        roofline.main()
    return buf.getvalue()

t1, t2 = render('16x16'), render('2x16x16')
table1 = '\n'.join(l for l in t1.splitlines() if l.startswith('|'))
summary = '\n'.join(l for l in t1.splitlines() if not l.startswith('|') and l.strip())
table2 = '\n'.join(l for l in t2.splitlines() if l.startswith('|'))

section = f"""
## §Roofline — FINAL (post-§Perf optimizations, corrected accounting)

Single-pod 16×16:

{table1}

Summary: {summary}

Multi-pod 2×16×16:

{table2}
"""
s = open('EXPERIMENTS.md').read()
marker = '## §Roofline — FINAL'
if marker in s:
    s = s[:s.index(marker)]
open('EXPERIMENTS.md', 'w').write(s + section)
print('embedded final tables')
