"""Roofline table generation from dry-run JSONL records.

``python -m repro.launch.roofline results/dryrun.jsonl`` prints the
EXPERIMENTS.md §Roofline markdown table and per-cell bottleneck analysis.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


MOVE_HINTS = {
    "compute": "raise MXU occupancy: bigger per-chip tiles (less TP padding)"
               " or fewer rematerialized flops",
    "memory": "fuse more (CPU-backend bytes are unfused upper bounds); cast"
              " activations bf16; increase arithmetic intensity per HBM pass",
    "collective": "overlap collectives with compute; hierarchical"
                  " all-reduce; shrink MoE psum via all-to-all dispatch",
}


def load(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line.startswith("{"):
                out.append(json.loads(line))
    # last record per (arch, shape, mesh) wins (reruns append)
    dedup = {}
    for r in out:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def fmt_row(r: dict) -> str:
    if r["status"] == "skip":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"SKIP | {r['reason']} |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | {r['error'][:60]} |")
    tc, tm, tl = r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
    dom = r["dominant"]
    frac = r["roofline_fraction"]
    ratio = r["useful_flop_ratio"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {tc:.2e} | "
            f"{tm:.2e} | {tl:.2e} | {dom} (frac {frac:.3f}, "
            f"useful {ratio:.2f}) | {MOVE_HINTS[dom][:58]} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load(args.jsonl)
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("| arch | shape | mesh | t_compute | t_memory | t_collective |"
          " bottleneck | to move it |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(fmt_row(r))
    ok = [r for r in recs if r["status"] == "ok"]
    if ok:
        print(f"\n{len(ok)} ok / "
              f"{sum(r['status'] == 'skip' for r in recs)} skip / "
              f"{sum(r['status'] == 'error' for r in recs)} error")
        by_dom = defaultdict(int)
        for r in ok:
            by_dom[r["dominant"]] += 1
        print("bottleneck distribution:", dict(by_dom))
        worst = sorted(ok, key=lambda r: r["roofline_fraction"])[:5]
        print("worst roofline fractions:",
              [(r["arch"], r["shape"], round(r["roofline_fraction"], 4))
               for r in worst])
        coll = sorted(ok, key=lambda r: -r["t_collective_s"])[:5]
        print("most collective-bound:",
              [(r["arch"], r["shape"], f"{r['t_collective_s']:.2e}s")
               for r in coll])


if __name__ == "__main__":
    main()
