"""End-to-end training driver.

``python -m repro.launch.train --arch qwen1_5_0_5b --smoke --steps 50``

Wires together: config registry -> model init (sharded) -> data pipeline ->
train step (pjit) -> checkpoint/restart + heartbeat/straggler supervision.
On CPU it runs reduced configs; on a real pod the same file runs the full
configs (the mesh adapts to the available devices).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import DataConfig, TokenStream
from repro.ft import checkpoint as ckpt
from repro.ft.manager import RunSupervisor
from repro.launch.mesh import (auto_axis_types, make_local_mesh,
                               make_production_mesh)
from repro.models import lm
from repro.optim import adamw as optim
from repro.sharding import context as shctx, rules
from repro.train.step import TrainFlags, make_train_step


def pick_mesh():
    n = len(jax.devices())
    if n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh()
    # largest (data, model) split of available devices
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **auto_axis_types(2))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = pick_mesh()
    opt_cfg = optim.OptConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 5))
    sup = RunSupervisor(args.workdir, ckpt_interval=args.ckpt_interval)

    with shctx.use_mesh(mesh):
        cap = {}

        def mk(key):
            b = lm.init(cfg, key)
            cap["specs"] = b.specs
            return b.params

        abs_params = jax.eval_shape(mk, jax.random.key(0))
        pshard = rules.param_shardings(cap["specs"], abs_params, mesh)
        params = jax.jit(mk, out_shardings=pshard)(jax.random.key(0))
        opt_state = jax.jit(
            lambda p: optim.opt_init(p, opt_cfg),
        )(params)

        start_step = 0
        last = ckpt.latest_step(sup.ckpt_dir) if args.resume else None
        if last is not None:
            print(f"[train] resuming from step {last}")
            state = ckpt.restore({"p": params, "o": opt_state, "s": 0},
                                 last, sup.ckpt_dir)
            params, opt_state = state["p"], state["o"]
            start_step = int(np.asarray(state["s"]))

        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg,
                            TrainFlags(remat=False,
                                       microbatches=args.microbatches)),
            donate_argnums=(0, 1))

        data = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch),
                           start_step=start_step)

        losses = []
        for step in range(start_step, args.steps):
            batch_np = next(data)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if cfg.frontend:
                batch["frontend"] = jnp.zeros(
                    (args.batch, cfg.frontend_seq, cfg.d_model),
                    cfg.cdtype)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            losses.append(loss)
            events = sup.after_step(step, dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)"
                      + (f" events={events}" if any(events.values()) else ""))
            if sup.should_checkpoint(step):
                t0 = time.time()
                ckpt.save({"p": params, "o": opt_state,
                           "s": jnp.asarray(step + 1)}, step + 1,
                          sup.ckpt_dir)
                sup.record_ckpt_time(time.time() - t0)
        print(f"[train] done: first loss {losses[0]:.4f} "
              f"final loss {losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
