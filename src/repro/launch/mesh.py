"""Production mesh construction.

Single pod : (16, 16)    axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Functions, not module-level constants, so importing never touches jax
device state (the dry-run must set XLA_FLAGS before first device init).
"""
from __future__ import annotations

import os

import jax


def auto_axis_types(n: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh`` where supported.
    ``jax.sharding.AxisType`` only exists on newer jax; older versions
    default to Auto semantics anyway, so omit the kwarg there."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def make_local_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"), **auto_axis_types(2))


def request_cpu_devices(n: int) -> None:
    """Ask XLA for ``n`` host CPU devices (the host-device CPU mesh the
    sharded serve smoke runs on).  Must be called before the first jax
    device use — backends already initialized ignore the flag, in which
    case ``make_serve_mesh`` falls back to the devices that exist."""
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()


def make_serve_mesh(n_shards: int) -> jax.sharding.Mesh:
    """(1, n_shards) serving mesh, axes ("data", "model"): the model axis
    is what ``ShardedBlockPool`` partitions the KV pool over.  When fewer
    devices exist than requested (jax already initialized before
    ``request_cpu_devices``), the mesh shrinks to what is available and
    pool shards map onto devices round-robin."""
    n = max(1, min(n_shards, jax.local_device_count()))
    return jax.make_mesh((1, n), ("data", "model"), **auto_axis_types(2))
