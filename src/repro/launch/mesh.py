"""Production mesh construction.

Single pod : (16, 16)    axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Functions, not module-level constants, so importing never touches jax
device state (the dry-run must set XLA_FLAGS before first device init).
"""
from __future__ import annotations

import jax


def auto_axis_types(n: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh`` where supported.
    ``jax.sharding.AxisType`` only exists on newer jax; older versions
    default to Auto semantics anyway, so omit the kwarg there."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def make_local_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"), **auto_axis_types(2))
