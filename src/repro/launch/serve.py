"""Serving driver: continuous batching behind the MARS request scheduler.

``python -m repro.launch.serve --arch qwen1_5_0_5b --smoke --requests 64``

Demonstrates the online MARS path end-to-end: requests (some sharing
prompt prefixes = "pages") flow through the bounded scheduler; batches are
formed page-major oldest-page-first; prefix-sharing batches reuse a
prefill cache.  Reports the serving CAS/ACT analogue: unique prefix blocks
per scheduled batch, with and without MARS.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.step import greedy_generate
from repro.serving.scheduler import MarsScheduler, Request, \
    default_classes, unique_prefix_blocks

# --classes N: per-class decode-length profile for the synthetic stream —
# interactive stays short (chat turns), batch decodes long (summarize),
# stream sits between; the multipliers scale --new-tokens
_CLASS_NEW_TOKENS = {"interactive": 1, "batch": 4, "stream": 2}


def synth_requests(n: int, vocab: int, n_prefixes: int = 8,
                   prefix_len: int = 16, seed: int = 0):
    """Interleaved request streams: n_prefixes hot prompt prefixes."""
    rng = np.random.default_rng(seed)
    prefixes = [tuple(rng.integers(1, vocab, prefix_len).tolist())
                for _ in range(n_prefixes)]
    out = []
    for i in range(n):
        p = prefixes[i % n_prefixes]       # round-robin = interleaved
        tail = tuple(rng.integers(1, vocab, 8).tolist())
        out.append(Request(rid=i, prompt=p + tail, arrival=i * 1e-3,
                           prefix_len=prefix_len))
    return out


def _attach_metrics(args, eng):
    """--metrics: wire an ``obs.Observer`` through the engine (spans,
    counters, live row-hit model; ``--paranoid`` adds the periodic
    incremental invariant sweep).  None when telemetry is off."""
    if not getattr(args, "metrics", False):
        return None
    from repro.obs import Observer
    return Observer(paranoid=args.paranoid).attach(eng)


def _dump_metrics(obs, args):
    """Write ``<metrics-path>/metrics.json`` (registry snapshot) and
    ``<metrics-path>/trace.jsonl`` (span/event log), then print the
    one-screen summary table."""
    if obs is None:
        return
    import json
    import os
    os.makedirs(args.metrics_path, exist_ok=True)
    snap_path = os.path.join(args.metrics_path, "metrics.json")
    trace_path = os.path.join(args.metrics_path, "trace.jsonl")
    with open(snap_path, "w", encoding="utf-8") as fh:
        json.dump(obs.snapshot(), fh, indent=1, sort_keys=True)
        fh.write("\n")
    open(trace_path, "w").close()       # fresh file; flush() appends
    n = obs.trace.flush(trace_path)
    print("[metrics] " + "-" * 50)
    for line in obs.summary_lines():
        print(f"[metrics]   {line}")
    print("[metrics] " + "-" * 50)
    print(f"[metrics] snapshot -> {snap_path}")
    print(f"[metrics] trace    -> {trace_path} ({n} events)")


def main_paged_toy(args):
    """Continuous batching over the paged KV pool (``serve.engine``) with
    the deterministic single-layer ToyModel: admission bounded by pool
    capacity, prefix-shared blocks, MARS-aware placement, CoW forks."""
    from repro.kvcache import BlockPool, PoolConfig
    from repro.serve.engine import ServeEngine

    pool = BlockPool(PoolConfig(num_blocks=args.pool_blocks, block_size=16,
                                n_kv_heads=2, head_dim=64))
    sched = MarsScheduler(pool=pool)
    eng = ServeEngine(pool, sched, max_lanes=args.batch,
                      use_kernel=args.kernel_decode)
    obs = _attach_metrics(args, eng)
    reqs = [Request(rid=r.rid, prompt=r.prompt, arrival=r.arrival,
                    prefix_len=r.prefix_len, max_new=args.new_tokens)
            for r in synth_requests(args.requests, vocab=128)]
    t0 = time.time()
    finished = eng.run(reqs)
    dt = time.time() - t0
    _dump_metrics(obs, args)
    print(f"[serve --paged] served={len(finished)} steps={eng.stats.steps} "
          f"prefill_tokens={eng.stats.prefill_tokens} "
          f"decode_tokens={eng.stats.decode_tokens} "
          f"prefix_hits={pool.stats.prefix_hits} "
          f"shared_prompt_tokens={eng.stats.shared_prompt_tokens} "
          f"evictions={pool.stats.evictions} "
          f"pool_rejects={sched.stats.pool_rejects} wall={dt:.1f}s")
    pool.check_invariants()
    return dict(served=len(finished), steps=eng.stats.steps,
                prefix_hits=pool.stats.prefix_hits,
                pool_rejects=sched.stats.pool_rejects)


def _dense_forced_logits(params, cfg, prompt, forced):
    """Teacher-force the dense backend along ``forced`` tokens; returns the
    dense logits (n, V) seen before each forced token."""
    logits, backend = lm.prefill(params, cfg,
                                 jnp.asarray([prompt], jnp.int32),
                                 max_seq=len(prompt) + len(forced) + 1)
    out = [np.asarray(logits[0, -1], np.float32)]
    for tok in forced[:-1]:
        logits = backend.decode_step(
            params, jnp.asarray([[tok]], jnp.int32))
        out.append(np.asarray(logits[0, -1], np.float32))
    return np.stack(out)


def main_paged(args):
    """Full-LM paged serving: a real ``ModelConfig`` model decoded through
    ``PagedBackend`` by the continuous-batching engine — every layer's KV
    in the layered block pool, ragged lanes, prefix sharing, CoW forks.
    Decode runs through the per-layer Pallas ``paged_attention`` kernel
    (``--kernel-decode``, default) or the gathered dense view
    (``--no-kernel-decode``).  Sliding-window configs decode on the
    kernel path natively (per-layer window mask), and hybrid families
    (``--config hymba_1_5b``) carry their per-sequence SSM/conv state
    through the backend.  ``--shards N`` partitions the pool across a
    host-device mesh (one pool + backend + staged mirror per shard,
    admissions shard-routed by the scheduler).  Cross-checks a sample of
    served sequences against the dense backend for end-to-end token
    parity."""
    if args.toy:
        return main_paged_toy(args)
    from repro.kvcache.backend import make_backend
    from repro.serve.engine import PagedLM, ServeEngine

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    assert cfg.n_layers > 1, "full-LM paged serving needs a multi-layer cfg"
    params = lm.init(cfg, jax.random.key(0)).params
    decode_mode = "kernel" if args.kernel_decode else "gather"
    if args.shards > 1:
        # mesh-sharded serving: one block pool + paged backend per shard
        # of the serving mesh's model axis, each shard's staged mirror
        # committed to its own device (round-robin when the host exposes
        # fewer devices than shards — see request_cpu_devices in main)
        from repro.launch import mesh as mesh_mod
        from repro.sharding import context as shctx
        mesh = mesh_mod.make_serve_mesh(args.shards)
        mesh_devices = list(mesh.devices.flat)
        devices = [mesh_devices[s % len(mesh_devices)]
                   for s in range(args.shards)]
        with shctx.use_mesh(mesh):
            pool_blocks = -(-args.pool_blocks // args.shards) * args.shards
            backend = make_backend(
                cfg, "paged", shards=args.shards, devices=devices,
                num_blocks=pool_blocks, block_size=16,
                decode_mode=decode_mode, tiered=args.tiered_kv)
        print(f"[serve --paged {cfg.name}] shards={args.shards} "
              f"mesh_devices={len(mesh_devices)} "
              f"blocks/shard={backend.pool.shard_blocks}")
    else:
        backend = make_backend(
            cfg, "paged", num_blocks=args.pool_blocks, block_size=16,
            decode_mode=decode_mode, tiered=args.tiered_kv)
    pool = backend.pool
    classes = default_classes(args.classes) if args.classes > 1 else None
    sched = MarsScheduler(pool=pool, classes=classes)
    if args.tiered_kv and args.shards > 1:
        # admission counts a promotable lower-tier prefix hit toward
        # shard routing: land the request where its demoted blocks are
        sched.tier_probe = backend.tier_shard_for
    eng = ServeEngine(pool, sched, PagedLM(params, cfg, backend),
                      max_lanes=args.batch, pipeline=args.pipeline)
    obs = _attach_metrics(args, eng)
    cnames = [c.name for c in classes] if classes else None
    reqs = []
    for r in synth_requests(args.requests, vocab=cfg.vocab,
                            n_prefixes=args.prefixes):
        cname = cnames[r.rid % len(cnames)] if cnames else "default"
        mult = _CLASS_NEW_TOKENS.get(cname, 1) if cnames else 1
        reqs.append(Request(rid=r.rid, prompt=r.prompt, arrival=r.arrival,
                            prefix_len=r.prefix_len,
                            max_new=args.new_tokens * mult,
                            traffic_class=cname))
    t0 = time.time()
    finished = eng.run(reqs)
    dt = time.time() - t0
    pool.check_invariants()
    _dump_metrics(obs, args)
    shard_note = "" if args.shards <= 1 else \
        f"shards={args.shards} shard_defers={sched.stats.shard_defers} "
    print(f"[serve --paged {cfg.name}] layers={cfg.n_layers} "
          f"decode={backend.decode_mode} "
          f"pipeline={'on' if args.pipeline else 'off'} {shard_note}"
          f"served={len(finished)} steps={eng.stats.steps} "
          f"prefill_tokens={eng.stats.prefill_tokens} "
          f"decode_tokens={eng.stats.decode_tokens} "
          f"prefix_hits={pool.stats.prefix_hits} "
          f"evictions={pool.stats.evictions} "
          f"pool_rejects={sched.stats.pool_rejects} wall={dt:.1f}s")
    if classes:
        for cname, cs in sched.class_stats.items():
            h = sched.wait_hist[cname]
            print(f"[serve --paged {cfg.name}] class {cname}: "
                  f"admit={cs.admit} reject={cs.reject} defer={cs.defer} "
                  f"preempt={cs.preempt} scheduled={cs.scheduled} "
                  f"wait p50={h.quantile(0.5):.1f}ms "
                  f"p99={h.quantile(0.99):.1f}ms")
    if args.tiered_kv:
        inner = getattr(backend, "backends", None) or [backend]
        tm = [b.tiers for b in inner if b.tiers is not None]
        print(f"[serve --paged {cfg.name}] tiers: "
              f"demotes={sum(t.stats.demotes for t in tm)} "
              f"promotes={sum(t.stats.promotes for t in tm)} "
              f"promoted_tokens={sum(t.stats.promoted_tokens for t in tm)} "
              f"clean_drops={sum(t.stats.clean_drops for t in tm)} "
              f"drops={sum(t.stats.drops for t in tm)} "
              f"stall_us={sum(t.stats.stall_us for t in tm):.1f}")
        for t in tm:
            t.check()

    # dense-vs-paged parity on a sample of served requests (salt-0 lane of
    # each request is plain greedy).  Gather-path decode runs the identical
    # dense math, so tokens must match the dense backend exactly.  The
    # kernel path accumulates attention in f32 (the dense path rounds
    # through the compute dtype), so its logits differ by ~1 ulp of the
    # compute dtype; the check teacher-forces the dense backend along the
    # *served* tokens and requires every served token's dense logit to be
    # within a near-tie margin of the dense argmax — exact parity up to
    # compute-dtype ties (same scheme as the fp8 near-tie tests).
    n_check = min(args.parity_checks, len(reqs))
    margin = 0.0 if backend.decode_mode == "gather" else \
        (0.0 if jnp.dtype(cfg.compute_dtype) == jnp.float32 else 5e-2)
    mismatches = exact = 0
    for req in reqs[:n_check]:
        got = finished[req.rid][0]
        dense = _dense_forced_logits(params, cfg, list(req.prompt), got)
        greedy = dense.argmax(-1)
        if list(greedy) == got:
            exact += 1
        elif any(dense[i, t] < dense[i].max() - margin
                 for i, t in enumerate(got)):
            mismatches += 1
    print(f"[serve --paged {cfg.name}] dense-vs-{backend.decode_mode} "
          f"parity: {n_check - mismatches}/{n_check} sequences match "
          f"({exact} argmax-exact, margin={margin})")
    assert mismatches == 0, \
        f"{backend.decode_mode} paged serving diverged from the dense backend"
    return dict(served=len(finished), steps=eng.stats.steps,
                prefix_hits=pool.stats.prefix_hits,
                parity_checked=n_check, decode=backend.decode_mode)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--config", dest="arch", default="qwen1_5_0_5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--prefixes", type=int, default=8,
                    help="distinct hot prompt prefixes in the synthetic "
                         "stream; raise past the pool's cached capacity "
                         "(with --tiered-kv) to force spill traffic")
    ap.add_argument("--paged", action="store_true",
                    help="serve a real config through the paged KV backend")
    ap.add_argument("--kernel-decode", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="with --paged: decode through the per-layer Pallas "
                         "paged_attention kernel (default on; sliding-"
                         "window and hybrid configs included); "
                         "--no-kernel-decode uses the gathered dense view")
    ap.add_argument("--toy", action="store_true",
                    help="with --paged: single-layer ToyModel engine demo")
    ap.add_argument("--pipeline", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="with --paged: drive the split-phase decode "
                         "pipeline (flush -> dispatch -> sync; KV write-"
                         "back one step deferred; default on); "
                         "--no-pipeline serves through the synchronous "
                         "decode() wrapper — tokens are identical")
    ap.add_argument("--shards", type=int, default=1,
                    help="with --paged: partition the KV pool across this "
                         "many mesh shards (per-shard pools, prefix-"
                         "affinity admission routing, per-shard kernel "
                         "decode); CPU runs force a host-device mesh")
    ap.add_argument("--pool-blocks", type=int, default=256)
    ap.add_argument("--classes", type=int, default=0,
                    help="with --paged (full-LM): SMS traffic classes — "
                         "install the first N default_classes() streams "
                         "(interactive/batch/stream), stamp the synthetic "
                         "requests round-robin with per-class decode "
                         "lengths, and let overload preempt batch decodes "
                         "for interactive arrivals (0/1 = class-blind)")
    ap.add_argument("--tiered-kv", action="store_true",
                    help="with --paged: spill tiers behind the block "
                         "pool(s) — eviction demotes registered prefix "
                         "blocks to host/remote tiers, prefix misses "
                         "promote them back (MARS-reordered batched "
                         "copy-in); size --pool-blocks small to force "
                         "spill traffic")
    ap.add_argument("--parity-checks", type=int, default=4,
                    help="with --paged: served sequences re-checked densely")
    ap.add_argument("--metrics", action="store_true",
                    help="with --paged: serve instrumented (obs.Observer) "
                         "and dump a JSON metrics snapshot + JSONL span "
                         "trace, plus a one-screen summary")
    ap.add_argument("--metrics-path", default="metrics_out",
                    help="directory for metrics.json / trace.jsonl")
    ap.add_argument("--paranoid", action="store_true",
                    help="with --metrics: run the pool's incremental "
                         "invariant sweep every few engine steps")
    args = ap.parse_args(argv)

    if args.shards > 1:
        # must precede the first jax device use so the host can present a
        # multi-device CPU mesh (no-op if the backend already initialized;
        # make_serve_mesh then shrinks to the devices that exist)
        from repro.launch.mesh import request_cpu_devices
        request_cpu_devices(args.shards)

    if args.paged:
        return main_paged(args)

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get(args.arch)
    params = lm.init(cfg, jax.random.key(0)).params

    reqs = synth_requests(args.requests, cfg.vocab)
    results = {}
    for mars in (False, True):
        sched = MarsScheduler(mars=mars)
        pending = list(reqs)
        served = 0
        blocks = 0
        batches = 0
        t0 = time.time()
        while pending or len(sched):
            while pending and sched.offer(pending[0]):
                pending.pop(0)
            batch = sched.schedule_batch(args.batch)
            if not batch:
                break
            blocks += unique_prefix_blocks(batch)
            batches += 1
            # run the batch through the dense KV backend: prefill the
            # (page-shared) prompts + greedy decode
            prompts = jnp.asarray([r.prompt for r in batch], jnp.int32)
            greedy_generate(params, cfg, prompts, args.new_tokens + 1,
                            max_seq=prompts.shape[1] + args.new_tokens + 1)
            served += len(batch)
        dt = time.time() - t0
        results[mars] = dict(served=served, batches=batches,
                             blocks_per_batch=blocks / max(batches, 1),
                             mean_wait=sched.stats.mean_wait, wall_s=dt)
        print(f"[serve] mars={mars} served={served} batches={batches} "
              f"unique-prefix-blocks/batch={blocks/max(batches,1):.2f} "
              f"wall={dt:.1f}s")
    base, mars_r = results[False], results[True]
    gain = base["blocks_per_batch"] / max(mars_r["blocks_per_batch"], 1e-9)
    print(f"[serve] MARS page-coherence gain: {gain:.2f}x fewer unique "
          f"prefix blocks per batch")
    return results


if __name__ == "__main__":
    main()
