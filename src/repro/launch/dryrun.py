import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import so the host platform
# exposes 512 placeholder devices for the production mesh.  Everything below
# is ordinary code.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: pjit sharding propagation succeeds, the collective schedule is
valid, and ``memory_analysis`` / ``cost_analysis`` quantify the compiled
program.  Roofline terms (EXPERIMENTS.md §Roofline) come straight from the
artifacts produced here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1_5_0_5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import ModelConfig, SHAPES, shape_by_name, \
    cell_applicable
from repro.optim import adamw as optim
from repro.serve import step as serve_step_mod
from repro.sharding import context as shctx, rules
from repro.train import step as train_step_mod
from repro.utils import hlo as hlo_util

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link


def opt_config_for(cfg: ModelConfig) -> optim.OptConfig:
    # trillion-scale MoE: factored second moment, bf16 has no full AdamW
    if cfg.n_params() > 1e11:
        return optim.OptConfig(kind="adafactor")
    return optim.OptConfig(kind="adamw")


def input_specs(cfg: ModelConfig, cell, mesh):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = cell.global_batch, cell.seq_len
    bsh = rules.batch_sharding(mesh, B)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bsh)
    out = {"tokens": tok, "labels": tok}
    if cfg.frontend:
        out["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16, sharding=bsh)
    return out


def abstract_state(cfg: ModelConfig, mesh, *, fsdp: bool = True,
                   with_opt: bool = True, opt_cfg=None):
    """Abstract (ShapeDtypeStruct) params [+ optimizer state] with shardings."""
    cap = {}

    def mk():
        b = lm.init(cfg, jax.random.key(0))
        cap["specs"] = b.specs      # static python tree, safe to capture
        return b.params
    params_abs = jax.eval_shape(mk)
    pshard = rules.param_shardings(cap["specs"], params_abs, mesh, fsdp=fsdp)
    params = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        params_abs, pshard)
    if not with_opt:
        return params, pshard, None, None
    opt_abs = jax.eval_shape(lambda p: optim.opt_init(p, opt_cfg), params)
    oshard = optim.state_shardings(opt_abs, pshard, mesh)
    opt = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        opt_abs, oshard)
    return params, pshard, opt, oshard


def _analyze(lowered, compiled, chips: int, model_flops: float) -> dict:
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    try:
        txt = compiled.as_text()
    except Exception:
        txt = lowered.as_text()
    coll = hlo_util.collective_bytes(txt)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)
    # cost_analysis counts while-loop bodies once; the analytic model
    # flops are a hard floor for executed matmul work, so the compute term
    # uses max(reported, model).  Collective bytes are trip-count-weighted
    # by the HLO parser.
    mf_per_chip_ = model_flops / chips
    t_compute = max(flops, mf_per_chip_) / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll.get("total", 0) / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf_per_chip = model_flops / chips
    return {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_,
        "collective_bytes_per_chip": coll.get("total", 0),
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "memory": mem,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": mf_per_chip,
        "useful_flop_ratio": mf_per_chip / flops if flops else 0.0,
        "roofline_bound_s": max(t_compute, t_memory, t_coll),
        "roofline_fraction":
            (t_compute / max(t_compute, t_memory, t_coll)
             if max(t_compute, t_memory, t_coll) else 0.0),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp: bool = True, remat: bool = True,
             kv_dtype: str = "") -> dict:
    cfg = configs.get(arch)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    cell = shape_by_name(shape_name)
    ok, why = cell_applicable(cfg, cell)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skip", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        with shctx.use_mesh(mesh):
            if cell.kind == "train":
                opt_cfg = opt_config_for(cfg)
                params, pshard, opt, oshard = abstract_state(
                    cfg, mesh, fsdp=fsdp, with_opt=True, opt_cfg=opt_cfg)
                flags = train_step_mod.TrainFlags(remat=remat)
                step = train_step_mod.make_train_step(cfg, opt_cfg, flags)
                batch = input_specs(cfg, cell, mesh)
                fn = jax.jit(step, donate_argnums=(0, 1))
                lowered = fn.lower(params, opt, batch)
                rec["params_gb_per_chip"] = round(
                    rules.sharded_bytes_per_device(params, pshard, mesh)
                    / 1e9, 3)
                rec["opt_gb_per_chip"] = round(
                    rules.sharded_bytes_per_device(opt, oshard, mesh)
                    / 1e9, 3)
                # training compute: fwd+bwd ~ 3x forward matmul flops
                model_flops = 6.0 * cfg.n_active_params() \
                    * cell.global_batch * cell.seq_len
            else:
                params, pshard, _, _ = abstract_state(
                    cfg, mesh, fsdp=False, with_opt=False)
                B = cell.global_batch
                cache_abs = lm.abstract_cache(
                    cfg, B, cell.seq_len,
                    enc_len=cfg.frontend_seq if cfg.family == "encdec" else 0)
                cshard = rules.cache_shardings(mesh, cfg, B)
                cache = jax.tree.map(
                    lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                                      sharding=s)
                    if a is not None else None,
                    cache_abs, cshard,
                    is_leaf=lambda x: x is None or isinstance(
                        x, jax.ShapeDtypeStruct))
                tok = jax.ShapeDtypeStruct(
                    (B, 1), jnp.int32,
                    sharding=rules.batch_sharding(mesh, B))
                rec["params_gb_per_chip"] = round(
                    rules.sharded_bytes_per_device(params, pshard, mesh)
                    / 1e9, 3)
                rec["cache_gb_per_chip"] = round(
                    rules.sharded_bytes_per_device(
                        jax.tree.leaves(cache_abs),
                        jax.tree.leaves(cshard,
                                        is_leaf=lambda x: x is None),
                        mesh) / 1e9, 3)
                if cell.kind == "prefill":
                    # prefill lowers forward over the full prompt
                    def fwd(p, batch):
                        logits, aux = lm.forward(p, cfg, batch["tokens"],
                                                 batch.get("frontend"),
                                                 remat=False)
                        return logits[:, -1]
                    batch = input_specs(cfg, cell, mesh)
                    batch.pop("labels")
                    fn = jax.jit(fwd)
                    lowered = fn.lower(params, batch)
                    model_flops = 2.0 * cfg.n_active_params() \
                        * cell.global_batch * cell.seq_len
                else:
                    step = serve_step_mod.make_decode_step(cfg)
                    fn = jax.jit(step, donate_argnums=(1,))
                    lowered = fn.lower(params, cache, tok)
                    model_flops = 2.0 * cfg.n_active_params() * B
            compiled = lowered.compile()
            rec.update(_analyze(lowered, compiled, chips, model_flops))
            rec.update(status="ok",
                       compile_s=round(time.time() - t0, 1),
                       chips=chips,
                       n_params=cfg.n_params(),
                       n_active=cfg.n_active_params())
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--kv-dtype", default="")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in configs.all_archs():
            for s in SHAPES:
                for mp in (False, True):
                    cells.append((a, s.name, mp))
    else:
        cells = [(args.arch, args.shape, args.mesh == "multi")]

    out_fh = open(args.out, "a") if args.out else None
    for arch, shape, mp in cells:
        rec = run_cell(arch, shape, mp, fsdp=not args.no_fsdp,
                       remat=not args.no_remat, kv_dtype=args.kv_dtype)
        line = json.dumps(rec)
        print(line, flush=True)
        if out_fh:
            out_fh.write(line + "\n")
            out_fh.flush()
        if rec.get("status") == "ok":
            print(f"#  mem={rec['memory']}", flush=True)
            print(f"#  cost: flops/chip={rec['hlo_flops_per_chip']:.3e} "
                  f"bytes/chip={rec['hlo_bytes_per_chip']:.3e} "
                  f"coll/chip={rec['collective_bytes_per_chip']:.3e} "
                  f"dominant={rec['dominant']}", flush=True)


if __name__ == "__main__":
    main()
