"""Protocol sanitizer suite: static lint, pipeline race detection,
refcount shadow accounting.

The serving stack's correctness now rests on invariants that reorder
*time*, not just addresses: the one-step-lagged KV write-back, the
single-consumer dirty-staging contract, flush barriers in front of every
fork/free/prefill/release, and Pallas fetch gates that must live in
BlockSpec index maps.  Those invariants live in docstrings; this package
makes them machine-checked:

  ``analysis.lint``    AST-based repo-specific lint pass (never imports
                       the checked code) — run via ``tools/lint.py``.
  ``analysis.races``   happens-before model of the ``DecodeStep``
                       lifecycle: exhaustive in-process interleaving
                       exploration plus offline replay of ``obs``
                       TraceLog JSONL (what ``tools/check_metrics.py
                       --require-pipeline`` drives).
  ``analysis.refsan``  opt-in ``BlockPool`` shadow refcount sanitizer:
                       leaks, double-frees and use-after-free with
                       call-site provenance.

See ``docs/ANALYSIS.md`` for the rule catalogue and usage.
"""
import importlib

__all__ = ["lint", "races", "refsan"]


def __getattr__(name):
    # lazy submodule access (keeps `python -m repro.analysis.races`
    # runnable without a double-import warning)
    if name in __all__:
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(name)
