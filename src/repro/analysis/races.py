"""Happens-before model of the ``DecodeStep`` lifecycle.

The pipelined backends (``PagedBackend``/``ShardedPagedBackend``) obey a
small protocol per shard:

* ``dispatch(k)`` may only run with no step in flight and no
  synced-but-uncommitted step pending (``dispatch_decode`` commits step
  ``k-1`` *before* emitting its own dispatch event, so a pending step at
  dispatch time means the one-step write-back lag was exceeded);
* ``sync(k)`` must follow ``dispatch(k)`` and moves the step from
  in-flight to pending;
* ``commit(k)`` must follow ``sync(k)``;
* barrier ops (``prefill``/``fork``/``free``/``release``/``resume``)
  require the shard fully drained (no in-flight, no pending step) — the
  flush barrier in front of every CoW fork / free / admission / resume;
* ``pause`` (decode preemption) must likewise observe the flush barrier
  BEFORE demoting the victim's blocks: a pause with a step in flight or
  a write-back still pending is the distinct ``preempt-during-dispatch``
  violation (the demoted pages would race the deferred KV commit);
* pipelining is real only if ≥1 token is emitted strictly between some
  ``sync(k)`` and its ``commit(k)`` (``lag_tokens``).

Two frontends drive one checker:

``check_history(events)``
    in-process: feed an explicit event list (e.g. every interleaving of
    per-shard chains from :func:`interleavings`) and get violations.
    Events may carry a ``round`` id, enabling the issue-then-gather
    check (all dispatches of a round precede all of its syncs).

``analyze_trace(lines)``
    offline: replay ``obs`` TraceLog JSONL (``backend.dispatch`` /
    ``backend.decode`` / ``backend.commit`` / ``backend.prefill`` /
    ``engine.token`` events) and produce a JSON-serializable report.
    This is what ``tools/check_metrics.py --require-pipeline`` uses.

Run standalone: ``python -m repro.analysis.races trace.jsonl
[--require-pipeline] [--json out.json]``.
"""
from __future__ import annotations

import dataclasses
import json

DISPATCH, SYNC, COMMIT = "dispatch", "sync", "commit"
PREFILL, FORK, FREE, RELEASE = "prefill", "fork", "free", "release"
PAUSE, RESUME = "pause", "resume"
TOKEN = "token"
_BARRIERS = {PREFILL, FORK, FREE, RELEASE, RESUME}
KINDS = {DISPATCH, SYNC, COMMIT, TOKEN, PAUSE} | _BARRIERS


@dataclasses.dataclass(frozen=True)
class Ev:
    """One lifecycle event. ``step`` is the per-shard step index;
    ``round`` (optional) groups a sharded issue-then-gather round."""
    kind: str
    shard: int = 0
    step: int | None = None
    round: int | None = None

    def __repr__(self) -> str:  # compact, for violation messages
        bits = [self.kind, f"sh{self.shard}"]
        if self.step is not None:
            bits.append(f"#{self.step}")
        if self.round is not None:
            bits.append(f"r{self.round}")
        return ":".join(bits)


@dataclasses.dataclass(frozen=True)
class Violation:
    code: str
    shard: int
    step: int | None
    index: int          # position in the event stream (-1 = end-of-stream)
    msg: str

    def __str__(self) -> str:
        return f"[{self.code}] {self.msg}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Shard:
    __slots__ = ("inflight", "pending", "seen_dispatch", "dispatched",
                 "synced", "committed")

    def __init__(self):
        self.inflight: int | None = None
        self.pending: int | None = None
        self.seen_dispatch = False
        self.dispatched = 0
        self.synced = 0
        self.committed = 0


class PipelineChecker:
    """Feed events in order; violations accumulate in ``.violations``.

    ``strict_start=False`` (trace replay) ignores sync/commit on a shard
    before its first dispatch — the obs ring buffer may have dropped the
    head of the stream.
    """

    def __init__(self, strict_start: bool = True):
        self.strict_start = strict_start
        self.violations: list[Violation] = []
        self.lag_tokens = 0
        self._shards: dict[int, _Shard] = {}
        self._rounds: dict[int, list[tuple[int, str]]] = {}
        self._n = 0

    def _sh(self, shard: int) -> _Shard:
        return self._shards.setdefault(shard, _Shard())

    def _bad(self, code: str, shard: int, step: int | None, msg: str):
        self.violations.append(Violation(code, shard, step, self._n, msg))

    def feed(self, ev: Ev):
        i, s = self._n, self._sh(ev.shard)
        if ev.round is not None and ev.kind in (DISPATCH, SYNC):
            self._rounds.setdefault(ev.round, []).append((i, ev.kind))
        if ev.kind == DISPATCH:
            if s.inflight is not None:
                self._bad("double-dispatch", ev.shard, ev.step,
                          f"dispatch of step {ev.step} on shard {ev.shard} "
                          f"while step {s.inflight} is still in flight")
            elif s.pending is not None:
                self._bad("lag-exceeded", ev.shard, ev.step,
                          f"dispatch of step {ev.step} on shard {ev.shard} "
                          f"before step {s.pending}'s commit — write-back "
                          "lag exceeded one step")
            s.inflight = ev.step
            s.seen_dispatch = True
            s.dispatched += 1
        elif ev.kind == SYNC:
            if s.inflight is None:
                if s.seen_dispatch or self.strict_start:
                    self._bad("sync-before-dispatch", ev.shard, ev.step,
                              f"sync of step {ev.step} on shard {ev.shard} "
                              "before its dispatch")
            elif ev.step is not None and s.inflight != ev.step:
                self._bad("sync-mismatch", ev.shard, ev.step,
                          f"sync of step {ev.step} on shard {ev.shard} but "
                          f"step {s.inflight} is the one in flight")
            if s.inflight is not None or s.seen_dispatch or self.strict_start:
                if s.pending is not None:
                    self._bad("lag-exceeded", ev.shard, ev.step,
                              f"sync of step {ev.step} on shard {ev.shard} "
                              f"while step {s.pending} is still uncommitted")
                s.pending = ev.step if ev.step is not None else s.inflight
                s.inflight = None
                s.synced += 1
        elif ev.kind == COMMIT:
            if s.pending is None:
                if s.seen_dispatch or self.strict_start:
                    self._bad("commit-before-sync", ev.shard, ev.step,
                              f"commit of step {ev.step} on shard {ev.shard} "
                              "before its sync — KV write-back would land "
                              "ahead of the logits it belongs to")
            else:
                if ev.step is not None and s.pending != ev.step:
                    self._bad("commit-mismatch", ev.shard, ev.step,
                              f"commit of step {ev.step} on shard {ev.shard} "
                              f"but step {s.pending} is pending")
                s.pending = None
                s.committed += 1
        elif ev.kind == PAUSE:
            # preemption's own code: demoting the victim's blocks while a
            # decode is in flight (or its KV write-back still deferred)
            # would hand reusable pages to the allocator with device
            # writes against them still outstanding
            if s.inflight is not None or s.pending is not None:
                stuck = s.inflight if s.inflight is not None else s.pending
                self._bad("preempt-during-dispatch", ev.shard, ev.step,
                          f"pause on shard {ev.shard} with step {stuck} "
                          "not yet committed — block demotion must observe "
                          "the flush barrier before preempting")
        elif ev.kind in _BARRIERS:
            if s.inflight is not None or s.pending is not None:
                stuck = s.inflight if s.inflight is not None else s.pending
                self._bad("barrier-missed", ev.shard, ev.step,
                          f"{ev.kind} on shard {ev.shard} inside an "
                          f"undrained pipeline (step {stuck} not yet "
                          "committed) — flush barrier missed")
        elif ev.kind == TOKEN:
            for sh in self._shards.values():
                if sh.pending is not None:
                    self.lag_tokens += 1
                    break
        else:
            raise ValueError(f"unknown event kind: {ev.kind!r}")
        self._n += 1

    def finish(self) -> list[Violation]:
        for shard, s in sorted(self._shards.items()):
            if s.inflight is not None:
                self._bad("lost-sync", shard, s.inflight,
                          f"step {s.inflight} on shard {shard} dispatched "
                          "but never synced")
            if s.pending is not None:
                self._bad("lost-commit", shard, s.pending,
                          f"step {s.pending} on shard {shard} synced but "
                          "never committed — flush lost the write-back")
        for rnd, evs in sorted(self._rounds.items()):
            last_dispatch = max((i for i, k in evs if k == DISPATCH),
                                default=None)
            first_sync = min((i for i, k in evs if k == SYNC), default=None)
            if (last_dispatch is not None and first_sync is not None
                    and first_sync < last_dispatch):
                self.violations.append(Violation(
                    "gather-before-issue", -1, None, first_sync,
                    f"round {rnd}: a shard synced before every shard's "
                    "kernel was issued — issue-then-gather order broken"))
        return self.violations

    def stats(self) -> dict:
        return {
            "shards": len(self._shards),
            "events": self._n,
            "dispatched": sum(s.dispatched for s in self._shards.values()),
            "synced": sum(s.synced for s in self._shards.values()),
            "committed": sum(s.committed for s in self._shards.values()),
            "lag_tokens": self.lag_tokens,
        }


def check_history(events, strict_start: bool = True) -> list[Violation]:
    """Run a full event sequence through the checker; returns violations."""
    c = PipelineChecker(strict_start=strict_start)
    for ev in events:
        c.feed(ev)
    return c.finish()


def shard_chain(shard: int, steps: int, tokens: bool = True,
                rounds: bool = False) -> list[Ev]:
    """The legal per-shard lifecycle: d0 s0 [tok] c0 d1 s1 [tok] c1 ...

    Commit of step k is emitted by dispatch of step k+1 (one-step lag),
    so tokens sampled from step k's logits land between s(k) and c(k).
    """
    out: list[Ev] = []
    for k in range(steps):
        rnd = k if rounds else None
        out.append(Ev(DISPATCH, shard, k, rnd))
        out.append(Ev(SYNC, shard, k, rnd))
        if tokens:
            out.append(Ev(TOKEN, shard, k))
        out.append(Ev(COMMIT, shard, k, rnd))
    return out


def interleavings(*chains):
    """Exhaustively yield every order-preserving merge of the chains."""
    chains = [list(c) for c in chains if c]
    if not chains:
        yield []
        return

    def rec(prefix, rests):
        if all(not r for r in rests):
            yield list(prefix)
            return
        for i, r in enumerate(rests):
            if not r:
                continue
            prefix.append(r[0])
            nxt = list(rests)
            nxt[i] = r[1:]
            yield from rec(prefix, nxt)
            prefix.pop()

    yield from rec([], chains)


# ---------------------------------------------------------------------------
# obs TraceLog replay

_EV_MAP = {
    "backend.dispatch": DISPATCH,
    "backend.decode": SYNC,       # span emitted when sync() returns
    "backend.commit": COMMIT,
    "backend.prefill": PREFILL,
    "backend.pause": PAUSE,       # decode preemption: pause -> demote
    "backend.resume": RESUME,     # bitwise restore (a flush barrier)
    "engine.token": TOKEN,
}


@dataclasses.dataclass
class Report:
    violations: list[Violation]
    stats: dict

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {"ok": self.ok,
                "violations": [v.to_dict() for v in self.violations],
                "stats": self.stats}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)


def _iter_trace_events(lines):
    """Parse TraceLog JSONL into Ev records in timestamp order.

    ``TraceLog.span`` stamps ``ts`` at *entry* (``dur_us`` is attached
    at exit), and instantaneous events stamp at emission, so a plain
    ``ts`` sort reconstructs program order for the single-threaded
    engine — ``backend.decode``'s ts is the moment the engine began
    blocking in ``sync``, which is exactly the happens-before point the
    protocol cares about.
    """
    out = []
    for seq, line in enumerate(lines):
        if isinstance(line, (bytes, str)):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue    # malformed lines are the schema check's job
        else:
            rec = line
        name = rec.get("ev")
        kind = _EV_MAP.get(name)
        if kind is None:
            continue
        ev = Ev(kind, int(rec.get("shard", 0)),
                rec.get("step") if rec.get("step") is None
                else int(rec.get("step")))
        out.append((rec.get("ts", 0), seq, ev))
    out.sort(key=lambda t: (t[0], t[1]))
    return [ev for _, _, ev in out]


def analyze_trace(lines, require_pipeline: bool = False) -> Report:
    """Replay an obs TraceLog (JSONL lines or parsed dicts) offline."""
    events = _iter_trace_events(lines)
    c = PipelineChecker(strict_start=False)
    for ev in events:
        c.feed(ev)
    c.finish()
    stats = c.stats()
    if require_pipeline:
        if stats["dispatched"] == 0:
            c.violations.append(Violation(
                "no-pipeline", -1, None, -1,
                "trace holds no backend.dispatch events — pipelined "
                "decode never ran"))
        elif stats["lag_tokens"] == 0:
            c.violations.append(Violation(
                "no-lag", -1, None, -1,
                "no token was ever emitted between a sync and its commit "
                "— the write-back is not lagged, decode is sequential"))
    return Report(violations=c.violations, stats=stats)


def analyze_trace_file(path: str, require_pipeline: bool = False) -> Report:
    with open(path, encoding="utf-8") as fh:
        return analyze_trace(fh, require_pipeline=require_pipeline)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Replay an obs TraceLog JSONL through the decode-"
                    "pipeline happens-before checker.")
    ap.add_argument("trace", help="trace JSONL path")
    ap.add_argument("--require-pipeline", action="store_true",
                    help="fail unless pipelined decode actually ran")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the findings report as JSON")
    args = ap.parse_args(argv)

    report = analyze_trace_file(args.trace,
                                require_pipeline=args.require_pipeline)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(report.to_json() + "\n")
    for v in report.violations:
        print(f"[races] BAD {v.msg}")
    if report.ok:
        print(f"[races] OK {report.stats}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
