"""Repo-specific static lint pass (AST-only, never imports checked code).

The rules encode serving-stack protocol invariants that a generic linter
cannot know about.  Each rule has a kebab-case name; suppress a finding
by appending ``# lint: ok(rule-name)`` (or bare ``# lint: ok``) to the
flagged line — suppressions are for *deliberate* violations only, e.g.
deprecation-coverage tests.

Rules
-----
pool-kv-mutation
    ``k_pages``/``v_pages``/``dirty`` may only be mutated by
    ``BlockPool`` itself (``write_kv``/``copy_block``/``drain_dirty``/
    ``_free_block``/``forget_dirty``/``__init__``).  Anything else
    bypasses the dirty-block staging contract and the write lands on
    the host copy but never reaches the device mirror.

flush-barrier
    In pipelined backends (classes that define ``_commit_pending``),
    ``fork_seq``/``free_seq``/``prefill``/``new_seq``/``_add_seqs``
    must reach ``self.flush()`` (or delegate to a flushing method)
    before touching backend state, and ``release`` must drain the
    in-flight step (``flush``/``sync``+``_commit_pending``) before
    tearing down.  Otherwise CoW forks or frees race the one-step-
    lagged KV write-back.

pallas-fetch-gate
    If a Pallas kernel gates work with an inequality ``pl.when`` (a
    bounds/window test), the fetch gate must also live in the BlockSpec
    index map: a table-driven index map (``table[param]``) must clamp
    its page index (``jnp.clip``/``minimum``/``maximum``).  A
    ``pl.when``-only guard skips compute but the pipeline still DMAs
    whatever block the index map names.

positional-pool
    ``PagedBackend``/``ShardedPagedBackend`` must be constructed via
    ``make_backend(...)`` or keyword arguments; ≥2 positional args hit
    the deprecated legacy signature.

dense-kv-read
    ``DenseBackend.k``/``.v`` reads are deprecated; use
    ``kv_for_layer(l)``.  Flagged when the receiver was assigned from
    ``DenseBackend(...)``/``make_backend(...)``/``init_cache(...)`` in
    the same scope.

drain-dirty-consumer
    ``drain_dirty()`` is destructive (clears the staging set); under
    ``src/`` only the backend staging path (``kvcache/backend.py``,
    ``kvcache/pool.py``) may call it.  A second consumer silently
    steals the other's staged writes.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re

RULES = {
    "pool-kv-mutation": ("direct k_pages/v_pages/dirty mutation outside "
                         "BlockPool write paths"),
    "flush-barrier": ("fork/free/prefill/new_seq/release in a pipelined "
                      "backend without a flush/drain barrier first"),
    "pallas-fetch-gate": ("pl.when bounds guard without a clamped "
                          "table-driven BlockSpec index map"),
    "positional-pool": ("deprecated positional PagedBackend/"
                        "ShardedPagedBackend construction"),
    "dense-kv-read": "deprecated DenseBackend.k/.v concrete-cache read",
    "drain-dirty-consumer": ("drain_dirty() called outside the backend "
                             "staging path"),
}

_POOL_ATTRS = {"k_pages", "v_pages"}
_DIRTY_METHODS = {"add", "discard", "clear", "update", "pop", "remove"}
_POOL_OK_METHODS = {"__init__", "write_kv", "copy_block", "drain_dirty",
                    "_free_block", "forget_dirty"}
_FLUSHING = {"flush", "free_seq", "fork_seq", "new_seq", "_add_seqs"}
_BARRIER_PRE_OK = {"_check_released"}
_BARRIER_METHODS = {"fork_seq", "free_seq", "prefill", "new_seq",
                    "_add_seqs"}
_DRAIN_OK_FILES = ("kvcache/backend.py", "kvcache/pool.py")
_CLAMP_FNS = {"clip", "clamp", "minimum", "maximum"}
_CTOR_NAMES = {"PagedBackend", "ShardedPagedBackend"}
_DENSE_SOURCES = {"DenseBackend", "make_backend", "init_cache"}
_INEQ = (ast.Lt, ast.LtE, ast.Gt, ast.GtE)

_SUPPRESS_RE = re.compile(r"#.*?lint:\s*ok(?:\(([a-z0-9-]+)\))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.msg}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _root_name(node: ast.AST) -> str | None:
    """Leftmost Name of an attribute/subscript chain, else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_self_call(node: ast.AST, names: set[str]) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == "self"
            and node.func.attr in names)


def _assign_targets(node: ast.stmt):
    if isinstance(node, ast.Assign):
        return node.targets
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        return [node.target]
    return []


# ---------------------------------------------------------------------------
# pool-kv-mutation


def _rule_pool_kv_mutation(tree: ast.Module, out: list):
    allowed: list[tuple[int, int]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "BlockPool":
            for item in node.body:
                if (isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and item.name in _POOL_OK_METHODS):
                    allowed.append((item.lineno, item.end_lineno or item.lineno))

    def ok(line: int) -> bool:
        return any(a <= line <= b for a, b in allowed)

    for node in ast.walk(tree):
        for tgt in _assign_targets(node) if isinstance(node, ast.stmt) else []:
            for sub in ast.walk(tgt):
                hit = None
                if isinstance(sub, ast.Attribute) and isinstance(sub.ctx, ast.Store):
                    if sub.attr in _POOL_ATTRS or sub.attr == "dirty":
                        hit = sub.attr
                elif (isinstance(sub, ast.Subscript)
                      and isinstance(sub.ctx, ast.Store)
                      and isinstance(sub.value, ast.Attribute)
                      and sub.value.attr in _POOL_ATTRS):
                    hit = sub.value.attr
                if hit is not None and not ok(sub.lineno):
                    out.append((sub.lineno, sub.col_offset, "pool-kv-mutation",
                                f"direct store to .{hit} outside BlockPool "
                                "write paths — use write_kv/copy_block so the "
                                "dirty-staging contract holds"))
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _DIRTY_METHODS
                and isinstance(node.func.value, ast.Attribute)
                and node.func.value.attr == "dirty"
                and not ok(node.lineno)):
            out.append((node.lineno, node.col_offset, "pool-kv-mutation",
                        f"direct .dirty.{node.func.attr}(...) outside "
                        "BlockPool — use forget_dirty/write_kv/drain_dirty"))


# ---------------------------------------------------------------------------
# flush-barrier


def _stmt_contains_flush(st: ast.stmt) -> bool:
    return any(_is_self_call(n, _FLUSHING) for n in ast.walk(st))


def _stmt_violation(st: ast.stmt):
    """First pre-flush self-mutation / disallowed self-call in a leaf stmt."""
    for n in ast.walk(st):
        if isinstance(n, ast.stmt):
            for tgt in _assign_targets(n):
                for sub in ast.walk(tgt):
                    if (isinstance(sub, (ast.Attribute, ast.Subscript))
                            and isinstance(sub.ctx, ast.Store)
                            and _root_name(sub) == "self"):
                        return (sub.lineno, sub.col_offset,
                                "backend state mutated")
        if (isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and isinstance(n.func.value, ast.Name)
                and n.func.value.id == "self"
                and n.func.attr not in (_FLUSHING | _BARRIER_PRE_OK)):
            return (n.lineno, n.col_offset, f"self.{n.func.attr}(...) called")
    return None


def _scan_barrier(body: list, flushed: bool):
    """Walk statements in order; return (flushed, violation|None)."""
    for st in body:
        if flushed:
            return True, None
        if isinstance(st, (ast.If, ast.For, ast.While, ast.With, ast.Try)):
            header = [x for x in ast.iter_child_nodes(st)
                      if isinstance(x, ast.expr)]
            for expr in header:
                fake = ast.Expr(value=expr)
                ast.copy_location(fake, expr)
                v = _stmt_violation(fake)
                if v:
                    return flushed, v
                if any(_is_self_call(n, _FLUSHING) for n in ast.walk(expr)):
                    flushed = True
            sub_bodies = [st.body]
            for fld in ("orelse", "finalbody"):
                sb = getattr(st, fld, None)
                if sb:
                    sub_bodies.append(sb)
            for h in getattr(st, "handlers", []) or []:
                sub_bodies.append(h.body)
            branch_flushed = []
            for sb in sub_bodies:
                f, v = _scan_barrier(sb, flushed)
                if v:
                    return flushed, v
                branch_flushed.append(f)
            # conservative: a flush on any branch counts (real code
            # flushes unconditionally; this avoids guard false-positives)
            flushed = flushed or any(branch_flushed)
        else:
            if _stmt_contains_flush(st):
                flushed = True
                continue
            v = _stmt_violation(st)
            if v:
                return flushed, v
    return flushed, None


def _rule_flush_barrier(tree: ast.Module, out: list):
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        methods = {m.name: m for m in cls.body
                   if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
        if "_commit_pending" not in methods:
            continue
        for name in sorted(_BARRIER_METHODS & set(methods)):
            fn = methods[name]
            flushed, v = _scan_barrier(fn.body, False)
            if v and not flushed:
                line, col, what = v
                out.append((line, col, "flush-barrier",
                            f"{cls.name}.{name}: {what} before flush() — "
                            "the one-step-lagged write-back must land first"))
        if "release" in methods:
            fn = methods["release"]
            drains = any(_is_self_call(n, {"flush", "_commit_pending"})
                         for n in ast.walk(fn))
            if not drains:
                out.append((fn.lineno, fn.col_offset, "flush-barrier",
                            f"{cls.name}.release never drains the pipeline "
                            "(no flush()/_commit_pending()) — in-flight KV "
                            "write-back is dropped"))


# ---------------------------------------------------------------------------
# pallas-fetch-gate


def _index_map_node(call: ast.Call, defs: dict):
    """The index_map function node of a BlockSpec(...) call, if resolvable."""
    fn = None
    if len(call.args) >= 2:
        fn = call.args[1]
    for kw in call.keywords:
        if kw.arg == "index_map":
            fn = kw.value
    if isinstance(fn, ast.Lambda):
        return fn
    if isinstance(fn, ast.Name):
        return defs.get(fn.id)
    return None


def _rule_pallas_fetch_gate(tree: ast.Module, out: list):
    has_ineq_when = False
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and ((isinstance(node.func, ast.Attribute)
                      and node.func.attr == "when")
                     or (isinstance(node.func, ast.Name)
                         and node.func.id == "when"))
                and node.args):
            for sub in ast.walk(node.args[0]):
                if isinstance(sub, ast.Compare) and any(
                        isinstance(op, _INEQ) for op in sub.ops):
                    has_ineq_when = True
    if not has_ineq_when:
        return

    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef)}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and ((isinstance(node.func, ast.Attribute)
                      and node.func.attr == "BlockSpec")
                     or (isinstance(node.func, ast.Name)
                         and node.func.id == "BlockSpec"))):
            continue
        im = _index_map_node(node, defs)
        if im is None:
            continue
        params = {a.arg for a in im.args.args}
        body = im.body if isinstance(im, ast.Lambda) else im
        table_driven = False
        for sub in ast.walk(body):
            if (isinstance(sub, ast.Subscript)
                    and isinstance(sub.ctx, ast.Load)
                    and _root_name(sub) is not None
                    and any(isinstance(x, ast.Name) and x.id in params
                            for x in ast.walk(sub.slice))):
                table_driven = True
        clamped = any(isinstance(s, ast.Call)
                      and ((isinstance(s.func, ast.Attribute)
                            and s.func.attr in _CLAMP_FNS)
                           or (isinstance(s.func, ast.Name)
                               and s.func.id in _CLAMP_FNS))
                      for s in ast.walk(body))
        if table_driven and not clamped:
            out.append((node.lineno, node.col_offset, "pallas-fetch-gate",
                        "kernel gates with an inequality pl.when but this "
                        "table-driven index map never clamps its page index "
                        "— pl.when only skips compute; the pipeline still "
                        "DMAs the block the index map names. Clamp with "
                        "jnp.clip so out-of-range steps re-name an in-range "
                        "block and the fetch is elided"))


# ---------------------------------------------------------------------------
# positional-pool


def _rule_positional_pool(tree: ast.Module, out: list):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        elif isinstance(node.func, ast.Attribute):
            name = node.func.attr
        if name in _CTOR_NAMES and len(node.args) >= 2:
            out.append((node.lineno, node.col_offset, "positional-pool",
                        f"positional {name}(cfg, pool, ...) is deprecated — "
                        "use make_backend(...) or keyword arguments"))


# ---------------------------------------------------------------------------
# dense-kv-read


def _rule_dense_kv_read(tree: ast.Module, out: list):
    scopes = [tree] + [n for n in ast.walk(tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
    for scope in scopes:
        backends: set[str] = set()
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                fname = None
                if isinstance(node.value.func, ast.Name):
                    fname = node.value.func.id
                elif isinstance(node.value.func, ast.Attribute):
                    fname = node.value.func.attr
                if fname in _DENSE_SOURCES:
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            backends.add(tgt.id)
        if not backends:
            continue
        for node in ast.walk(scope):
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.ctx, ast.Load)
                    and node.attr in ("k", "v")
                    and isinstance(node.value, ast.Name)
                    and node.value.id in backends):
                out.append((node.lineno, node.col_offset, "dense-kv-read",
                            f"deprecated read of .{node.attr} on backend "
                            f"'{node.value.id}' — use kv_for_layer(l)"))


# ---------------------------------------------------------------------------
# drain-dirty-consumer


def _rule_drain_dirty(tree: ast.Module, relpath: str, out: list):
    rp = relpath.replace(os.sep, "/")
    if not rp.startswith("src/"):
        return
    if rp.endswith(_DRAIN_OK_FILES):
        return
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "drain_dirty"):
            out.append((node.lineno, node.col_offset, "drain-dirty-consumer",
                        "drain_dirty() outside the backend staging path — "
                        "a second consumer steals staged writes from "
                        "_staged_pages"))


# ---------------------------------------------------------------------------
# driver


def _suppressed(src_lines: list[str], line: int, rule: str) -> bool:
    if not (1 <= line <= len(src_lines)):
        return False
    m = _SUPPRESS_RE.search(src_lines[line - 1])
    return bool(m) and (m.group(1) is None or m.group(1) == rule)


def lint_source(src: str, relpath: str) -> list[Finding]:
    """Lint python source text as if it lived at ``relpath``."""
    tree = ast.parse(src)
    raw: list[tuple[int, int, str, str]] = []
    _rule_pool_kv_mutation(tree, raw)
    _rule_flush_barrier(tree, raw)
    _rule_pallas_fetch_gate(tree, raw)
    _rule_positional_pool(tree, raw)
    _rule_dense_kv_read(tree, raw)
    _rule_drain_dirty(tree, relpath, raw)
    lines = src.splitlines()
    findings = [Finding(relpath, ln, col, rule, msg)
                for ln, col, rule, msg in sorted(set(raw))
                if not _suppressed(lines, ln, rule)]
    return findings


def lint_file(path: str, relpath: str | None = None) -> list[Finding]:
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    return lint_source(src, relpath if relpath is not None else path)


_SKIP_DIRS = {"lint_fixtures", "__pycache__", ".git", ".venv"}


def iter_py_files(paths, root: str = "."):
    """Yield (abspath, relpath) for .py files under ``paths``."""
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            yield full, os.path.relpath(full, root)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS
                                 and not d.startswith("."))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    fp = os.path.join(dirpath, fn)
                    yield fp, os.path.relpath(fp, root)


def lint_paths(paths, root: str = ".") -> list[Finding]:
    findings: list[Finding] = []
    for full, rel in iter_py_files(paths, root):
        findings.extend(lint_file(full, rel))
    return findings
