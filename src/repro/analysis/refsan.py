"""Opt-in ``BlockPool`` shadow refcount sanitizer.

Wraps a live pool's mutating methods (``alloc``/``incref``/``decref``/
``reuse_cached``/``_free_block``/``write_kv``/``copy_block``/``touch``)
with instance-level shims that keep a *shadow* account of every block:
free / live / cached, a generation counter bumped per allocation, and
the call site (first frame outside the pool) that performed each alloc
and free.  Because block ids are recycled, a use-after-free by a stale
id is invisible to the pool itself — the shadow account catches it the
moment the stale holder touches the reused slot.

Findings reported:

``double-free``      decref/_free_block of an already-free block
``use-after-free``   incref/touch/write/copy of a free block (including
                     by id reuse — generation mismatch provenance)
``bad-alloc``        allocator handed out a block the shadow account
                     considers live/cached
``leak``             blocks still live at ``report(quiesced=True)``,
                     with the allocating call site

Usage::

    san = refsan.attach(pool)          # also accepts ShardedBlockPool
    ... exercise ...
    san.check()                        # raises on findings
    san.check(quiesced=True)           # additionally: no live blocks
    san.detach()

Pure stdlib; overhead is one dict update + a few frame hops per pool
op, fine for the CI soaks.
"""
from __future__ import annotations

import dataclasses
import sys

_SKIP_FILES = ("kvcache/pool.py", "analysis/refsan.py")

FREE, LIVE, CACHED = "free", "live", "cached"


def _call_site() -> str:
    """First stack frame outside pool.py / this module."""
    f = sys._getframe(1)
    while f is not None:
        fn = f.f_code.co_filename.replace("\\", "/")
        if not fn.endswith(_SKIP_FILES):
            return f"{fn.rsplit('/', 1)[-1]}:{f.f_lineno}:{f.f_code.co_name}"
        f = f.f_back
    return "<unknown>"


@dataclasses.dataclass(frozen=True)
class RefFinding:
    kind: str           # double-free | use-after-free | bad-alloc | leak
    bid: int
    gen: int
    op: str             # pool method that tripped it
    site: str           # call site of the offending op
    history: str        # where the block was alloc'd / freed before
    msg: str

    def __str__(self) -> str:
        return f"[{self.kind}] {self.msg}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Slot:
    __slots__ = ("state", "gen", "alloc_site", "free_site")

    def __init__(self, state: str):
        self.state = state
        self.gen = 0
        self.alloc_site = "<pre-attach>"
        self.free_site = "<never>"


class RefcountSanitizer:
    """Shadow accounting for one ``BlockPool``. Construct via
    :func:`attach`."""

    _WRAPPED = ("alloc", "incref", "decref", "reuse_cached", "_free_block",
                "write_kv", "copy_block", "touch")

    def __init__(self, pool):
        self.pool = pool
        self.findings: list[RefFinding] = []
        self._orig: dict = {}
        n = pool.cfg.num_blocks
        self._slots = [None] * n
        for bid in range(n):
            if not pool.used[bid]:
                st = FREE
            elif pool.refcount[bid] == 0:
                st = CACHED
            else:
                st = LIVE
            self._slots[bid] = _Slot(st)
        for name in self._WRAPPED:
            self._orig[name] = getattr(pool, name)
            setattr(pool, name, self._make_wrapper(name))

    # -- wrapping ----------------------------------------------------------

    def _make_wrapper(self, name: str):
        orig = self._orig[name]
        pre = getattr(self, f"_pre_{name}", None)

        def wrapper(*args, **kwargs):
            if pre is not None:
                pre(*args, **kwargs)
            out = orig(*args, **kwargs)
            post = getattr(self, f"_post_{name}", None)
            if post is not None:
                post(out, *args, **kwargs)
            return out

        wrapper.__name__ = f"refsan_{name}"
        return wrapper

    def detach(self):
        for name, orig in self._orig.items():
            # the originals are bound methods; deleting the instance
            # attribute restores class-level resolution
            try:
                delattr(self.pool, name)
            except AttributeError:
                setattr(self.pool, name, orig)
        self._orig.clear()

    # -- findings ----------------------------------------------------------

    def _flag(self, kind: str, bid: int, op: str, msg: str):
        slot = self._slots[bid]
        history = (f"alloc@{slot.alloc_site} free@{slot.free_site} "
                   f"gen={slot.gen}")
        self.findings.append(RefFinding(
            kind=kind, bid=bid, gen=slot.gen, op=op,
            site=_call_site(), history=history,
            msg=f"{msg} (block {bid}, {history})"))

    def _expect_held(self, bid: int, op: str):
        slot = self._slots[bid]
        if slot.state == FREE:
            self._flag("use-after-free", bid, op,
                       f"{op} on a freed block — stale id after "
                       f"{slot.gen} reuse(s)?")

    # -- per-op shims ------------------------------------------------------

    def _post_alloc(self, out, n, *a, **k):
        for bid in out:
            slot = self._slots[bid]
            if slot.state != FREE:
                self._flag("bad-alloc", bid, "alloc",
                           f"allocator handed out a {slot.state} block")
            slot.state = LIVE
            slot.gen += 1
            slot.alloc_site = _call_site()
            slot.free_site = "<never>"

    def _pre_incref(self, bid, *a, **k):
        self._expect_held(bid, "incref")

    def _post_incref(self, out, bid, *a, **k):
        self._sync(bid)

    def _pre_decref(self, bid, *a, **k):
        slot = self._slots[bid]
        if slot.state == FREE:
            self._flag("double-free", bid, "decref",
                       "decref of an already-free block")

    def _post_decref(self, out, bid, *a, **k):
        self._sync(bid)

    def _pre_reuse_cached(self, bid, *a, **k):
        self._expect_held(bid, "reuse_cached")

    def _post_reuse_cached(self, out, bid, *a, **k):
        self._sync(bid)

    def _pre__free_block(self, bid, *a, **k):
        slot = self._slots[bid]
        if slot.state == FREE:
            self._flag("double-free", bid, "_free_block",
                       "free of an already-free block")

    def _post__free_block(self, out, bid, *a, **k):
        slot = self._slots[bid]
        slot.state = FREE
        slot.free_site = _call_site()

    def _pre_write_kv(self, bid, *a, **k):
        self._expect_held(bid, "write_kv")

    def _pre_copy_block(self, src, dst, *a, **k):
        self._expect_held(src, "copy_block")
        self._expect_held(dst, "copy_block")

    def _pre_touch(self, bid, *a, **k):
        self._expect_held(bid, "touch")

    def _sync(self, bid: int):
        """Resync one slot's state from pool ground truth (decref may
        have cached or freed it)."""
        slot = self._slots[bid]
        if not self.pool.used[bid]:
            if slot.state != FREE:
                slot.state = FREE
                slot.free_site = _call_site()
        elif self.pool.refcount[bid] == 0:
            slot.state = CACHED
        else:
            slot.state = LIVE

    # -- reporting ---------------------------------------------------------

    def leaks(self) -> list[RefFinding]:
        out = []
        for bid, slot in enumerate(self._slots):
            if slot.state == LIVE:
                out.append(RefFinding(
                    kind="leak", bid=bid, gen=slot.gen, op="report",
                    site="<end-of-run>",
                    history=f"alloc@{slot.alloc_site} gen={slot.gen}",
                    msg=f"block {bid} still live at end of run "
                        f"(allocated at {slot.alloc_site}, "
                        f"refcount {int(self.pool.refcount[bid])})"))
        return out

    def report(self, quiesced: bool = False) -> dict:
        findings = list(self.findings)
        if quiesced:
            findings += self.leaks()
        return {
            "ok": not findings,
            "findings": [f.to_dict() for f in findings],
            "counts": {
                "free": sum(s.state == FREE for s in self._slots),
                "live": sum(s.state == LIVE for s in self._slots),
                "cached": sum(s.state == CACHED for s in self._slots),
            },
        }

    def check(self, quiesced: bool = False):
        rep = self.report(quiesced=quiesced)
        if not rep["ok"]:
            msgs = "\n  ".join(f["msg"] for f in rep["findings"][:20])
            raise AssertionError(
                f"refcount sanitizer: {len(rep['findings'])} finding(s)\n"
                f"  {msgs}")


class _MultiSanitizer:
    """One sanitizer per shard of a ``ShardedBlockPool``."""

    def __init__(self, pools):
        self.parts = [RefcountSanitizer(p) for p in pools]

    @property
    def findings(self):
        return [f for p in self.parts for f in p.findings]

    def leaks(self):
        return [f for p in self.parts for f in p.leaks()]

    def report(self, quiesced: bool = False) -> dict:
        reps = [p.report(quiesced=quiesced) for p in self.parts]
        return {
            "ok": all(r["ok"] for r in reps),
            "findings": [f for r in reps for f in r["findings"]],
            "counts": [r["counts"] for r in reps],
        }

    def check(self, quiesced: bool = False):
        for p in self.parts:
            p.check(quiesced=quiesced)

    def detach(self):
        for p in self.parts:
            p.detach()


def attach(pool):
    """Attach a sanitizer to a ``BlockPool`` or ``ShardedBlockPool``."""
    shards = getattr(pool, "shards", None)
    if shards is not None:
        return _MultiSanitizer(shards)
    return RefcountSanitizer(pool)
