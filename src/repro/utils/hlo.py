"""Post-optimization HLO analysis: collective-traffic accounting.

``compiled.cost_analysis()`` reports FLOPs and bytes but not collective
traffic; we parse the optimized HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[4,128,256]{2,1,0}  or  f32[] or (f32[2], bf16[3,4])
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY = re.compile(r"body=%?([\w.\-]+)")
_CALLEE = re.compile(r"(?:to_apply|condition|body)=%?([\w.\-]+)"
                     r"|calls=%?([\w.\-]+)")


def _execution_counts(hlo_text: str) -> dict:
    """Per-computation execution multiplier from the call graph.

    While bodies multiply by their ``known_trip_count`` annotation (the
    layer scan); everything else propagates its caller's count.  Without
    this, loop-body collectives/flops are counted once instead of x L.
    """
    comp_of_line: list[tuple[str, str]] = []
    cur = "__entry__"
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and ("{" in line or line.rstrip().endswith("{")):
            cur = m.group(1)
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        comp_of_line.append((cur, line))

    # edges: caller -> (callee, factor)
    edges = defaultdict(list)
    for comp, line in comp_of_line:
        if "=" not in line:
            continue
        trip = 1
        tm = _TRIP.search(line)
        body = _BODY.search(line)
        if tm and body:
            trip = int(tm.group(1))
        for m in _CALLEE.finditer(line):
            name = m.group(1) or m.group(2)
            factor = trip if (body and name == body.group(1)) else 1
            edges[comp].append((name, factor))

    counts = defaultdict(int)
    counts[entry or "__entry__"] = 1
    # propagate (call graph is a DAG; a few passes reach fixpoint)
    for _ in range(12):
        changed = False
        new = defaultdict(int, {entry or "__entry__": 1})
        for caller, outs in edges.items():
            c = counts.get(caller, 0)
            if not c:
                continue
            for callee, factor in outs:
                new[callee] += c * factor
        new[entry or "__entry__"] = 1
        if dict(new) != dict(counts):
            counts = new
            changed = True
        if not changed:
            break
    return dict(counts), (entry or "__entry__")


def collective_bytes(hlo_text: str) -> dict:
    """Sum *output* shape bytes per collective kind over the module,
    weighting each instruction by its computation's execution count
    (while-loop trip counts included).

    Returns {kind: bytes} plus "total" and per-kind op counts in
    "{kind}_count".
    """
    counts, entry = _execution_counts(hlo_text)
    out: dict = defaultdict(int)
    cur = entry
    for line in hlo_text.splitlines():
        hm = _COMP_HDR.match(line.strip())
        if hm and ("{" in line or line.rstrip().endswith("{")):
            cur = hm.group(1)
            continue
        s = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (\S+?)\(", s)
        if not m:
            continue
        shape_txt, opname = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-") \
                    or opname.startswith(c + "."):
                kind = c
                break
        if kind is None:
            continue
        # "-start" variants have matching "-done"; count starts only
        if opname.endswith("-done"):
            continue
        mult = counts.get(cur, 1) or 1
        b = _shape_bytes(shape_txt) * mult
        out[kind] += b
        out[f"{kind}_count"] += mult
    out["total"] = sum(out[c] for c in _COLLECTIVES if c in out)
    return dict(out)
