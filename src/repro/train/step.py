"""Distributed train step: loss -> grads -> optimizer, with optional
microbatch gradient accumulation and activation rematerialization.

The returned step function is pure and pjit-able; ``launch/train.py`` and
``launch/dryrun.py`` wrap it with in/out shardings from ``sharding/rules``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw as optim


@dataclasses.dataclass(frozen=True)
class TrainFlags:
    remat: bool = True
    microbatches: int = 1          # gradient-accumulation steps
    aux_weight: float = 0.01


def make_loss(cfg: ModelConfig, flags: TrainFlags):
    def loss(params, tokens, labels, frontend):
        return lm.loss_fn(params, cfg, tokens, labels, frontend,
                          remat=flags.remat, aux_weight=flags.aux_weight)
    return loss


def make_train_step(cfg: ModelConfig, opt_cfg: optim.OptConfig,
                    flags: TrainFlags = TrainFlags()):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    batch: {"tokens": (B,S), "labels": (B,S), "frontend": optional}.
    With flags.microbatches > 1 the batch's leading axis is split and
    gradients are accumulated in fp32 before one optimizer update (keeps
    peak activation memory ~1/k at the cost of k sequential passes).
    """
    loss_fn = make_loss(cfg, flags)

    def grads_of(params, tokens, labels, frontend):
        (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, tokens, labels, frontend)
        return l, aux, g

    def step(params, opt_state, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        frontend = batch.get("frontend")
        k = flags.microbatches
        if k > 1:
            B = tokens.shape[0]
            mb = B // k

            def body(carry, xs):
                acc, lsum = carry
                t, y = xs["t"], xs["y"]
                f = xs.get("f")
                l, _, g = grads_of(params, t, y, f)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = {"t": tokens.reshape(k, mb, -1),
                  "y": labels.reshape(k, mb, -1)}
            if frontend is not None:
                xs["f"] = frontend.reshape(k, mb, *frontend.shape[1:])
            (g, lsum), _ = jax.lax.scan(body, (zeros, 0.0), xs)
            g = jax.tree.map(lambda x: x / k, g)
            loss = lsum / k
        else:
            loss, _, g = grads_of(params, tokens, labels, frontend)

        params, opt_state, om = optim.opt_update(g, opt_state, params,
                                                 opt_cfg)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return step
