"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes dense / MoE / SSM / hybrid / encoder-decoder /
VLM transformer backbones.  ``src/repro/configs/<arch>.py`` instantiates the
exact published configurations; every arch also exposes a reduced ``smoke()``
variant for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    rope_theta: float = 10_000.0
    use_rope: bool = True
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = global attention
    global_every: int = 0          # hybrid: every k-th layer is global
    # normalization / mlp
    norm: str = "rms"              # rms | ln
    norm_eps: float = 1e-5
    act: str = "silu"              # silu | gelu
    mlp_gated: bool = True
    tie_embeddings: bool = False
    # positional fallback when use_rope=False
    max_position: int = 32_768

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0        # leading dense layers (e.g. kimi-k2)
    moe_dense_residual: bool = False  # parallel dense MLP (arctic)
    router_scale: float = 1.0

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    d_ssm_head: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # encoder-decoder / multimodal frontend
    enc_layers: int = 0
    frontend: str = ""             # "" | audio | image
    frontend_seq: int = 0          # stub frames / patches

    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    kv_dtype: str = ""             # KV-cache storage ("" = compute dtype)

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def kvdtype(self):
        return jnp.dtype(self.kv_dtype or self.compute_dtype)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        if not self.use_rope and self.family == "encdec":
            emb += self.max_position * d  # learned positions
        per_attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        per_mlp = d * f * (3 if self.mlp_gated else 2)
        per_moe = 0
        if self.is_moe:
            e = self.d_expert or f
            per_moe = (d * self.n_experts
                       + self.n_experts * d * e * 3
                       + self.n_shared_experts * d * e * 3)
        per_ssm = 0
        if self.has_ssm:
            di = self.d_inner_ssm
            ns = self.ssm_heads
            per_ssm = d * 2 * di + di * d + d * (2 * self.ssm_state) \
                + di * self.ssm_conv + 2 * ns + di
        blocks = 0
        for li in range(self.n_layers):
            blocks += per_attn if self.has_attention else 0
            blocks += per_ssm if self.has_ssm else 0
            if self.is_moe and li >= self.n_dense_layers:
                blocks += per_moe + (per_mlp if self.moe_dense_residual else 0)
            else:
                blocks += per_mlp if f else 0
        enc = 0
        if self.enc_layers:
            enc = self.enc_layers * (per_attn + per_mlp) \
                + self.n_layers * per_attn  # decoder cross-attention
        return emb + blocks + enc

    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        e = self.d_expert or self.d_ff
        inactive = (self.n_experts - self.top_k) * d * e * 3 \
            * (self.n_layers - self.n_dense_layers)
        return self.n_params() - inactive


# shape cells assigned to every LM-family architecture
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention: run only for SSM/hybrid
    (sliding-window or state-space) families; full-attention archs skip."""
    if cell.name == "long_500k" and not (
            cfg.family in ("ssm", "hybrid")):
        return False, "full attention at 524k context out of scope (per spec)"
    return True, ""
