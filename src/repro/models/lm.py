"""Unified causal LM covering every assigned architecture family.

One parameter tree, one scan-over-layers forward, three entry points:

  ``forward``            — teacher-forced training/prefill logits
  ``prefill``            — build the serving cache from a prompt
  ``decode_step``        — one-token serve step against the cache
  ``paged_decode_step``  — one-token serve step reading KV straight from
                           the block pool via the Pallas paged-attention
                           kernel (the PagedBackend's kernel decode path)

Families: dense / moe (leading-dense + shared experts + dense residual) /
ssm (mamba2) / hybrid (parallel attention+SSM heads, hymba-style) /
encdec (whisper: audio-frame encoder + cross-attention decoder) /
vlm (paligemma: image-patch prefix LM).  Modality frontends are stubs per
the assignment: ``frontend_emb`` carries precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_mod, ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import ParamBundle, _merge


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, li: int, *, decoder: bool = False,
                encoder: bool = False) -> ParamBundle:
    ks = jax.random.split(key, 8)
    items = []
    if cfg.has_attention:
        items += [("ln1", layers.norm_init(cfg)),
                  ("attn", layers.attention_init(ks[0], cfg))]
    if cfg.has_ssm and not encoder:
        items += [("ln_ssm", layers.norm_init(cfg)),
                  ("ssm", ssm_mod.ssm_init(ks[1], cfg))]
    if decoder:
        items += [("lnx", layers.norm_init(cfg)),
                  ("xattn", layers.attention_init(ks[2], cfg, cross=True))]
    is_moe_layer = cfg.is_moe and li >= cfg.n_dense_layers and not encoder
    if is_moe_layer:
        items += [("ln2", layers.norm_init(cfg)),
                  ("moe", moe_mod.moe_init(ks[3], cfg))]
        if cfg.moe_dense_residual:
            items += [("mlp", layers.mlp_init(ks[4], cfg))]
    elif cfg.d_ff:
        items += [("ln2", layers.norm_init(cfg)),
                  ("mlp", layers.mlp_init(ks[4], cfg))]
    return _merge(*items)


def _stack_bundles(bundles):
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[b.params for b in bundles])
    specs = jax.tree.map(lambda s: ("layers",) + tuple(s),
                         bundles[0].specs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return ParamBundle(params, specs)


def init(cfg: ModelConfig, key) -> ParamBundle:
    ks = jax.random.split(key, cfg.n_layers + cfg.enc_layers + 4)
    items = [("embed", layers.embedding_init(ks[0], cfg)),
             ("final_norm", layers.norm_init(cfg))]
    decoder = cfg.family == "encdec"
    nd = cfg.n_dense_layers if cfg.is_moe else 0
    if nd:
        items.append(("blocks_dense", _stack_bundles(
            [_block_init(ks[1 + i], cfg, 0, decoder=decoder)
             for i in range(nd)])))
    items.append(("blocks", _stack_bundles(
        [_block_init(ks[1 + nd + i], cfg, nd + i, decoder=decoder)
         for i in range(cfg.n_layers - nd)])))
    if cfg.enc_layers:
        enc = _stack_bundles(
            [_block_init(ks[1 + cfg.n_layers + i], cfg, i, encoder=True)
             for i in range(cfg.enc_layers)])
        items.append(("encoder", enc))
        items.append(("enc_norm", layers.norm_init(cfg)))
    return _merge(*items)


def abstract_init(cfg: ModelConfig):
    """Shape-only init (no allocation) — used by the dry-run."""
    return jax.eval_shape(lambda: init(cfg, jax.random.key(0)).params)


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------

def _is_global_layer(cfg: ModelConfig, li):
    """Hybrid archs: a few global-attention layers among sliding-window."""
    if cfg.sliding_window == 0:
        return jnp.ones((), bool) if isinstance(li, jnp.ndarray) else True
    if cfg.global_every:
        return li % cfg.global_every == 0
    return li < 0  # none


def _block_apply(bp, x, cfg: ModelConfig, *, masks, positions,
                 kv=None, cache_pos=None, ssm_state=None, xkv=None,
                 is_global=None, paged=None):
    """One transformer block.  Returns (x, new_kv, new_ssm_state, aux).

    ``paged`` routes decode attention through the Pallas paged-attention
    kernel (KV read straight from the pool's layered page buffers) instead
    of a dense cache view; everything around attention is unchanged."""
    aux = {}
    new_kv = None
    new_ssm = None
    attn_out = None
    if cfg.has_attention and paged is not None:
        h = layers.apply_norm(bp["ln1"], x, cfg)
        attn_out, new_kv = layers.paged_attention_apply(
            bp["attn"], h, cfg, lengths=paged["lengths"],
            k_pages=paged["k_pages"], v_pages=paged["v_pages"],
            page_tables=paged["page_tables"], layer=paged["layer"],
            window=paged.get("window", 0),
            interpret=paged["interpret"])
    elif cfg.has_attention:
        mask = masks[0]
        if cfg.sliding_window and is_global is not None:
            mask = jnp.where(is_global, masks[1], masks[0])
        h = layers.apply_norm(bp["ln1"], x, cfg)
        attn_out, new_kv = layers.attention_apply(
            bp["attn"], h, cfg, positions=positions, mask=mask,
            kv_cache=kv, cache_positions=cache_pos)
    if cfg.has_ssm:
        hs = layers.apply_norm(bp.get("ln_ssm", bp.get("ln1")), x, cfg)
        if ssm_state is not None:
            ssm_out, new_ssm = ssm_mod.ssm_apply(
                bp["ssm"], hs, cfg, state=ssm_state[0],
                conv_state=ssm_state[1], return_state=True)
        else:
            ssm_out, new_ssm = ssm_mod.ssm_apply(bp["ssm"], hs, cfg,
                                                 return_state=True)
        if attn_out is not None:
            # hymba: parallel heads, mean-combined
            x = x + 0.5 * (attn_out + ssm_out)
        else:
            x = x + ssm_out
    elif attn_out is not None:
        x = x + attn_out
    if "xattn" in bp and xkv is not None:
        h = layers.apply_norm(bp["lnx"], x, cfg)
        xo, _ = layers.attention_apply(bp["xattn"], h, cfg,
                                       positions=None, mask=None, xattn_kv=xkv)
        x = x + xo
    if "moe" in bp:
        h = layers.apply_norm(bp["ln2"], x, cfg)
        mo, aux = moe_mod.moe_apply(bp["moe"], h, cfg)
        if cfg.moe_dense_residual and "mlp" in bp:
            mo = mo + layers.mlp_apply(bp["mlp"], h, cfg)
        x = x + mo
    elif "mlp" in bp:
        h = layers.apply_norm(bp["ln2"], x, cfg)
        x = x + layers.mlp_apply(bp["mlp"], h, cfg)
    return x, new_kv, new_ssm, aux


def _zero_aux():
    return {"moe_lb": jnp.zeros((), jnp.float32),
            "moe_z": jnp.zeros((), jnp.float32)}


def _scan_blocks(stacked, x, cfg: ModelConfig, *, masks, positions,
                 layer_offset: int, n: int, kv=None, cache_pos=None,
                 ssm_states=None, xkv=None, remat: bool = False,
                 paged=None):
    """lax.scan over stacked block params (+ optional caches).

    ``paged``: kernel-path decode operands (pool page buffers + table +
    lengths); the absolute layer index rides the scan so every iteration
    reads its own plane of the layered pool through one shared table."""
    li = jnp.arange(layer_offset, layer_offset + n)
    glob = None
    if cfg.sliding_window:
        ge = max(cfg.global_every, 1)
        glob = (li % ge == 0) if cfg.global_every else jnp.zeros(n, bool)

    def body(carry, inp):
        xx, aux_acc = carry
        bp = inp["p"]
        paged_l = None
        if paged is not None:
            paged_l = dict(paged, layer=inp["li"])
            if cfg.sliding_window:
                # per-layer global/window flag: global layers attend the
                # whole cache (window 0), the rest apply the sliding
                # window — one traced int32 rides the scan, so one
                # compiled kernel serves a global_every hybrid
                paged_l["window"] = jnp.where(
                    inp["glob"], 0, cfg.sliding_window).astype(jnp.int32)
        out, new_kv, new_ssm, aux = _block_apply(
            bp, xx, cfg, masks=masks, positions=positions,
            kv=inp.get("kv"), cache_pos=cache_pos,
            ssm_state=inp.get("ssm"), xkv=inp.get("xkv"),
            is_global=inp.get("glob"), paged=paged_l)
        for k in aux_acc:
            aux_acc = dict(aux_acc)
            aux_acc[k] = aux_acc[k] + aux.get(k, 0.0)
        ys = {}
        if new_kv is not None:
            ys["kv"] = new_kv
        if new_ssm is not None:
            ys["ssm"] = new_ssm
        return (out, aux_acc), ys

    fn = jax.checkpoint(body) if remat else body
    xs: dict = {"p": stacked}
    if kv is not None:
        xs["kv"] = kv
    if ssm_states is not None:
        xs["ssm"] = ssm_states
    if xkv is not None:
        xs["xkv"] = xkv
    if glob is not None:
        xs["glob"] = glob
    if paged is not None:
        xs["li"] = jnp.asarray(li, jnp.int32)
    (x, aux), ys = jax.lax.scan(fn, (x, _zero_aux()), xs)
    return x, aux, ys


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _encoder_forward(params, cfg: ModelConfig, frontend_emb):
    x = frontend_emb.astype(cfg.cdtype)
    S = x.shape[1]
    masks = (jnp.ones((S, S), bool), None)
    positions = jnp.arange(S)[None, :]
    x, _, _ = _scan_blocks(params["encoder"], x, cfg, masks=masks,
                           positions=positions, layer_offset=0,
                           n=cfg.enc_layers)
    return layers.apply_norm(params["enc_norm"], x, cfg)


def _cross_kvs(params, cfg: ModelConfig, enc_out):
    def body(_, bp):
        return None, layers.cross_kv(bp["xattn"], enc_out, cfg)
    _, kvs = jax.lax.scan(body, None, params["blocks"])
    return kvs


def forward(params, cfg: ModelConfig, tokens, frontend_emb=None,
            remat: bool = False):
    """Teacher-forced logits.  tokens: (B, S) int32.

    encdec: frontend_emb (B, Senc, d) feeds the encoder.
    vlm: frontend_emb (B, P, d) is prepended as a bidirectional prefix;
    logits are returned for the token part only."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = layers.embed_tokens(params["embed"], tokens, cfg, positions)
    xkv = None
    prefix = 0
    if cfg.family == "encdec":
        enc_out = _encoder_forward(params, cfg, frontend_emb)
        xkv = _cross_kvs(params, cfg, enc_out)
    elif cfg.family == "vlm":
        pimg = frontend_emb.astype(cfg.cdtype)
        prefix = pimg.shape[1]
        x = jnp.concatenate([pimg, x], axis=1)
        positions = jnp.broadcast_to(
            jnp.arange(prefix + S)[None, :], (B, prefix + S))
    Sq = x.shape[1]
    m_causal = layers.causal_mask(Sq, Sq, prefix_len=prefix or None)
    m_window = layers.causal_mask(Sq, Sq, window=cfg.sliding_window,
                                  prefix_len=prefix or None) \
        if cfg.sliding_window else m_causal
    masks = (m_window if cfg.sliding_window else m_causal, m_causal)

    nd = cfg.n_dense_layers if cfg.is_moe else 0
    aux_total = _zero_aux()
    if nd:
        x, aux, _ = _scan_blocks(params["blocks_dense"], x, cfg, masks=masks,
                                 positions=positions, layer_offset=0, n=nd,
                                 remat=remat)
        aux_total = {k: aux_total[k] + aux[k] for k in aux_total}
    x, aux, _ = _scan_blocks(params["blocks"], x, cfg, masks=masks,
                             positions=positions, layer_offset=nd,
                             n=cfg.n_layers - nd, xkv=xkv, remat=remat)
    aux_total = {k: aux_total[k] + aux[k] for k in aux_total}
    x = layers.apply_norm(params["final_norm"], x, cfg)
    if prefix:
        x = x[:, prefix:]
    logits = layers.lm_head(params["embed"], x, cfg)
    return logits, aux_total


# ---------------------------------------------------------------------------
# Serving cache — dense storage (the DenseBackend's pytree)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Cache:
    k: Any            # (L, B, Smax, K, dh) or None
    v: Any
    ssm: Any          # (L, B, H, P, N) or None
    conv: Any         # (L, B, k-1, ch) or None
    xk: Any           # (L, B, Senc, K, dh) or None (encdec)
    xv: Any
    length: Any       # int32 — tokens already cached; scalar, or (B,) for
                      # ragged (per-sequence) decode


def init_dense_cache(cfg: ModelConfig, batch: int, max_seq: int,
                     enc_len: int = 0) -> Cache:
    """The dense per-layer storage pytree (jit/sharding friendly)."""
    L, K, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    cd = cfg.kvdtype
    k = v = ssm = conv = xk = xv = None
    if cfg.has_attention:
        k = jnp.zeros((L, batch, max_seq, K, dh), cd)
        v = jnp.zeros((L, batch, max_seq, K, dh), cd)
    if cfg.has_ssm:
        (ss, cs) = ssm_mod.ssm_state_shapes(cfg, batch)
        ssm = jnp.zeros((L,) + ss, jnp.float32)
        conv = jnp.zeros((L,) + cs, cd)
    if cfg.family == "encdec":
        xk = jnp.zeros((L, batch, enc_len, K, dh), cd)
        xv = jnp.zeros((L, batch, enc_len, K, dh), cd)
    return Cache(k, v, ssm, conv, xk, xv, jnp.zeros((), jnp.int32))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_len: int = 0, kind: str = "dense", **backend_kw):
    """Build a KV backend (``kind``: "dense" | "paged").

    The serving entry point of the KVBackend API: returns a
    ``kvcache.backend.KVBackend`` whose ``prefill``/``decode_step`` drive
    this model.  ``DenseBackend`` forwards ``.k``/``.v``/``.length`` reads
    to its underlying ``Cache``, so code written against the old concrete
    cache keeps working.
    """
    from repro.kvcache.backend import make_backend
    return make_backend(cfg, kind, batch=batch, max_seq=max_seq,
                        enc_len=enc_len, **backend_kw)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   enc_len: int = 0):
    """Shape-only dense storage pytree (dry-run / sharding planning)."""
    return jax.eval_shape(
        lambda: init_dense_cache(cfg, batch, max_seq, enc_len))


def dense_decode_step(params, cfg: ModelConfig, tokens, cache: Cache):
    """One-token decode against dense storage (pure; jit/shard friendly).

    tokens: (B, 1) int32.  ``cache.length`` may be a scalar (all lanes at
    the same position) or an int32 (B,) vector for ragged decode — the
    paged backend decodes continuous-batching lanes whose sequences have
    different lengths in one call.  Returns (logits, cache).
    """
    B = tokens.shape[0]
    pos = jnp.asarray(cache.length)
    ragged = pos.ndim > 0
    posv = jnp.broadcast_to(jnp.atleast_1d(pos), (B,))
    positions = posv[:, None]
    x = layers.embed_tokens(params["embed"], tokens, cfg, positions)

    masks = None
    kv = None
    if cfg.has_attention:
        Smax = cache.k.shape[2]
        kpos = jnp.arange(Smax)[None, :]
        m_causal = kpos <= posv[:, None]
        m = m_causal
        if cfg.sliding_window:
            m = m_causal & (kpos > posv[:, None] - cfg.sliding_window)
        masks = (m[:, None, None, :] if cfg.sliding_window else
                 m_causal[:, None, None, :],
                 m_causal[:, None, None, :])
        kv = (cache.k, cache.v)
    ssm_states = (cache.ssm, cache.conv) if cfg.has_ssm else None
    xkv = (cache.xk, cache.xv) if cfg.family == "encdec" else None
    cache_pos = posv if ragged else pos

    nd = cfg.n_dense_layers if cfg.is_moe else 0
    ys_all = {}
    if nd:
        kv_d = jax.tree.map(lambda a: a[:nd], kv) if kv is not None else None
        x, _, ys = _scan_blocks(params["blocks_dense"], x, cfg, masks=masks,
                                positions=positions, layer_offset=0, n=nd,
                                kv=kv_d, cache_pos=cache_pos,
                                ssm_states=jax.tree.map(
                                    lambda a: a[:nd], ssm_states)
                                if ssm_states else None)
        ys_all["dense"] = ys
    kv_m = jax.tree.map(lambda a: a[nd:], kv) if kv is not None else None
    x, _, ys = _scan_blocks(
        params["blocks"], x, cfg, masks=masks, positions=positions,
        layer_offset=nd, n=cfg.n_layers - nd, kv=kv_m, cache_pos=cache_pos,
        ssm_states=jax.tree.map(lambda a: a[nd:], ssm_states)
        if ssm_states else None,
        xkv=xkv)
    ys_all["main"] = ys

    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = layers.lm_head(params["embed"], x, cfg)

    def _cat(name, idx):
        parts = []
        if nd and name in ys_all["dense"]:
            parts.append(ys_all["dense"][name][idx])
        if name in ys_all["main"]:
            parts.append(ys_all["main"][name][idx])
        return jnp.concatenate(parts, 0) if parts else None

    new_cache = Cache(
        k=_cat("kv", 0) if cfg.has_attention else None,
        v=_cat("kv", 1) if cfg.has_attention else None,
        ssm=_cat("ssm", 0) if cfg.has_ssm else None,
        conv=_cat("ssm", 1) if cfg.has_ssm else None,
        xk=cache.xk, xv=cache.xv,
        length=cache.length + 1)
    return logits, new_cache


def paged_decode_step(params, cfg: ModelConfig, tokens, k_pages, v_pages,
                      page_tables, lengths, *, ssm_state=None,
                      conv_state=None, interpret: bool = True):
    """One-token decode reading cached KV straight from the block pool via
    the Pallas ``paged_attention`` kernel — no gathered dense view.

    tokens: (B, 1) int32; k_pages/v_pages: the pool's layered
    (L, P, page, K, dh) buffers; page_tables: (B, n_pages) int32;
    lengths: (B,) int32 ragged per-lane cached token counts.  One page
    table serves every layer (the pool's layer axis = one placement
    decision per block id).  Sliding-window configs run natively: the
    scan flips the kernel's window mask per layer (``global_every``
    hybrids keep their global layers unmasked).

    Hybrid (attention + SSM) families thread their side state through the
    scan: ``ssm_state`` (L, B, H, P, N) float32 and ``conv_state``
    (L, B, k-1, ch) ride alongside the page operands — the PagedBackend
    keeps them per-sequence next to the block tables.

    Returns (logits (B, 1, V), k_new, v_new, ssm_new, conv_new) with
    k_new/v_new (L, B, 1, K, dh) — the in-flight token's per-layer K/V
    for the caller's pool write-back (write-after-attend: the kernel
    never reads a partially-written page) — and ssm_new/conv_new the
    advanced side state (None for attention-only families).
    """
    assert cfg.has_attention and cfg.family not in ("encdec", "vlm"), \
        f"kernel-path decode pages attention KV (+ SSM side state) only " \
        f"(family {cfg.family!r})"
    if cfg.has_ssm:
        assert ssm_state is not None and conv_state is not None, \
            "hybrid kernel-path decode needs ssm_state/conv_state"
    ssm_states = (ssm_state, conv_state) if cfg.has_ssm else None
    B = tokens.shape[0]
    lengths = jnp.asarray(lengths, jnp.int32)
    positions = lengths[:, None]
    x = layers.embed_tokens(params["embed"], tokens, cfg, positions)
    paged = dict(k_pages=k_pages, v_pages=v_pages, page_tables=page_tables,
                 lengths=lengths, interpret=interpret)

    nd = cfg.n_dense_layers if cfg.is_moe else 0
    ys_all = {}
    if nd:
        x, _, ys = _scan_blocks(params["blocks_dense"], x, cfg, masks=None,
                                positions=positions, layer_offset=0, n=nd,
                                ssm_states=jax.tree.map(
                                    lambda a: a[:nd], ssm_states)
                                if ssm_states else None,
                                paged=paged)
        ys_all["dense"] = ys
    x, _, ys = _scan_blocks(params["blocks"], x, cfg, masks=None,
                            positions=positions, layer_offset=nd,
                            n=cfg.n_layers - nd,
                            ssm_states=jax.tree.map(
                                lambda a: a[nd:], ssm_states)
                            if ssm_states else None,
                            paged=paged)
    ys_all["main"] = ys

    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = layers.lm_head(params["embed"], x, cfg)

    def _cat(name, idx):
        parts = []
        if nd and name in ys_all["dense"]:
            parts.append(ys_all["dense"][name][idx])
        if name in ys_all["main"]:
            parts.append(ys_all["main"][name][idx])
        return jnp.concatenate(parts, 0) if parts else None

    return (logits, _cat("kv", 0), _cat("kv", 1),
            _cat("ssm", 0), _cat("ssm", 1))


def decode_step(params, cfg: ModelConfig, tokens, cache):
    """One-token decode.  ``cache`` is either a concrete dense ``Cache``
    pytree (pure path, used under jit by the dry-run and the dense
    backend) or any ``KVBackend``.  Returns (logits, cache)."""
    if isinstance(cache, Cache):
        return dense_decode_step(params, cfg, tokens, cache)
    logits = cache.decode_step(params, tokens)
    return logits, cache


def prefill_parts(params, cfg: ModelConfig, tokens, frontend_emb=None):
    """Run the prompt, returning last-position logits plus every cacheable
    part — the storage-agnostic half of prefill that both backends share.

    Returns (logits (B,1,V), parts) with parts:
      k/v   (L, B, S, K, dh) or None   (post-RoPE, compute dtype)
      ssm   (L, B, H, P, N) or None    conv (L, B, k-1, ch) or None
      xk/xv (L, B, Senc, K, dh) or None
    """
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = layers.embed_tokens(params["embed"], tokens, cfg, positions)
    xkv = None
    if cfg.family == "encdec":
        enc_out = _encoder_forward(params, cfg, frontend_emb)
        xkv = _cross_kvs(params, cfg, enc_out)
    m_causal = layers.causal_mask(S, S)
    m_window = layers.causal_mask(S, S, window=cfg.sliding_window) \
        if cfg.sliding_window else m_causal
    masks = (m_window if cfg.sliding_window else m_causal, m_causal)
    nd = cfg.n_dense_layers if cfg.is_moe else 0
    ys_all = {}
    if nd:
        x, _, ys = _scan_blocks(params["blocks_dense"], x, cfg, masks=masks,
                                positions=positions, layer_offset=0, n=nd)
        ys_all["dense"] = ys
    x, _, ys = _scan_blocks(params["blocks"], x, cfg, masks=masks,
                            positions=positions, layer_offset=nd,
                            n=cfg.n_layers - nd, xkv=xkv)
    ys_all["main"] = ys
    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = layers.lm_head(params["embed"], x[:, -1:], cfg)

    def _cat(name, idx):
        parts = []
        if nd and name in ys_all.get("dense", {}):
            parts.append(ys_all["dense"][name][idx])
        if name in ys_all["main"]:
            parts.append(ys_all["main"][name][idx])
        return jnp.concatenate(parts, 0) if parts else None

    parts = {
        "k": _cat("kv", 0) if cfg.has_attention else None,
        "v": _cat("kv", 1) if cfg.has_attention else None,
        "ssm": _cat("ssm", 0) if cfg.has_ssm else None,
        "conv": _cat("ssm", 1) if cfg.has_ssm else None,
        "xk": xkv[0] if xkv is not None else None,
        "xv": xkv[1] if xkv is not None else None,
    }
    return logits, parts


def dense_prefill(params, cfg: ModelConfig, tokens, max_seq: int,
                  frontend_emb=None):
    """Prompt -> (logits, concrete dense Cache)."""
    B, S = tokens.shape
    cache = init_dense_cache(cfg, B, max_seq,
                             enc_len=frontend_emb.shape[1]
                             if cfg.family == "encdec" else 0)
    logits, parts = prefill_parts(params, cfg, tokens, frontend_emb)
    if cfg.has_attention:
        cache.k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, parts["k"].astype(cache.k.dtype), 0, axis=2)
        cache.v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, parts["v"].astype(cache.v.dtype), 0, axis=2)
    if cfg.has_ssm:
        cache.ssm = parts["ssm"]
        cache.conv = parts["conv"]
    if cfg.family == "encdec":
        cache.xk, cache.xv = parts["xk"], parts["xv"]
    cache.length = jnp.asarray(S, jnp.int32)
    return logits, cache


def prefill(params, cfg: ModelConfig, tokens, max_seq: int = 0,
            frontend_emb=None, backend=None):
    """Run the prompt through the model, building the serving cache.

    Returns (logits, backend).  With ``backend=None`` a ``DenseBackend``
    sized by ``max_seq`` is created; pass a ``PagedBackend`` to prefill
    into pool block tables instead.
    """
    if backend is None:
        assert max_seq, "prefill needs max_seq (or an explicit backend)"
        backend = init_cache(cfg, tokens.shape[0], max_seq,
                             enc_len=frontend_emb.shape[1]
                             if cfg.family == "encdec" else 0)
    logits = backend.prefill(params, tokens, frontend_emb=frontend_emb)
    return logits, backend


def loss_fn(params, cfg: ModelConfig, tokens, labels, frontend_emb=None,
            remat: bool = False, aux_weight: float = 0.01):
    """Causal LM cross-entropy with MoE aux losses."""
    logits, aux = forward(params, cfg, tokens, frontend_emb, remat=remat)
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    ll = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(ll, safe[..., None], axis=-1)[..., 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    loss = loss + aux_weight * (aux["moe_lb"] + 1e-3 * aux["moe_z"])
    return loss, {"lm_loss": loss, **aux}
