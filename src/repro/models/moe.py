"""Mixture-of-Experts layer with MARS-sorted dispatch.

This is the paper's technique mapped onto TPU: token->expert assignments
arrive interleaved (tokens in sequence order = the merged GPU streams); the
locality-oblivious baseline streams every token through capacity buffers for
every expert (GShard one-hot einsum — the "no MARS" path).  The MARS path
buffers a step's token window, *sorts assignments by destination expert*
("page"), moves them with a single all-to-all, and runs a contiguous
grouped matmul per expert — sequential HBM reads of each expert's weights,
full MXU tiles, then inverse-permute.  ``core/reorder.py`` provides the
sort; ``kernels/moe_dispatch`` provides the TPU Pallas grouped matmul (the
jnp path below uses ``lax.ragged_dot`` so everything compiles on any
backend).

Expert weights are sharded on the ``model`` mesh axis (expert parallelism);
tokens are sharded on ``data`` (and ``pod``).  The dispatch all-to-all runs
inside ``shard_map`` along ``model`` only, so it never crosses pods for
token movement — only gradient all-reduce does.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reorder import mars_sort_by_page, inverse_permutation
from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import context as shctx


def moe_init(key, cfg: ModelConfig) -> layers.ParamBundle:
    d = cfg.d_model
    e = cfg.d_expert or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    items = [
        ("router", layers._dense_init(ks[0], (d, E), ("embed", "expert"),
                                      jnp.float32)),
        ("w_in", layers._dense_init(ks[1], (E, d, e),
                                    ("expert", "embed", "mlp"), cfg.pdtype)),
        ("w_gate", layers._dense_init(ks[2], (E, d, e),
                                      ("expert", "embed", "mlp"), cfg.pdtype)),
        ("w_out", layers._dense_init(ks[3], (E, e, d),
                                     ("expert", "mlp", "embed"), cfg.pdtype)),
    ]
    if cfg.n_shared_experts:
        shared = layers.mlp_init(ks[4], cfg,
                                 d_ff=e * cfg.n_shared_experts)
        items.append(("shared", shared))
    return layers._merge(*items)


def router_topk(p, x, cfg: ModelConfig):
    """Returns (expert_idx (T,k), gates (T,k), aux losses) for flat tokens."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style) + router z-loss
    T = x.shape[0]
    me = probs.mean(0)
    ce = jnp.zeros(cfg.n_experts).at[idx.reshape(-1)].add(1.0) / (
        T * cfg.top_k)
    aux_lb = cfg.n_experts * jnp.sum(me * ce)
    aux_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return idx, gates.astype(x.dtype), {"moe_lb": aux_lb, "moe_z": aux_z}


# ---------------------------------------------------------------------------
# MARS-sorted grouped dispatch
# ---------------------------------------------------------------------------

def _grouped_ffn(tokens, local_ids, w_in, w_gate, w_out, n_local: int,
                 act: str):
    """Sorted grouped matmul over contiguous per-expert segments.

    tokens: (M, d) already MARS-sorted by ``local_ids`` (invalid rows zeroed
    and assigned to the last group).  Uses lax.ragged_dot (the Pallas
    moe_dispatch kernel implements the same contract on TPU).
    """
    group_sizes = jnp.bincount(local_ids, length=n_local)
    h = jax.lax.ragged_dot(tokens, w_in, group_sizes)
    g = jax.lax.ragged_dot(tokens, w_gate, group_sizes)
    h = layers._act(g, act) * h
    return jax.lax.ragged_dot(h, w_out, group_sizes)


def _mars_dispatch_local(p, xf, cfg: ModelConfig):
    """Single-shard MARS dispatch: sort assignments by expert, grouped
    matmul, unsort.  (T, d) -> (T, d)."""
    E, k = cfg.n_experts, cfg.top_k
    idx, gates, aux = router_topk(p, xf, cfg)
    T = xf.shape[0]
    flat_e = idx.reshape(-1)                      # (T*k,)
    perm, inv, sorted_e, _ = mars_sort_by_page(flat_e, E)
    tok_of = perm // k                            # source token per slot
    cd = cfg.cdtype
    gathered = xf[tok_of].astype(cd)              # (T*k, d) page-ordered
    out_sorted = _grouped_ffn(gathered, sorted_e, p["w_in"].astype(cd),
                              p["w_gate"].astype(cd), p["w_out"].astype(cd),
                              E, cfg.act)
    out_flat = out_sorted[inv]                    # back to assignment order
    w = gates.reshape(-1, 1).astype(cd)
    y = jnp.zeros_like(xf).at[jnp.arange(T * k) // k].add(out_flat * w)
    return y, aux


def _mars_dispatch_sharded(p, xf, cfg: ModelConfig, mesh):
    """shard_map dispatch: tokens sharded on data axes (replicated over
    model), experts sharded on model.

    Every model column holds the full token window for its data row; it
    MARS-sorts assignments by expert, keeps the contiguous slice destined
    to *its* expert shard, runs the sorted grouped matmul, and the partial
    outputs are psum-combined over the model axis.  Token traffic is zero;
    the psum is the per-layer collective (see EXPERIMENTS §Perf for the
    all-to-all variant trade-off).
    """
    from jax.sharding import PartitionSpec as P
    E, k = cfg.n_experts, cfg.top_k
    n_model = mesh.shape["model"]
    E_loc = E // n_model
    daxes = shctx.data_axes(mesh)
    cd = cfg.cdtype

    # per-column capacity: the RequestQ-slot bound of the paper.  Each
    # column computes ONLY its contiguous MARS-sorted slice (expected
    # A/n_model rows, 2x headroom); overflow beyond capacity is dropped,
    # exactly the capacity-factor semantics of production MoE (§Perf C1:
    # without this every column multiplies all A rows -> 16x wasted flops).
    def body(pr, w_in, w_gate, w_out, x):
        T = x.shape[0]
        d = x.shape[1]
        col = jax.lax.axis_index("model")
        idx, gates, aux = router_topk({"router": pr}, x, cfg)
        A = T * k
        import os
        C = A if os.environ.get("REPRO_MOE_FULL") else \
            int(np.ceil(A / n_model * 2.0))
        flat_e = idx.reshape(-1)
        # ---- MARS reorder: group assignments by destination expert
        perm, inv, sorted_e, _ = mars_sort_by_page(flat_e, E)
        tok_of = perm // k
        gathered = x[tok_of].astype(cd)                    # (A, d)
        # ---- slice this column's contiguous block [lo, lo+C)
        lo = jnp.searchsorted(sorted_e, col * E_loc).astype(jnp.int32)
        xpad = jnp.concatenate([gathered, jnp.zeros((C, d), cd)])
        epad = jnp.concatenate([sorted_e,
                                jnp.full((C,), E, sorted_e.dtype)])
        xin = jax.lax.dynamic_slice(xpad, (lo, jnp.int32(0)), (C, d))
        e_c = jax.lax.dynamic_slice_in_dim(epad, lo, C)
        mine = (e_c // E_loc) == col
        eloc = jnp.where(mine, e_c % E_loc, E_loc)         # E_loc = dump grp
        xin = jnp.where(mine[:, None], xin, 0)
        # already sorted (contiguous slice of a sorted array)
        gsz = jnp.bincount(eloc, length=E_loc + 1)
        pad = jnp.zeros((1,) + w_in.shape[1:], w_in.dtype)
        h = jax.lax.ragged_dot(xin, jnp.concatenate([w_in, pad]), gsz)
        g = jax.lax.ragged_dot(xin, jnp.concatenate([w_gate, pad]), gsz)
        h = layers._act(g, cfg.act) * h
        padT = jnp.zeros((1,) + w_out.shape[1:], w_out.dtype)
        out_c = jax.lax.ragged_dot(h, jnp.concatenate([w_out, padT]), gsz)
        out_c = jnp.where(mine[:, None], out_c, 0)
        # ---- scatter the block back to assignment order
        outpad = jnp.zeros((A + C, d), cd)
        outpad = jax.lax.dynamic_update_slice(outpad, out_c,
                                              (lo, jnp.int32(0)))
        out = outpad[:A][inv]
        w = gates.reshape(-1, 1).astype(cd)
        y = jnp.zeros_like(x).at[jnp.arange(A) // k].add(out * w)
        y = jax.lax.psum(y, "model")
        return y, aux["moe_lb"][None], aux["moe_z"][None]

    spec_tok = P(daxes if daxes else None)
    aux_spec = P(daxes if daxes else None)
    y, lb, zz = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"), spec_tok),
        out_specs=(spec_tok, aux_spec, aux_spec),
        check_vma=False,
    )(p["router"], p["w_in"], p["w_gate"], p["w_out"], xf)
    return y, {"moe_lb": lb.mean(), "moe_z": zz.mean()}


def moe_apply_einsum(p, xf, cfg: ModelConfig):
    """Locality-oblivious baseline (GShard one-hot capacity dispatch): every
    token window is streamed through per-expert capacity buffers — the
    "interleaved streams" path MARS removes."""
    E, k = cfg.n_experts, cfg.top_k
    T = xf.shape[0]
    idx, gates, aux = router_topk(p, xf, cfg)
    cap = max(1, int(np.ceil(T * k / E * 2.0)))
    # position of each assignment within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)       # (T,k,E)
    pos = jnp.cumsum(onehot.reshape(T * k, E), axis=0) - 1
    pos = pos.reshape(T, k, E)
    keep = (pos < cap) & (onehot > 0)
    disp = jax.nn.one_hot(pos, cap, dtype=xf.dtype) * keep[..., None]
    disp = (disp * gates[..., None, None]).sum(1)          # (T,E,cap) combine
    sel = jax.nn.one_hot(pos, cap, dtype=xf.dtype) * keep[..., None]
    sel = sel.sum(1)                                       # (T,E,cap) 0/1
    cd = cfg.cdtype
    ex_in = jnp.einsum("td,tec->ecd", xf.astype(cd), sel.astype(cd))
    h = jnp.einsum("ecd,edf->ecf", ex_in, p["w_in"].astype(cd))
    g = jnp.einsum("ecd,edf->ecf", ex_in, p["w_gate"].astype(cd))
    h = layers._act(g, cfg.act) * h
    out = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(cd))
    y = jnp.einsum("ecd,tec->td", out, disp.astype(cd))
    return y, aux


@dataclasses.dataclass(frozen=True)
class MoeRuntime:
    dispatch: str = "mars"         # mars | einsum


_RUNTIME = MoeRuntime()


def set_dispatch(mode: str):
    global _RUNTIME
    _RUNTIME = MoeRuntime(dispatch=mode)


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (B, S, d); adds shared-expert path if configured."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    mesh = shctx.current_mesh()
    if _RUNTIME.dispatch == "einsum":
        y, aux = moe_apply_einsum(p, xf, cfg)
    elif mesh is not None and mesh.shape.get("model", 1) > 1 \
            and cfg.n_experts % mesh.shape["model"] == 0:
        y, aux = _mars_dispatch_sharded(p, xf, cfg, mesh)
    else:
        y, aux = _mars_dispatch_local(p, xf, cfg)
    y = y.reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + layers.mlp_apply(p["shared"], x, cfg)
    return y, aux
