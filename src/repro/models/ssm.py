"""Mamba2 (SSD — state-space duality) layer, chunked, with O(1) decode.

Implements the SSD algorithm of arXiv:2405.21060: within-chunk quadratic
(attention-like) term + inter-chunk state recurrence.  The chunked scan is
the perf-critical inner loop; ``kernels/ssd_scan`` provides the Pallas TPU
version of the same contract, this module is the jnp reference used on CPU
and by the dry-run.

Shapes: d_inner = expand*d_model, H = d_inner/d_ssm_head heads of size P,
state size N, single B/C group shared across heads (n_groups=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.d_inner_ssm
    P = cfg.d_ssm_head
    H = d_in // P
    N = cfg.ssm_state
    return d_in, H, P, N


def ssm_init(key, cfg: ModelConfig) -> layers.ParamBundle:
    """Projections are kept in shard-ALIGNED groups: (z|x) both live on the
    TP-sharded ssm_in axis (split offset d_in is a multiple of the shard),
    while the small B/C/dt block is replicated.  A single fused projection
    splits at offsets that cross shard boundaries and GSPMD repairs every
    split with collective-permutes — 6.2e11 B/chip on mamba2 train_4k
    (§Perf E1)."""
    d = cfg.d_model
    d_in, H, P, N = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    items = [
        ("w_zx", layers._dense_init(
            ks[0], (d, 2 * d_in), ("embed", "ssm_in"), cfg.pdtype)),
        ("w_bcdt", layers._dense_init(
            ks[3], (d, 2 * N + H), ("embed", "ssm_small"), cfg.pdtype)),
        ("conv_w", layers._dense_init(
            ks[1], (cfg.ssm_conv, d_in), ("conv", "ssm_in"), cfg.pdtype,
            scale=1.0 / np.sqrt(cfg.ssm_conv))),
        ("conv_b", layers._zeros_init((d_in,), ("ssm_in",), cfg.pdtype)),
        ("conv_w_bc", layers._dense_init(
            ks[4], (cfg.ssm_conv, 2 * N), ("conv", "ssm_small"), cfg.pdtype,
            scale=1.0 / np.sqrt(cfg.ssm_conv))),
        ("conv_b_bc", layers._zeros_init((2 * N,), ("ssm_small",),
                                         cfg.pdtype)),
        ("a_log", layers.ParamBundle(
            jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
            ("ssm_heads",))),
        ("dt_bias", layers._zeros_init((H,), ("ssm_heads",), jnp.float32)),
        ("d_skip", layers._ones_init((H,), ("ssm_heads",), jnp.float32)),
        ("norm", layers._ones_init((d_in,), ("ssm_in",), cfg.pdtype)),
        ("w_out", layers._dense_init(ks[2], (d_in, d), ("ssm_in", "embed"),
                                     cfg.pdtype)),
    ]
    return layers._merge(*items)


def _split_proj(p, x, cfg: ModelConfig):
    d_in, H, P, N = ssm_dims(cfg)
    cd = cfg.cdtype
    zx = jnp.einsum("bsd,dk->bsk", x, p["w_zx"].astype(cd))
    z, xs = jnp.split(zx, [d_in], axis=-1)     # shard-aligned split
    bcdt = jnp.einsum("bsd,dk->bsk", x, p["w_bcdt"].astype(cd))
    bc, dt = jnp.split(bcdt, [2 * N], axis=-1)  # replicated, free
    return z, xs, bc, dt


def _causal_conv(xbc, w, b, cfg: ModelConfig, conv_state=None):
    """Depthwise causal conv (applied per shard-aligned channel group).

    conv_state: (B, k-1, ch) trailing context for decode.  Returns
    (out, new_conv_state)."""
    k = cfg.ssm_conv
    w = w.astype(xbc.dtype)                 # (k, ch)
    if conv_state is not None:
        buf = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    else:
        buf = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(buf[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    out = jax.nn.silu(out + b.astype(xbc.dtype))
    new_state = buf[:, -(k - 1):, :]
    return out, new_state


def ssd_chunked(x, b, c, la, dt, cfg: ModelConfig, init_state=None):
    """SSD core.  x:(B,S,H,P) b,c:(B,S,N) la:(B,S,H) log-decay dt:(B,S,H).

    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = b.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    nc = S // Q
    assert S % Q == 0, (S, Q)
    xq = x.reshape(Bsz, nc, Q, H, P)
    bq = b.reshape(Bsz, nc, Q, N)
    cq = c.reshape(Bsz, nc, Q, N)
    laq = la.reshape(Bsz, nc, Q, H)
    dtq = dt.reshape(Bsz, nc, Q, H)

    cum = jnp.cumsum(laq, axis=2)                        # (B,nc,Q,H)
    # within-chunk (attention-like) term.  Valid (lower-triangle) entries
    # always have li <= 0; clamping inside exp() keeps the masked upper
    # triangle finite so the where() cotangent never sees 0 * inf = NaN.
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,K,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None],
                  jnp.exp(jnp.minimum(li, 0.0)), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", cq, bq)
    w = scores[..., None] * L * dtq[:, :, None, :, :]    # (B,nc,Q,K,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", w.astype(x.dtype), xq)

    # chunk state contributions: decay from position k to chunk end
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,Q,H)
    zc = jnp.einsum("bckn,bckh,bckhp->bchpn",
                    bq, (dec_end * dtq).astype(x.dtype), xq)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def scan_fn(s, inp):
        z_c, dk = inp
        s_new = s * dk[:, :, None, None] + z_c
        return s_new, s
    s0 = init_state if init_state is not None else \
        jnp.zeros((Bsz, H, P, N), x.dtype)
    final, s_prev = jax.lax.scan(
        scan_fn, s0,
        (zc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                         cq, s_prev, jnp.exp(cum).astype(x.dtype))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, final


def ssm_apply(p, x, cfg: ModelConfig, *, state=None, conv_state=None,
              return_state: bool = False):
    """Full Mamba2 layer.  x: (B,S,d).  With ``state``/``conv_state`` given
    (decode), S must be 1 and the recurrence is applied directly."""
    d_in, H, P, N = ssm_dims(cfg)
    z, xs_raw, bc_raw, dt_raw = _split_proj(p, x, cfg)
    cs_x = cs_bc = None
    if conv_state is not None:
        cs_x, cs_bc = (conv_state[..., :d_in], conv_state[..., d_in:])
    xs, new_conv_x = _causal_conv(xs_raw, p["conv_w"], p["conv_b"], cfg,
                                  cs_x)
    bc, new_conv_bc = _causal_conv(bc_raw, p["conv_w_bc"], p["conv_b_bc"],
                                   cfg, cs_bc)
    new_conv = jnp.concatenate([new_conv_x, new_conv_bc], axis=-1)
    b, c = jnp.split(bc, [N], axis=-1)
    Bsz, S = x.shape[0], x.shape[1]
    xs = xs.reshape(Bsz, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                  # (B,S,H)
    a = -jnp.exp(p["a_log"])                              # (H,)
    la = dt * a                                           # log decay

    if state is not None:
        # O(1) decode: s' = s*exp(la) + dt * x  (outer) B
        dec = jnp.exp(la[:, 0])[:, :, None, None]         # (B,H,1,1)
        upd = jnp.einsum("bhp,bn->bhpn", (dt[:, 0, :, None]
                                          * xs[:, 0].astype(jnp.float32)),
                         b[:, 0].astype(jnp.float32))
        new_state = state * dec + upd
        y = jnp.einsum("bhpn,bn->bhp", new_state,
                       c[:, 0].astype(jnp.float32))[:, None]
    else:
        y, new_state = ssd_chunked(xs, b, c, la.astype(x.dtype),
                                   dt.astype(x.dtype), cfg)
        y = y.astype(jnp.float32)

    y = y + p["d_skip"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    # grouped RMS norm over d_inner
    y32 = y.astype(jnp.float32)
    y = (y32 * jax.lax.rsqrt((y32 ** 2).mean(-1, keepdims=True)
                             + cfg.norm_eps)).astype(x.dtype)
    y = y * p["norm"].astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"].astype(cfg.cdtype))
    if return_state:
        return out, (new_state, new_conv)
    return out


def ssm_state_shapes(cfg: ModelConfig, batch: int):
    d_in, H, P, N = ssm_dims(cfg)
    return ((batch, H, P, N), (batch, cfg.ssm_conv - 1, d_in + 2 * N))
