"""Core transformer building blocks (pure functions over param pytrees).

Parameters are nested dicts whose leaves are ``jnp`` arrays.  Every init
function also produces a parallel tree of *logical sharding axes* (tuples of
axis names) — ``sharding/rules.py`` maps those onto the device mesh.  Init
functions are pure and work under ``jax.eval_shape`` for allocation-free
abstract initialization (used by the multi-pod dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

Pytree = Any


@dataclasses.dataclass
class ParamBundle:
    """Parameters plus their logical-axis annotations (same tree shape)."""
    params: Pytree
    specs: Pytree


def _merge(*bundles_kv) -> ParamBundle:
    params = {k: b.params for k, b in bundles_kv}
    specs = {k: b.specs for k, b in bundles_kv}
    return ParamBundle(params, specs)


def _dense_init(key, shape, axes, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    w = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return ParamBundle(w, axes)


def _zeros_init(shape, axes, dtype):
    return ParamBundle(jnp.zeros(shape, dtype), axes)


def _ones_init(shape, axes, dtype):
    return ParamBundle(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig) -> ParamBundle:
    if cfg.norm == "ln":
        return ParamBundle(
            {"scale": jnp.ones(cfg.d_model, cfg.pdtype),
             "bias": jnp.zeros(cfg.d_model, cfg.pdtype)},
            {"scale": ("embed",), "bias": ("embed",)})
    return ParamBundle({"scale": jnp.ones(cfg.d_model, cfg.pdtype)},
                       {"scale": ("embed",)})


def apply_norm(p, x, cfg: ModelConfig):
    x32 = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = x32.mean(-1, keepdims=True)
        var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    var = (x32 ** 2).mean(-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + cfg.norm_eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, positions: jnp.ndarray) -> tuple:
    """positions: int32[...]; returns (cos, sin) with trailing dim d_head/2."""
    d = cfg.d_head
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (..., S, H, d_head); cos/sin: (..., S, d_head/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / prefix / cross)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, cross: bool = False) -> ParamBundle:
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    items = [
        ("wq", _dense_init(ks[0], (d, H, dh), ("embed", "heads", "head"),
                           cfg.pdtype)),
        ("wk", _dense_init(ks[1], (d, K, dh), ("embed", "kv_heads", "head"),
                           cfg.pdtype)),
        ("wv", _dense_init(ks[2], (d, K, dh), ("embed", "kv_heads", "head"),
                           cfg.pdtype)),
        ("wo", _dense_init(ks[3], (H, dh, d), ("heads", "head", "embed"),
                           cfg.pdtype, scale=1.0 / np.sqrt(H * dh))),
    ]
    if cfg.qkv_bias:
        items += [
            ("bq", _zeros_init((H, dh), ("heads", "head"), cfg.pdtype)),
            ("bk", _zeros_init((K, dh), ("kv_heads", "head"), cfg.pdtype)),
            ("bv", _zeros_init((K, dh), ("kv_heads", "head"), cfg.pdtype)),
        ]
    return _merge(*items)


def _qkv(p, x, cfg: ModelConfig, positions=None):
    cd = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.use_rope and positions is not None:
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, kh, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (b, s, kh, n_rep, dh)).reshape(b, s, kh * n_rep, dh)


def _constrain_heads(x):
    """Pin (B,S,H,dh) activations to head-sharding on the model axis.

    For head counts that don't divide the TP degree (56 heads / 16-way),
    parameter shardings must fall back (inputs need exact divisibility),
    and without a hint GSPMD chooses head-DIM sharding — which makes QK^T
    a partial contraction and all-reduces the S x S logits (§Perf A1:
    7.8e12 B/chip on deepseek prefill_32k).  Intermediates MAY be padded,
    so constraining heads onto ``model`` here keeps attention fully local
    per shard; only the row-parallel output psum remains.
    """
    import os
    from repro.sharding import context as shctx
    mesh = shctx.current_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or os.environ.get("REPRO_NO_HEAD_CONSTRAINT"):
        return x
    daxes = shctx.data_axes(mesh)
    spec = jax.sharding.PartitionSpec(
        daxes if x.shape[0] % np.prod([mesh.shape[a] for a in daxes]) == 0
        else None, None, "model", None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def _constrain_kv_seq(x):
    """Pin cached (B,S,*,dh) K/V to sequence-sharding on the model axis.

    Decode over a sequence-sharded cache (the kv_heads<TP fallback) must
    NOT gather the cache: with K/V kept S-sharded the QK^T contraction is
    local, softmax needs only (B,H,1) max/sum all-reduces, and the PV
    product psums a (B,1,H,dh) partial — flash-decoding semantics.  Without
    this hint GSPMD all-gathers the entire cache every token (§Perf D1:
    3.8e11 B/chip/step on deepseek decode_32k).
    """
    import os
    from repro.sharding import context as shctx
    mesh = shctx.current_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or os.environ.get("REPRO_NO_KV_SEQ_CONSTRAINT") \
            or x.shape[1] % mesh.shape["model"] != 0:
        return x
    daxes = shctx.data_axes(mesh)
    spec = jax.sharding.PartitionSpec(
        daxes if x.shape[0] % np.prod([mesh.shape[a] for a in daxes]) == 0
        else None, "model", None, None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def sdpa(q, k, v, mask=None, scale=None, kv_seq_sharded: bool = False):
    """q:(B,Sq,H,dh) k,v:(B,Sk,H,dh); mask broadcastable to (B,H,Sq,Sk)."""
    if kv_seq_sharded:
        k = _constrain_kv_seq(k)
        v = _constrain_kv_seq(v)
    else:
        q = _constrain_heads(q)
        k = _constrain_heads(k)
        v = _constrain_heads(v)
    scale = scale or (1.0 / np.sqrt(q.shape[-1]))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def causal_mask(sq: int, sk: int, window: int = 0, prefix_len=None):
    """bool[Sq, Sk] (True = attend).  ``sk - sq`` offsets queries to the
    cache tail; ``window`` > 0 restricts to a sliding window; ``prefix_len``
    makes the first ``prefix_len`` keys bidirectional (VLM prefix-LM)."""
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    if prefix_len is not None:
        m |= kpos < prefix_len
    return m


def attention_apply(p, x, cfg: ModelConfig, *, positions, mask,
                    kv_cache=None, cache_positions=None,
                    xattn_kv=None):
    """Full attention layer.  Modes:
      - training/prefill: kv_cache is None -> self-attention over x
      - decode: kv_cache=(k,v) of shape (B,S,K,dh) -> append x's kv
      - cross: xattn_kv=(k,v) precomputed from the encoder
    Returns (out, new_kv) where new_kv is (k, v) for cache maintenance.
    """
    cd = cfg.cdtype
    H, K = cfg.n_heads, cfg.n_kv_heads
    if xattn_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
        if cfg.qkv_bias:
            q = q + p["bq"].astype(cd)
        k, v = xattn_kv
        new_kv = None
    else:
        q, k, v = _qkv(p, x, cfg, positions)
        new_kv = (k, v)
        if kv_cache is not None:
            ck, cv = kv_cache
            if cache_positions is None:
                k = jnp.concatenate([ck, k], axis=1)
                v = jnp.concatenate([cv, v], axis=1)
            elif getattr(cache_positions, "ndim", 0):
                # ragged decode: one write position per sequence (paged /
                # continuous-batching lanes advance independently)
                upd = jax.vmap(lambda c, u, pos: jax.lax.
                               dynamic_update_slice_in_dim(c, u, pos, axis=0))
                k = upd(ck, k.astype(ck.dtype), cache_positions)
                v = upd(cv, v.astype(cv.dtype), cache_positions)
            else:
                k = jax.lax.dynamic_update_slice_in_dim(
                    ck, k.astype(ck.dtype), cache_positions, axis=1)
                v = jax.lax.dynamic_update_slice_in_dim(
                    cv, v.astype(cv.dtype), cache_positions, axis=1)
            new_kv = (k, v)
            k = k.astype(cfg.cdtype)   # fp8 cache reads upcast for compute
            v = v.astype(cfg.cdtype)
    from repro.sharding import context as shctx
    mesh = shctx.current_mesh()
    kv_seq_sharded = (
        kv_cache is not None and cache_positions is not None
        and mesh is not None and "model" in mesh.axis_names
        and K % mesh.shape["model"] != 0)
    k = _repeat_kv(k, H // K)
    v = _repeat_kv(v, H // K)
    out = sdpa(q, k, v, mask, kv_seq_sharded=kv_seq_sharded)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return out, new_kv


def paged_attention_apply(p, x, cfg: ModelConfig, *, lengths, k_pages,
                          v_pages, page_tables, layer, window=0,
                          interpret: bool = True):
    """Decode attention reading cached KV straight from the block pool via
    the Pallas ``paged_attention`` kernel (kernel over the cached pages +
    online-softmax merge of the in-flight token).

    x: (B, 1, d); k_pages/v_pages: the pool's layered (L, P, page, K, dh)
    buffers; ``layer`` selects the plane — one page table serves every
    layer.  ``window`` > 0 applies the kernel's sliding-window mask (a
    traced int32, so a scan over a ``global_every`` hybrid's layers flips
    it per layer).  Returns (out (B, 1, d), (k_new, v_new) each
    (B, 1, K, dh), post-RoPE, for pool write-back after the step).
    """
    from repro.kernels.paged_attention.paged_attention import decode_attend
    cd = cfg.cdtype
    positions = lengths[:, None]
    q, k, v = _qkv(p, x, cfg, positions)
    # round-trip through the cache dtype so the in-flight token sees the
    # same quantization the dense backend applies on cache write/read
    kc = k.astype(cfg.kvdtype).astype(cd)
    vc = v.astype(cfg.kvdtype).astype(cd)
    o = decode_attend(q[:, 0], kc[:, 0], vc[:, 0], k_pages, v_pages,
                      page_tables, lengths, layer=layer, window=window,
                      interpret=interpret)
    out = jnp.einsum("bshk,hkd->bsd", o[:, None].astype(cd),
                     p["wo"].astype(cd))
    return out, (k, v)


def cross_kv(p, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output."""
    cd = cfg.cdtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(cd))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return k, v


# ---------------------------------------------------------------------------
# MLP (gated or plain)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> ParamBundle:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    items = [("wi", _dense_init(ks[0], (d, f), ("embed", "mlp"), cfg.pdtype)),
             ("wo", _dense_init(ks[1], (f, d), ("mlp", "embed"), cfg.pdtype))]
    if cfg.mlp_gated:
        items.append(("wg", _dense_init(ks[2], (d, f), ("embed", "mlp"),
                                        cfg.pdtype)))
    return _merge(*items)


def _act(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp_apply(p, x, cfg: ModelConfig):
    cd = cfg.cdtype
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(cd))
    if cfg.mlp_gated:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(cd))
        h = _act(g, cfg.act) * h
    else:
        h = _act(h, cfg.act)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cd))


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> ParamBundle:
    ks = jax.random.split(key, 3)
    items = [("tok", _dense_init(ks[0], (cfg.vocab, cfg.d_model),
                                 ("vocab", "embed"), cfg.pdtype, scale=0.02))]
    if not cfg.tie_embeddings:
        items.append(("head", _dense_init(ks[1], (cfg.d_model, cfg.vocab),
                                          ("embed", "vocab"), cfg.pdtype)))
    if not cfg.use_rope and cfg.family in ("encdec",):
        items.append(("pos", _dense_init(
            ks[2], (cfg.max_position, cfg.d_model), ("seq", "embed"),
            cfg.pdtype, scale=0.02)))
    return _merge(*items)


def embed_tokens(p, tokens, cfg: ModelConfig, positions=None):
    from repro.kernels.mars_gather import ops as gather_ops
    x = gather_ops.embedding_gather(p["tok"], tokens).astype(cfg.cdtype)
    if "pos" in p and positions is not None:
        x = x + p["pos"].astype(cfg.cdtype)[positions]
    return x


def lm_head(p, x, cfg: ModelConfig):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(cfg.cdtype))
