"""Public MoE-dispatch op: MARS sort + group padding + grouped matmul.

``mars_moe_ffn(x, expert_idx, gates, w_in, w_gate, w_out)`` runs a full
expert FFN over top-k routed tokens:

  1. flatten (token, k) assignments, MARS-sort by expert id ("page")
  2. pad each expert's segment to the M-tile so row tiles are single-expert
  3. grouped matmuls (Pallas on TPU, ragged_dot elsewhere)
  4. inverse-permute + gate-weighted combine

Semantics identical to ref.py's dense oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_dispatch.moe_dispatch import grouped_matmul, DEFAULT_BM
from repro.models import layers


def pad_sorted_groups(sorted_e, perm, n_groups: int, bm: int):
    """Compute padded slot of each sorted assignment + tile->group map.

    Each group's segment starts at a bm-aligned offset; rows inside a
    padded区 not backed by a real assignment stay zero.
    Returns (slot (A,), tile_group (n_tiles,), M_pad)."""
    A = sorted_e.shape[0]
    counts = jnp.bincount(sorted_e, length=n_groups)
    padded = ((counts + bm - 1) // bm) * bm
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    seg_start = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                 jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    slot = starts[sorted_e] + (jnp.arange(A, dtype=jnp.int32)
                               - seg_start[sorted_e])
    M_pad = A + n_groups * bm          # static upper bound
    n_tiles = M_pad // bm
    # tile -> group: group whose padded segment covers the tile start
    bounds = jnp.cumsum(padded)        # (G,)
    tile_starts = jnp.arange(n_tiles, dtype=jnp.int32) * bm
    tile_group = jnp.searchsorted(bounds, tile_starts, side="right")
    tile_group = jnp.minimum(tile_group, n_groups - 1).astype(jnp.int32)
    return slot, tile_group, M_pad


@functools.partial(jax.jit, static_argnames=("n_experts", "act", "bm",
                                             "use_pallas", "interpret"))
def mars_moe_ffn(x, expert_idx, gates, w_in, w_gate, w_out, *,
                 n_experts: int, act: str = "silu", bm: int = DEFAULT_BM,
                 use_pallas: bool = False, interpret: bool = True):
    """x: (T, d); expert_idx: (T, k); gates: (T, k); w_*: (E, d, f)/(E, f, d).

    Returns (T, d).  With use_pallas the grouped matmuls run through the
    Pallas kernel (interpret=True validates on CPU); otherwise ragged_dot.
    """
    T, d = x.shape
    k = expert_idx.shape[1]
    A = T * k
    flat_e = expert_idx.reshape(-1).astype(jnp.int32)
    perm = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
    sorted_e = flat_e[perm]
    tok_of = perm // k
    gathered = x[tok_of]                                # (A, d) MARS order

    if use_pallas:
        slot, tile_group, M_pad = pad_sorted_groups(sorted_e, perm,
                                                    n_experts, bm)
        xbuf = jnp.zeros((M_pad, d), x.dtype).at[slot].set(gathered)
        h = grouped_matmul(xbuf, w_in, tile_group, bm=bm,
                           interpret=interpret)
        g = grouped_matmul(xbuf, w_gate, tile_group, bm=bm,
                           interpret=interpret)
        h = layers._act(g, act) * h
        out_pad = grouped_matmul(h, w_out, tile_group, bm=bm,
                                 interpret=interpret)
        out_sorted = out_pad[slot]
    else:
        group_sizes = jnp.bincount(sorted_e, length=n_experts)
        h = jax.lax.ragged_dot(gathered, w_in, group_sizes)
        g = jax.lax.ragged_dot(gathered, w_gate, group_sizes)
        h = layers._act(g, act) * h
        out_sorted = jax.lax.ragged_dot(h, w_out, group_sizes)

    inv = jnp.zeros(A, jnp.int32).at[perm].set(jnp.arange(A, dtype=jnp.int32))
    out_flat = out_sorted[inv]
    w = gates.reshape(-1, 1).astype(out_flat.dtype)
    return jnp.zeros_like(x).at[jnp.arange(A) // k].add(out_flat * w)
