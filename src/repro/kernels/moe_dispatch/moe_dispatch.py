"""MARS-sorted grouped matmul — Pallas TPU kernel.

The TPU rendering of the paper's RequestQ drain: token rows arrive already
grouped by destination expert ("page"), each group padded to the M-tile so
every grid row belongs to exactly one expert.  The kernel walks groups
back-to-back — each expert's weight matrix streams HBM->VMEM exactly once
per N-tile column (sequential reads, the CAS/ACT analogue), against full
128x128 MXU tiles.

Grid: (M_tiles, N_tiles, K_tiles) with a float32 VMEM accumulator.  The
expert for row-tile ``i`` comes from the scalar-prefetched ``tile_group``
array, which the weight BlockSpec index map reads — the PhyPageList lookup
in hardware terms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _kernel(tile_group_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    del tile_group_ref  # consumed by the index maps

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_matmul(x, w, tile_group, *, bm: int = DEFAULT_BM,
                   bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                   interpret: bool = False):
    """x: (M, K), rows sorted by group and group-padded so each row tile
    [i*bm, (i+1)*bm) belongs to one group; w: (G, K, N); tile_group: int32
    (M//bm,) expert id per row tile.  Returns (M, N) in x.dtype."""
    M, K = x.shape
    G, Kw, N = w.shape
    assert K == Kw, (K, Kw)
    assert M % bm == 0, (M, bm)
    bk = min(bk, K)
    bn = min(bn, N)
    assert K % bk == 0 and N % bn == 0, (K, bk, N, bn)
    n_m, n_n, n_k = M // bm, N // bn, K // bk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, tg: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, tg: (tg[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, tg: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )
    return kernel(tile_group, x, w)
