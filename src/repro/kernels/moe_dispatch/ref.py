"""Pure-jnp oracle for the MARS-sorted grouped matmul.

Contract: ``grouped_matmul(x, w, group_sizes)`` where
  x: (M, K)  rows sorted by group (MARS page order)
  w: (G, K, N) per-group weights
  group_sizes: int32 (G,), sum <= M (trailing rows belong to the last group
  with zero semantic weight — callers zero them)

out[i] = x[i] @ w[g(i)]  with g(i) the group containing row i.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def grouped_matmul_ref(x, w, group_sizes):
    M = x.shape[0]
    G = w.shape[0]
    ends = jnp.cumsum(group_sizes)
    gid = jnp.searchsorted(ends, jnp.arange(M), side="right")
    gid = jnp.minimum(gid, G - 1)
    wx = w[gid]                      # (M, K, N) — oracle only, O(M*K*N) mem
    return jnp.einsum("mk,mkn->mn", x, wx)


def grouped_matmul_ref_loop(x, w, group_sizes):
    """Second independent oracle (numpy loop) for small tests."""
    x = np.asarray(x, np.float32)
    w = np.asarray(w, np.float32)
    gs = np.asarray(group_sizes)
    out = np.zeros((x.shape[0], w.shape[2]), np.float32)
    r = 0
    for g, n in enumerate(gs):
        out[r:r + n] = x[r:r + n] @ w[g]
        r += n
    if r < x.shape[0]:
        out[r:] = x[r:] @ w[-1]
    return out
