"""Pure-jnp oracle for blockwise causal attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q,k,v: (B, S, H, D) -> (B, S, H, D); fp32 softmax."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    S, Sk = q.shape[1], k.shape[1]
    if causal:
        qpos = jnp.arange(S)[:, None] + (Sk - S)
        kpos = jnp.arange(Sk)[None, :]
        m = kpos <= qpos
        if window:
            m &= kpos > qpos - window
        logits = jnp.where(m, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)
