"""Blockwise (flash) causal attention — Pallas TPU kernel.

Online-softmax attention with KV streamed through VMEM in blocks; the
quadratic score matrix never materializes in HBM.  Used by the 32k-prefill
path where attention dominates the roofline.

Grid: (B*H, Q_tiles, KV_tiles), KV innermost with running (m, l, acc)
carried in VMEM scratch.  Causality skips fully-masked KV tiles via
``pl.when`` on the block index (the grid is dense; masked tiles cost a
branch only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, n_kv: int, bq: int, bk: int, causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk

    run = True
    if causal:
        run = k_start <= q_start + bq - 1   # any unmasked element?

    @pl.when(jnp.asarray(run) if not isinstance(run, bool) else run)
    def _body():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _store():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = False):
    """q,k,v: (B, S, H, D) -> (B, S, H, D)."""
    B, S, H, D = q.shape
    Sk = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, Sk)
    assert S % bq == 0 and Sk % bk == 0, (S, bq, Sk, bk)
    scale = 1.0 / np.sqrt(D)
    # fold heads into batch; kernel works on (BH, S, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    n_q, n_kv = S // bq, Sk // bk

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_kv=n_kv, bq=bq, bk=bk,
                          causal=causal),
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
