"""Paged-KV decode attention — Pallas TPU kernel (MARS page-visit order).

The serving analogue of the paper: a decode batch's KV reads are scattered
across cache pages ("DRAM rows"); visiting each sequence's pages
*in page-table order, page-contiguously* turns the gather into sequential
HBM block reads.  The page table is scalar-prefetched and drives the K/V
BlockSpec index maps — exactly the PhyPageList head/tail walk.

Grid: (B, pages_per_seq) with online-softmax state in VMEM scratch across
the page loop; one query token per sequence (decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, n_pages: int,
            n_rep: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ln = len_ref[b]
    base = j * page

    @pl.when(base < ln)
    def _body():
        q = q_ref[0]                                  # (H, D)
        k = k_ref[0]                                  # (page, Hkv, D)
        v = v_ref[0]
        Hkv = k.shape[1]
        H = q.shape[0]
        # GQA: fold query heads onto kv heads: (Hkv, n_rep, D)
        qg = q.reshape(Hkv, n_rep, -1)
        s = jnp.einsum("hrd,phd->hrp", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(pos < ln, s, NEG_INF)
        s = s.reshape(H, page)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        pv = jnp.einsum("hrp,phd->hrd",
                        p.reshape(Hkv, n_rep, page),
                        v.astype(jnp.float32))
        acc_ref[...] = acc_ref[...] * alpha + pv.reshape(H, -1)
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _store():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, page_tables, lengths, *,
                    interpret: bool = False):
    """q: (B, H, D); k/v_pages: (P, page, Hkv, D); page_tables: (B, n_pages);
    lengths: (B,).  Returns (B, H, D)."""
    B, H, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    n_pages = page_tables.shape[1]
    n_rep = H // Hkv
    scale = 1.0 / np.sqrt(D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, pt, ln: (b, 0, 0)),
            # MARS page walk: the page table drives the block index
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, pt, ln: (pt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, pt, ln: (pt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j, pt, ln: (b, 0, 0)),
        scratch_shapes=[pltpu.VMEM((H, 1), jnp.float32),
                        pltpu.VMEM((H, 1), jnp.float32),
                        pltpu.VMEM((H, D), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page=page, n_pages=n_pages,
                          n_rep=n_rep, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(page_tables, lengths, q, k_pages, v_pages)
