"""Paged-KV decode attention — Pallas TPU kernel (MARS page-visit order).

The serving analogue of the paper: a decode batch's KV reads are scattered
across cache pages ("DRAM rows"); visiting each sequence's pages
*in page-table order, page-contiguously* turns the gather into sequential
HBM block reads.  The page table is scalar-prefetched and drives the K/V
BlockSpec index maps — exactly the PhyPageList head/tail walk.

Grid: (B, pages_per_seq) with online-softmax state in VMEM scratch across
the page loop; one query token per sequence (decode).

The kernel understands the block pool's leading **layer axis**: pass
``k_pages``/``v_pages`` of shape (L, P, page, Hkv, D) plus ``layer`` and
the index map reads plane ``layer`` of the pool directly — one block-table
lookup serves every layer of a row group, and no per-layer plane is ever
materialized.  4-D pages (single-layer pools, the PR-1 engine) keep
working unchanged.

``window`` adds the sliding-window mask: with ``window > 0`` the query
(the in-flight token at position ``lengths[b]``) attends only cached
positions in ``(lengths[b] - window, lengths[b])`` — the same keys the
dense decode mask ``kpos > pos - window`` admits.  ``window`` is a traced
int32 scalar (scalar-prefetched alongside ``layer``), so a scan over a
``global_every`` hybrid's layers can flip it per layer (0 = global) with
one compiled kernel.  Pages that fall entirely outside the window are
skipped — never fetched, never touching the DRAM address stream.

``decode_attend`` is the full decode-step attention: kernel over the
cached pages + one online-softmax merge step folding in the in-flight
token's K/V (which is not in the pool yet — the backend writes it back
*after* the step, so the kernel never reads a partially-written page).
The in-flight token is its own causal context and always inside any
window, so the merge step needs no mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _window_lo(ln, w):
    """First valid cached position for a query at position ``ln`` under
    sliding window ``w`` (0 = global).  The canonical definition lives in
    the oracle (``ref._window_lo`` — kept independent so parity tests
    stay meaningful); ``ops._lane_lines`` mirrors it for the DRAM-trace
    bench."""
    return jnp.where(w > 0, ln - w + 1, 0)


def _kernel(pt_ref, len_ref, layer_ref, win_ref, q_ref, k_ref, v_ref,
            o_ref, m_out_ref, l_out_ref,
            m_ref, l_ref, acc_ref, *, page: int, n_pages: int,
            n_rep: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ln = len_ref[b]
    w = win_ref[0]
    base = j * page
    # sliding window: the query sits at position ln, so valid cached
    # positions are [lo, ln) (w = 0 means global, lo <= 0).  The page
    # gate must admit a page only if it holds at least one valid
    # position — a fully-masked page would feed
    # exp(NEG_INF - NEG_INF) = 1 into the softmax state.
    lo = _window_lo(ln, w)

    @pl.when((base < ln) & (base + page > lo) & (lo < ln))
    def _body():
        q = q_ref[0]                                  # (H, D)
        k = k_ref[0, 0]                               # (page, Hkv, D)
        v = v_ref[0, 0]
        Hkv = k.shape[1]
        H = q.shape[0]
        # GQA: fold query heads onto kv heads: (Hkv, n_rep, D)
        qg = q.reshape(Hkv, n_rep, -1)
        s = jnp.einsum("hrd,phd->hrp", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where((pos < ln) & (pos >= lo), s, NEG_INF)
        s = s.reshape(H, page)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        pv = jnp.einsum("hrp,phd->hrd",
                        p.reshape(Hkv, n_rep, page),
                        v.astype(jnp.float32))
        acc_ref[...] = acc_ref[...] * alpha + pv.reshape(H, -1)
        m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _store():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)
        m_out_ref[0] = m_ref[...]
        l_out_ref[0] = l_ref[...]


def paged_attention(q, k_pages, v_pages, page_tables, lengths, *,
                    layer=None, window=0, interpret: bool = False,
                    return_state: bool = False):
    """q: (B, H, D); k/v_pages: (P, page, Hkv, D) or, for a layered block
    pool, (L, P, page, Hkv, D) with ``layer`` selecting the plane;
    page_tables: (B, n_pages); lengths: (B,).  ``window`` > 0 restricts
    each query to the last ``window`` positions (query at ``lengths[b]``
    included); 0 attends all cached positions.

    Returns (B, H, D), or with ``return_state`` the online-softmax state
    ``(o, m, l)`` (m/l: (B, H, 1) float32) so a caller can merge more
    keys — e.g. the decode step's in-flight token — without renormalizing.
    A lane whose window admits no cached position (length 0, or
    ``window == 1``) comes back as (o=0, m=-inf, l=0) for the merge.
    """
    # concrete-value validation must live outside the jit boundary —
    # inside, every operand is a tracer and isinstance checks are dead
    if k_pages.ndim == 4 and isinstance(layer, (int, np.integer)) \
            and layer != 0:
        raise ValueError(
            f"4-D pages have only plane 0, got layer={layer} — a "
            f"calling-convention mix-up (layered pools are 5-D)")
    return _paged_attention(q, k_pages, v_pages, page_tables, lengths,
                            layer=layer, window=window,
                            interpret=interpret, return_state=return_state)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "return_state"))
def _paged_attention(q, k_pages, v_pages, page_tables, lengths, *,
                     layer=None, window=0, interpret: bool = False,
                     return_state: bool = False):
    B, H, D = q.shape
    if k_pages.ndim == 4:            # single-layer pool: lift to one plane
        k_pages = k_pages[None]
        v_pages = v_pages[None]
        layer = 0
    assert layer is not None, "layered k_pages needs a layer index"
    L, P, page, Hkv, _ = k_pages.shape
    n_pages = page_tables.shape[1]
    n_rep = H // Hkv
    scale = 1.0 / np.sqrt(D)
    layer_arr = jnp.atleast_1d(jnp.asarray(layer, jnp.int32))
    win_arr = jnp.atleast_1d(jnp.asarray(window, jnp.int32))

    def kv_index(b, j, pt, ln, la, w):
        # MARS page walk: the page table drives the block index; the
        # layer plane comes straight from the layered pool buffer.  The
        # fetch gate lives HERE, not in the kernel body — a pl.when only
        # skips compute, the pipeline still DMAs whatever the index map
        # names.  Clamping j to the lane's valid page range [j0, jmax]
        # makes every out-of-range grid step re-name the same in-range
        # block, and Pallas elides the copy when consecutive steps map to
        # the same block — out-of-window (and beyond-length) pages never
        # reach the DRAM address stream.
        lnb = ln[b]
        lo = _window_lo(lnb, w[0])
        j0 = jnp.maximum(lo, 0) // page
        jmax = jnp.maximum(lnb - 1, 0) // page
        jj = jnp.clip(j, j0, jnp.maximum(jmax, j0))
        return (la[0], pt[b, jj], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, pt, ln, la, w: (b, 0, 0)),
            pl.BlockSpec((1, 1, page, Hkv, D), kv_index),
            pl.BlockSpec((1, 1, page, Hkv, D), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, H, D), lambda b, j, pt, ln, la, w: (b, 0, 0)),
            pl.BlockSpec((1, H, 1), lambda b, j, pt, ln, la, w: (b, 0, 0)),
            pl.BlockSpec((1, H, 1), lambda b, j, pt, ln, la, w: (b, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((H, 1), jnp.float32),
                        pltpu.VMEM((H, 1), jnp.float32),
                        pltpu.VMEM((H, D), jnp.float32)],
    )
    o, m, l = pl.pallas_call(
        functools.partial(_kernel, page=page, n_pages=n_pages,
                          n_rep=n_rep, scale=scale),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, H, D), q.dtype),
                   jax.ShapeDtypeStruct((B, H, 1), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, 1), jnp.float32)],
        interpret=interpret,
    )(page_tables, lengths, layer_arr, win_arr, q, k_pages, v_pages)
    return (o, m, l) if return_state else o


def decode_attend(q, k_new, v_new, k_pages, v_pages, page_tables,
                  lengths, *, layer=0, window=0, interpret: bool = False):
    """Decode-step attention: the paged kernel over the cached pages plus
    one online-softmax merge step for the in-flight token (position
    ``lengths[b]``, always attended — it is its own causal context and
    always inside any sliding window).

    q: (B, H, D); k_new/v_new: (B, Hkv, D) — the in-flight token's K/V,
    not yet written to the pool.  ``window`` > 0 applies the sliding-
    window mask to the cached positions.  Returns (B, H, D).

    A lane with ``lengths[b] == 0`` degenerates cleanly: the kernel state
    is (m=-inf, l=0) and the merge reduces to attending the token alone.
    """
    B, H, D = q.shape
    Hkv = k_new.shape[1]
    n_rep = H // Hkv
    scale = 1.0 / np.sqrt(D)
    o, m, l = paged_attention(q, k_pages, v_pages, page_tables, lengths,
                              layer=layer, window=window,
                              interpret=interpret, return_state=True)
    # score of the in-flight token, same GQA head layout as the kernel
    qg = q.reshape(B, Hkv, n_rep, D)
    s_new = jnp.einsum("bhrd,bhd->bhr", qg.astype(jnp.float32),
                       k_new.astype(jnp.float32)) * scale
    s_new = s_new.reshape(B, H, 1)
    m2 = jnp.maximum(m, s_new)
    alpha = jnp.exp(m - m2)
    p = jnp.exp(s_new - m2)
    l2 = l * alpha + p
    v_rep = jnp.repeat(v_new, n_rep, axis=1).astype(jnp.float32)  # (B,H,D)
    o2 = (o.astype(jnp.float32) * (l * alpha) + p * v_rep) \
        / jnp.maximum(l2, 1e-30)
    return o2.astype(q.dtype)
