"""Pool -> kernel bridge: block tables as paged-attention operands, and the
kernel's memory-access stream as a DRAM-model trace.

Views of the same object:

  ``pool_page_tables``     pad per-sequence ``BlockTable``s into the dense
                           ``(B, n_pages)`` int32 operand the Pallas kernel
                           scalar-prefetches (optionally lane-padded for
                           recompile-free batching)
  ``decode_step_operands`` one ragged decode step's full operand pack —
                           pow2-padded page tables, lengths, and the
                           ``(Bp, 1)`` token batch — what
                           ``backend.PagedBackend.dispatch_decode`` hands
                           the jitted decode
  ``batch_lane_order``     order decode lanes so sequences whose tail blocks
                           share a DRAM row neighborhood sit adjacent — the
                           ``reorder.mars_order`` policy applied to the batch
  ``kv_read_trace``        the 64B-line address stream the paged *gather*
                           emits toward memory (per-lane streams interleaved
                           by the parallel gather), consumable by
                           ``core.dram.simulate``
  ``kv_read_trace_kernel`` the same step's reads as the Pallas kernel's
                           grid issues them: sequence-major, each lane's
                           pages visited in page-table order,
                           page-contiguously — the PhyPageList walk

All trace builders accept empty inputs (no tables, or tables with no
blocks — e.g. a zero-sequence batch from an idle engine step) and return
an empty int32 stream that ``dram.simulate`` serves as zero requests.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.reorder import mars_order
from repro.core.streams import _round_robin_merge
from repro.kvcache.placement import row_group_of
from repro.kvcache.pool import LINES_PER_BLOCK


def pool_page_tables(tables: Sequence, pad_to: int | None = None,
                     pad_lanes: int | None = None):
    """(page_tables int32 (B, n_pages), lengths int32 (B,)).  Padding block
    id 0 is safe: the kernel masks positions >= length.  ``pad_to`` pads
    the page axis, ``pad_lanes`` the batch axis (padded lanes have
    length 0, which the kernel skips entirely)."""
    n_pages = max((len(t.blocks) for t in tables), default=1)
    n_pages = max(n_pages, pad_to or 1)
    B = max(len(tables), pad_lanes or 0)
    pt = np.zeros((B, n_pages), np.int32)
    lengths = np.zeros(B, np.int32)
    for i, t in enumerate(tables):
        pt[i, :len(t.blocks)] = t.blocks
        lengths[i] = t.num_tokens
    return pt, lengths


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def decode_step_operands(tables: Sequence, tokens: Sequence[int],
                         block_size: int):
    """Operand pack for one ragged decode step over ``tables``.

    Returns ``(page_tables (Bp, n_pages) int32, lengths (Bp,) int32,
    tokens (Bp, 1) int32)`` with both the page axis and the lane axis
    padded to the next power of two — every lane has room for its new
    slot (``num_tokens + 1``), and recompiles of the jitted decode are
    bounded by the pow2 buckets rather than the ragged batch.  Padded
    lanes carry length 0 (the kernel skips them) and token 0.
    """
    B = len(tables)
    n_pages = _pow2(max(
        -(-(t.num_tokens + 1) // block_size) for t in tables))
    pt, lengths = pool_page_tables(tables, pad_to=n_pages,
                                   pad_lanes=_pow2(B))
    toks = np.zeros((pt.shape[0], 1), np.int32)
    toks[:B, 0] = list(tokens)
    return pt, lengths, toks


def batch_lane_order(tables: Sequence, blocks_per_group: int,
                     shard_ids: Sequence[int] | None = None) -> np.ndarray:
    """Permutation over batch lanes grouping tail blocks by row neighborhood
    (first-arrival page order, FIFO within a page — ``mars_order``).

    ``shard_ids`` (mesh-sharded pools): per-lane shard of the lane's pool
    — block ids are shard-local, so the grouping key gets the leading
    shard coordinate of ``placement.placement_key``; lanes on different
    shards never share a neighborhood even when their local ids collide.
    """
    if not tables:
        return np.zeros(0, np.int64)
    groups = np.asarray([
        row_group_of(t.blocks[-1], blocks_per_group) if t.blocks else -1
        for t in tables], np.int32)
    if shard_ids is not None:
        assert len(shard_ids) == len(tables)
        span = int(groups.max()) + 2        # local groups live in [-1, max]
        groups = np.asarray(shard_ids, np.int32) * span + groups
    return np.asarray(mars_order(groups))


def kv_read_trace(tables: Sequence, *, grant_beats: int = 4,
                  lines_per_block: int = LINES_PER_BLOCK) -> np.ndarray:
    """64B-line addresses of one decode step's full KV gather.

    Each lane reads its whole block list sequentially (one block = one 4KB
    page); lanes run in parallel, so the stream the memory system sees is
    the round-robin interleave of the per-lane streams — the same
    multi-stream merge that destroys locality at the paper's GPU boundary.
    """
    lanes = [_lane_lines(t, lines_per_block) for t in tables if t.blocks]
    if not lanes:
        return np.zeros(0, np.int32)
    addr, _ = _round_robin_merge(lanes, grant_beats)
    return addr


def kv_read_trace_kernel(tables: Sequence, *,
                         lines_per_block: int = LINES_PER_BLOCK,
                         window_tokens: int = 0,
                         block_size: int = 16) -> np.ndarray:
    """64B-line addresses of one decode step's KV reads as the Pallas
    ``paged_attention`` grid issues them: lanes served one after another
    (grid axis 0), each lane's pages in page-table order (grid axis 1),
    lines within a page contiguous.  No cross-lane interleave ever reaches
    the memory system — the kernel-path rendering of the MARS reorder.

    ``window_tokens`` > 0 models the kernel's sliding-window page gate: a
    query at position ``num_tokens`` attends cached positions
    ``(num_tokens - window, num_tokens)`` only, so pages entirely outside
    the window are never fetched (the gather path has no such gate — it
    gathers the full table and masks afterwards).
    """
    chunks = [_lane_lines(t, lines_per_block,
                          window_tokens=window_tokens,
                          block_size=block_size)
              for t in tables if t.blocks]
    chunks = [c for c in chunks if c.size]
    if not chunks:
        return np.zeros(0, np.int32)
    return np.concatenate(chunks)


def _lane_lines(table, lines_per_block: int, *, window_tokens: int = 0,
                block_size: int = 16) -> np.ndarray:
    blocks = table.blocks
    if window_tokens:
        # first valid cached position for the in-flight query (canonical
        # definition: paged_attention ref._window_lo).  A window of 1
        # admits no cached position (lo == num_tokens), but the kernel's
        # clamped index map still names one in-range page per lane — the
        # pipeline DMAs it even though the body never runs — so model a
        # single residual page, not an empty trace.
        lo = table.num_tokens - window_tokens + 1
        if lo >= table.num_tokens:
            blocks = blocks[-1:]
        else:
            blocks = blocks[max(lo, 0) // block_size:]
    base = np.asarray(blocks, np.int64)[:, None] * lines_per_block
    return (base + np.arange(lines_per_block)).reshape(-1).astype(np.int32)
