"""Pure-jnp oracle for paged-KV decode attention.

Cache layout: KV lives in fixed-size pages; each sequence owns a list of
page ids (its "page table").  One decode step attends one query token per
sequence over its first ``length`` cached positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def paged_attention_ref(q, k_pages, v_pages, page_tables, lengths):
    """q: (B, H, D); k_pages/v_pages: (P, page, Hkv, D);
    page_tables: int32 (B, pages_per_seq); lengths: int32 (B,).

    Returns (B, H, D).  GQA via H % Hkv == 0 head repetition."""
    B, H, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    n_rep = H // Hkv
    scale = 1.0 / np.sqrt(D)

    def one(qb, pt, ln):
        k = k_pages[pt].reshape(-1, Hkv, D)      # (S_max, Hkv, D)
        v = v_pages[pt].reshape(-1, Hkv, D)
        k = jnp.repeat(k, n_rep, axis=1)
        v = jnp.repeat(v, n_rep, axis=1)
        s = jnp.einsum("hd,khd->hk", qb, k).astype(jnp.float32) * scale
        mask = jnp.arange(k.shape[0]) < ln
        s = jnp.where(mask[None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("hk,khd->hd", w.astype(qb.dtype), v)

    return jax.vmap(one)(q, page_tables, lengths)
