"""Pure-jnp oracle for paged-KV decode attention.

Cache layout: KV lives in fixed-size pages; each sequence owns a list of
page ids (its "page table").  One decode step attends one query token per
sequence over its first ``length`` cached positions.

``paged_attention_ref`` mirrors the kernel (cached positions only);
``paged_decode_ref`` is the full decode-step oracle: cached positions
*plus* the in-flight token's K/V, computed with one plain softmax over the
concatenated keys — what ``paged_attention.decode_attend`` must match.
Both accept 4-D pages or a layered 5-D pool buffer with ``layer``, and a
``window`` > 0 sliding-window restriction (query at position ``length``,
so valid cached positions are ``(length - window, length)``; the
in-flight token is always inside the window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _layer_plane(k_pages, v_pages, layer):
    if k_pages.ndim == 5:
        return k_pages[layer], v_pages[layer]
    return k_pages, v_pages


def _window_lo(ln, window):
    """First valid cached position for a query at position ``ln``."""
    w = jnp.asarray(window, jnp.int32)
    return jnp.where(w > 0, ln - w + 1, 0)


def paged_attention_ref(q, k_pages, v_pages, page_tables, lengths,
                        layer=0, window=0):
    """q: (B, H, D); k_pages/v_pages: (P, page, Hkv, D) or layered
    (L, P, page, Hkv, D); page_tables: int32 (B, pages_per_seq);
    lengths: int32 (B,); ``window`` 0 = global.

    Returns (B, H, D).  GQA via H % Hkv == 0 head repetition."""
    B, H, D = q.shape
    k_pages, v_pages = _layer_plane(k_pages, v_pages, layer)
    P, page, Hkv, _ = k_pages.shape
    n_rep = H // Hkv
    scale = 1.0 / np.sqrt(D)

    def one(qb, pt, ln):
        k = k_pages[pt].reshape(-1, Hkv, D)      # (S_max, Hkv, D)
        v = v_pages[pt].reshape(-1, Hkv, D)
        k = jnp.repeat(k, n_rep, axis=1)
        v = jnp.repeat(v, n_rep, axis=1)
        s = jnp.einsum("hd,khd->hk", qb, k).astype(jnp.float32) * scale
        pos = jnp.arange(k.shape[0])
        mask = (pos < ln) & (pos >= _window_lo(ln, window))
        s = jnp.where(mask[None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("hk,khd->hd", w.astype(qb.dtype), v)

    return jax.vmap(one)(q, page_tables, lengths)


def paged_decode_ref(q, k_new, v_new, k_pages, v_pages, page_tables,
                     lengths, layer=0, window=0):
    """Decode-step oracle: attend the cached pages AND the in-flight
    token (k_new/v_new: (B, Hkv, D)) with one flat softmax; ``window``
    > 0 restricts the cached positions to the sliding window (the
    in-flight token is always attended).

    Returns (B, H, D)."""
    B, H, D = q.shape
    k_pages, v_pages = _layer_plane(k_pages, v_pages, layer)
    P, page, Hkv, _ = k_pages.shape
    n_rep = H // Hkv
    scale = 1.0 / np.sqrt(D)

    def one(qb, kn, vn, pt, ln):
        k = jnp.concatenate(
            [k_pages[pt].reshape(-1, Hkv, D), kn[None]], axis=0)
        v = jnp.concatenate(
            [v_pages[pt].reshape(-1, Hkv, D), vn[None]], axis=0)
        k = jnp.repeat(k, n_rep, axis=1)
        v = jnp.repeat(v, n_rep, axis=1)
        s = jnp.einsum("hd,khd->hk",
                       qb.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        S = k.shape[0]
        pos = jnp.arange(S)
        # cached positions inside [window lo, ln) are valid; the final
        # slot is the in-flight token itself (its own causal context,
        # always inside the window) — always attended
        mask = ((pos < ln) & (pos >= _window_lo(ln, window))) \
            | (pos == S - 1)
        s = jnp.where(mask[None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("hk,khd->hd", w,
                          v.astype(jnp.float32)).astype(qb.dtype)

    return jax.vmap(one)(q, k_new, v_new, page_tables, lengths)
