"""MARS-sorted embedding gather — Pallas TPU kernel.

The scalar-prefetched sorted-id stream drives the row BlockSpec index map:
grid step ``i`` copies table block ``ids[i]`` to output block ``i``.  With
MARS-sorted ids, consecutive grid steps read consecutive (or identical)
table pages — sequential HBM streaming, the CAS/ACT analogue; Pallas's
pipelined DMA then overlaps block ``i+1``'s fetch with block ``i``'s copy.

Rows are blocked in groups of ``rows_per_block`` ids; ids inside a block
gather one row each via dynamic slicing from a VMEM-resident table tile
when the block's ids share a page, falling back to per-row copies.
This kernel keeps the simple one-row-per-step form (robust for any id
distribution); the sort is what buys locality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, table_ref, o_ref):
    # the index map already selected table row block ids[i]; pure copy
    o_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(table: jnp.ndarray, sorted_ids: jnp.ndarray,
                *, interpret: bool = False) -> jnp.ndarray:
    """table: (V, D); sorted_ids: int32 (N,) MARS-sorted.  Returns (N, D).

    One grid step per id; the scalar-prefetch index map turns the gather
    into block reads at table[ids[i]].
    """
    N = sorted_ids.shape[0]
    V, D = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, ids: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        interpret=interpret,
    )(sorted_ids, table)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mars_gather_pallas(table: jnp.ndarray, ids: jnp.ndarray,
                       *, interpret: bool = False) -> jnp.ndarray:
    """Full MARS gather: sort ids by page, kernel-gather, unsort."""
    shape = ids.shape
    flat = ids.reshape(-1).astype(jnp.int32)
    perm = jnp.argsort(flat, stable=True).astype(jnp.int32)
    inv = jnp.zeros_like(perm).at[perm].set(
        jnp.arange(perm.shape[0], dtype=jnp.int32))
    rows = gather_rows(table, flat[perm], interpret=interpret)
    return rows[inv].reshape(*shape, table.shape[1])
