"""MARS-sorted embedding gather — jit'd public op.

TPU adaptation of the paper: a token-id stream arrives in sequence order
(interleaved "streams" of the batch); gathering rows in that order produces
scattered HBM reads over a (vocab x d) table that can span hundreds of MB.
MARS-sorting the ids groups reads by table *page* so consecutive reads hit
the same HBM page, then the inverse permutation restores order — identical
semantics (see ref.py), better achieved bandwidth.

On CPU/GPU backends the sort is usually not worth it; the Pallas kernel
(``mars_gather.py``) implements the sorted gather with explicit VMEM block
staging on TPU.  The op picks the strategy via ``mode``:
  - "auto": sorted path for large tables, plain take otherwise
  - "sorted" / "plain": forced
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.reorder import inverse_permutation
from repro.kernels.mars_gather.ref import embedding_gather_ref

# rows per 4KB-ish HBM "page" bucket used as the MARS grouping key; with
# bf16 d_model>=1024 a row exceeds a page, so grouping by row id directly
# (page == row) is the natural key; we keep a shift for small-row tables.
_PAGE_SHIFT = 2


@partial(jax.jit, static_argnames=("mode",))
def embedding_gather(table: jnp.ndarray, ids: jnp.ndarray,
                     mode: str = "auto") -> jnp.ndarray:
    shape = ids.shape
    flat = ids.reshape(-1)
    if mode == "plain" or (mode == "auto" and
                           table.shape[0] * table.shape[1] < (1 << 22)):
        out = embedding_gather_ref(table, flat)
        return out.reshape(*shape, table.shape[1])
    # MARS path: stable sort by page-of-row, gather grouped, unsort
    page = flat >> _PAGE_SHIFT
    perm = jnp.argsort(page, stable=True)
    sorted_ids = flat[perm]
    gathered = jnp.take(table, sorted_ids, axis=0)
    out = gathered[inverse_permutation(perm)]
    return out.reshape(*shape, table.shape[1])


def embedding_grad_scatter(ids: jnp.ndarray, grads: jnp.ndarray,
                           vocab: int) -> jnp.ndarray:
    """Backward of the gather: MARS-sorted segment-sum scatter-add.

    Sorting assignments by destination row turns the scatter into
    contiguous per-row accumulation (sequential HBM writes)."""
    flat = ids.reshape(-1)
    g = grads.reshape(-1, grads.shape[-1])
    perm = jnp.argsort(flat, stable=True)
    return jnp.zeros((vocab, g.shape[-1]), g.dtype).at[flat[perm]].add(g[perm])
