"""Pure-jnp oracle for the MARS-sorted embedding gather.

The contract: ``gather(table, ids) == table[ids]`` exactly — the MARS
reorder is a pure performance transform and must be bit-transparent.
"""
from __future__ import annotations

import jax.numpy as jnp


def embedding_gather_ref(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """table: (V, D); ids: int (...) -> (..., D)."""
    return jnp.take(table, ids, axis=0)
