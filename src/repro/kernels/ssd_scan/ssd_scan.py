"""SSD (Mamba2) chunked scan — Pallas TPU kernel.

One grid step processes one (batch, head-block, chunk): the within-chunk
quadratic term runs on the MXU from VMEM-resident tiles, and the
inter-chunk state (H_blk, P, N) is carried in VMEM scratch across the
chunk dimension (sequential grid axis) — HBM sees each token exactly once.

Grid: (B, H_blocks, n_chunks); chunk innermost so the scratch state
carries the recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, la_ref, dt_ref, y_ref, s_final_ref,
            state_ref, *, n_chunks: int, q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (q, Hb, P)
    b = b_ref[0].astype(jnp.float32)        # (q, N)
    c = c_ref[0].astype(jnp.float32)        # (q, N)
    la = la_ref[0].astype(jnp.float32)      # (q, Hb)
    dt = dt_ref[0].astype(jnp.float32)      # (q, Hb)

    cum = jnp.cumsum(la, axis=0)            # (q, Hb)
    # within-chunk quadratic term
    li = cum[:, None, :] - cum[None, :, :]  # (q, k, Hb)
    iq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = (ik <= iq)[:, :, None]
    L = jnp.where(tri, jnp.exp(jnp.minimum(li, 0.0)), 0.0)
    scores = jnp.einsum("qn,kn->qk", c, b)[:, :, None] * L \
        * dt[None, :, :]                    # (q, k, Hb)
    y_intra = jnp.einsum("qkh,khp->qhp", scores, x)

    # inter-chunk: contribution of carried state
    s_prev = state_ref[...]                 # (Hb, P, N)
    y_inter = jnp.einsum("qn,hpn,qh->qhp", c, s_prev, jnp.exp(cum))

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: s = decay_chunk * s + sum_k decay(end..k) dt_k B_k x_k
    dec_end = jnp.exp(cum[-1:, :] - cum)    # (q, Hb)
    z = jnp.einsum("kn,kh,khp->hpn", b, dec_end * dt, x)
    state_ref[...] = s_prev * jnp.exp(cum[-1])[:, None, None] + z

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        s_final_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "h_block",
                                             "interpret"))
def ssd_scan(x, b, c, la, dt, *, chunk: int = 64, h_block: int = 0,
             interpret: bool = False):
    """x: (B,S,H,P); b,c: (B,S,N); la,dt: (B,S,H).

    Returns (y (B,S,H,P) float32, final_state (B,H,P,N) float32)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    q = min(chunk, S)
    assert S % q == 0
    hb = h_block or H
    assert H % hb == 0
    n_chunks = S // q
    grid = (B, H // hb, n_chunks)

    y, s_final = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, hb, P), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, q, N), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, q, N), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, q, hb), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, q, hb), lambda ib, ih, ic: (ib, ic, ih)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, hb, P), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, hb, P, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, P, N), jnp.float32)],
        interpret=interpret,
    )(x, b, c, la, dt)
    return y, s_final
