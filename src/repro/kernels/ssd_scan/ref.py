"""Pure-jnp oracle for the SSD chunked scan: the sequential recurrence.

y_t = C_t . s_t,   s_t = exp(dt_t * A) * s_{t-1} + dt_t * (B_t (x) x_t)

This is the O(S) literal recurrence; the chunked kernel must match it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, b, c, la, dt):
    """x: (B,S,H,P); b,c: (B,S,N); la,dt: (B,S,H) -> (y (B,S,H,P), state)."""
    Bz, S, H, P = x.shape
    N = b.shape[-1]

    def step(s, t):
        xt, bt, ct, lat, dtt = t
        s = s * jnp.exp(lat)[:, :, None, None] \
            + jnp.einsum("bhp,bn->bhpn", dtt[..., None] * xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, y

    s0 = jnp.zeros((Bz, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32),
          c.transpose(1, 0, 2).astype(jnp.float32),
          la.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32))
    s, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s
