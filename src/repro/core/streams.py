"""Synthetic GPU request-stream generation + multi-level arbitration.

Models the paper's Section 2 setup: N shader cores clustered into shader
core groups (SCGs); each core emits sequential per-stream requests
(texture / stencil / color / HiZ / depth regions); requests are merged by
round-robin arbitration first within each SCG and then across SCGs before
they leave the GPU.  The merged order is what the memory controller sees in
the baseline (no MARS).

Addresses are 64B-cacheline ids (int32).  A 4KB physical page = 64 lines.
All generation is deterministic (pure numpy) so experiments are exactly
reproducible; the MARS engine and DRAM model consume the resulting arrays
with jax.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

LINE_BYTES = 64
PAGE_BYTES = 4096
LINES_PER_PAGE = PAGE_BYTES // LINE_BYTES  # 64
PAGE_SHIFT = 6  # line-id -> page-id


@dataclasses.dataclass(frozen=True)
class RequestStream:
    """A merged stream of memory requests at some observation point."""

    addr: np.ndarray      # int32[N]  64B-line ids
    is_write: np.ndarray  # bool[N]
    source: np.ndarray    # int32[N]  emitting core id

    def __post_init__(self):
        assert self.addr.shape == self.is_write.shape == self.source.shape

    @property
    def n(self) -> int:
        return int(self.addr.shape[0])

    @property
    def page(self) -> np.ndarray:
        return self.addr >> PAGE_SHIFT


@dataclasses.dataclass(frozen=True)
class GpuConfig:
    """Shader-core topology (paper Section 2 / Section 4).

    ``grant_beats``: consecutive beats an arbiter grants one source before
    rotating (real NOC arbiters grant per packet/burst, so short same-source
    runs survive the merge — this is why the baseline MC is not fully
    pathological).
    """

    n_cores: int = 64
    cores_per_group: int = 8
    grant_beats: int = 7
    # consecutive requests a core issues from one of its sub-streams before
    # switching (stream-specific L1s emit misses in per-page bursts as a
    # texture/stencil tile is walked, not one line at a time)
    substream_chunk: int = 8

    @property
    def n_groups(self) -> int:
        return self.n_cores // self.cores_per_group


# ---------------------------------------------------------------------------
# Per-core stream generation
# ---------------------------------------------------------------------------

def _core_stream(base_page: int, n_req: int, *, stride: int = 1,
                 rng: np.random.Generator | None = None,
                 jitter: float = 0.0) -> np.ndarray:
    """Sequential line addresses starting at ``base_page`` with optional
    small jitter (models partially out-of-order misses from a texture cache).
    """
    addr = base_page * LINES_PER_PAGE + np.arange(n_req, dtype=np.int64) * stride
    if jitter > 0.0 and rng is not None:
        noise = rng.integers(0, max(1, int(jitter * LINES_PER_PAGE)), size=n_req)
        addr = addr + noise
    return addr.astype(np.int32)


def _round_robin_merge(streams: Sequence[np.ndarray],
                       grant_beats: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """Round-robin arbitration across equal-rate sources, granting
    ``grant_beats`` consecutive beats per source per rotation.

    Returns merged array + source-id array.  Streams may have unequal
    lengths; exhausted streams drop out of the rotation (as a real arbiter
    would skip empty input queues).
    """
    lens = [len(s) for s in streams]
    total = sum(lens)
    out = np.empty(total, dtype=np.int32)
    src = np.empty(total, dtype=np.int32)
    cursors = [0] * len(streams)
    pos = 0
    while pos < total:
        for i, s in enumerate(streams):
            take = min(grant_beats, lens[i] - cursors[i])
            if take > 0:
                out[pos:pos + take] = s[cursors[i]:cursors[i] + take]
                src[pos:pos + take] = i
                cursors[i] += take
                pos += take
    return out, src


def merge_hierarchical(core_streams: Sequence[np.ndarray],
                       core_writes: Sequence[np.ndarray],
                       cfg: GpuConfig) -> RequestStream:
    """Two-level round-robin: within SCG, then across SCGs.

    This is the arbitration that destroys per-stream locality (paper Fig 2).
    """
    n = len(core_streams)
    g = cfg.cores_per_group
    gb = cfg.grant_beats
    group_addr, group_src, group_wr = [], [], []
    for g0 in range(0, n, g):
        a, s = _round_robin_merge(core_streams[g0:g0 + g], gb)
        w, _ = _round_robin_merge(core_writes[g0:g0 + g], gb)
        group_addr.append(a)
        group_src.append(s + g0)
        group_wr.append(w)
    merged_addr, gsel = _round_robin_merge(group_addr, gb)
    merged_wr, _ = _round_robin_merge(group_wr, gb)
    # recover source ids through the same rotation
    merged_src = np.empty_like(merged_addr)
    cursors = [0] * len(group_src)
    for i, gi in enumerate(gsel):
        merged_src[i] = group_src[gi][cursors[gi]]
        cursors[gi] += 1
    return RequestStream(merged_addr, merged_wr.astype(bool), merged_src)


# ---------------------------------------------------------------------------
# Paper workloads (Table 1)
# ---------------------------------------------------------------------------

_STREAM_REGION_PAGES = 1 << 14  # 64MB region per logical graphics stream


def _build(cfg: GpuConfig, reqs_per_core: int, specs, seed: int) -> RequestStream:
    """specs: list of (region_id, is_write, fraction, stride) sub-streams."""
    rng = np.random.default_rng(seed)
    core_streams, core_writes = [], []
    for c in range(cfg.n_cores):
        parts_a, parts_w = [], []
        for (region, wr, frac, stride) in specs:
            n_req = int(reqs_per_core * frac)
            # Each core walks its own slice of the stream's region — this is
            # the "inherent locality in a single data stream" at source.
            # Slice bases get a small randomized offset (real allocators
            # don't place per-core surface slices at perfectly regular
            # strides), which avoids systematic bank aliasing.
            span = reqs_per_core * stride // LINES_PER_PAGE + 2
            base_page = (region * _STREAM_REGION_PAGES + c * (span + 2)
                         + int(rng.integers(0, 2)))
            parts_a.append(_core_stream(base_page, n_req, stride=stride,
                                        rng=rng, jitter=0.05))
            parts_w.append(np.full(n_req, wr, dtype=np.int32))
        if len(parts_a) == 1:
            a, w = parts_a[0], parts_w[0]
        else:
            # a core interleaves its own sub-streams (e.g. stencil read +
            # color write) in tile-sized chunks
            a, _ = _round_robin_merge(parts_a, cfg.substream_chunk)
            w, _ = _round_robin_merge(parts_w, cfg.substream_chunk)
        core_streams.append(a)
        core_writes.append(w)
    return merge_hierarchical(core_streams, core_writes, cfg)


def make_workload(name: str, cfg: GpuConfig | None = None,
                  reqs_per_core: int = 512, seed: int = 0) -> RequestStream:
    """The five synthetic memory-intensive workloads of Table 1."""
    cfg = cfg or GpuConfig()
    wl = {
        # WL1: read only, single texture stream
        "WL1": [(0, 0, 1.0, 1)],
        # WL2: read + write, stencil and color streams
        "WL2": [(1, 0, 0.5, 1), (2, 1, 0.5, 1)],
        # WL3: write only, single stream
        "WL3": [(3, 1, 1.0, 1)],
        # WL4: read only, HiZ and depth streams
        "WL4": [(4, 0, 0.5, 1), (5, 0, 0.5, 1)],
        # WL5: read + write, single HiZ stream (read-modify-write same tile)
        "WL5": [(6, 0, 0.5, 1), (6, 1, 0.5, 1)],
    }
    if name not in wl:
        raise ValueError(f"unknown workload {name!r}; have {sorted(wl)}")
    return _build(cfg, reqs_per_core, wl[name], seed)


WORKLOADS = ("WL1", "WL2", "WL3", "WL4", "WL5")


# ---------------------------------------------------------------------------
# Locality metric (paper Fig 2)
# ---------------------------------------------------------------------------

def locality(addr: np.ndarray, window: int) -> float:
    """Average #requests per unique 4KB page within consecutive windows."""
    pages = (np.asarray(addr, dtype=np.int64) >> PAGE_SHIFT)
    n = (len(pages) // window) * window
    if n == 0:
        return float(len(pages)) / max(1, len(np.unique(pages)))
    w = pages[:n].reshape(-1, window)
    w = np.sort(w, axis=1)
    uniq = 1 + (np.diff(w, axis=1) != 0).sum(axis=1)
    return float((window / uniq).mean())


def locality_sweep(addr: np.ndarray,
                   windows=(128, 512, 2048, 8192, 16384)) -> dict[int, float]:
    return {w: locality(addr, w) for w in windows if w <= len(addr)}


def single_cache_stream(cfg: GpuConfig | None = None, reqs_per_core: int = 2048,
                        seed: int = 0) -> np.ndarray:
    """The texture stream at the output of ONE L1 texture cache (pre-merge)."""
    cfg = cfg or GpuConfig()
    rng = np.random.default_rng(seed)
    return _core_stream(0, reqs_per_core, rng=rng, jitter=0.05)
