"""Bulk MARS reorder — the policy of the cycle-level engine as a single
vectorized program transform.

The hardware engine (``core.mars``) is online: bounded RequestQ window,
group-by-page, pages drained oldest-first.  Inside a bulk-synchronous TPU
step the same policy becomes: within a bounded window of requests (tokens /
indices / KV-page reads), emit requests grouped by destination page, pages
ordered by first arrival, FIFO within a page.  That is exactly a stable
argsort by ``first_arrival[page_of(i)]`` — computable on-device in O(n log n)
with no data-dependent shapes, hence jit/pjit friendly.

This module is the bridge between the paper-faithful simulator and the
TPU-native kernels: ``kernels/moe_dispatch``, ``kernels/mars_gather``,
``serving/scheduler`` and ``data/pipeline`` all consume these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mars_order(page_ids: jnp.ndarray, *, num_pages: int | None = None,
               window: int | None = None) -> jnp.ndarray:
    """Return the MARS emission permutation for a stream of page ids.

    ``perm`` such that ``page_ids[perm]`` is grouped by page, pages in
    first-arrival (oldest-first) order, FIFO within a page — the
    PhyPageOrderQ policy with an unbounded RequestQ.  With ``window`` set,
    the stream is processed in independent windows of that size (the
    bounded-RequestQ semantics of the hardware engine, up to drain-boundary
    effects).
    """
    page_ids = jnp.asarray(page_ids)
    n = page_ids.shape[0]
    if n == 0:
        # empty stream (e.g. a zero-sequence decode batch from an idle
        # engine step): the identity permutation, not an associative_scan
        # over zero segments — mirrors the mars_reorder empty-input fix
        return jnp.zeros(0, jnp.int32)
    if window is not None and window < n:
        pad = (-n) % window
        padded = jnp.concatenate(
            [page_ids, jnp.full(pad, jnp.iinfo(jnp.int32).max, page_ids.dtype)])
        wperm = jax.vmap(lambda p: _mars_order_full(p, num_pages))(
            padded.reshape(-1, window))
        base = (jnp.arange(wperm.shape[0]) * window)[:, None]
        return (wperm + base).reshape(-1)[:n]
    return _mars_order_full(page_ids, num_pages)


def _mars_order_full(page_ids: jnp.ndarray, num_pages: int | None) -> jnp.ndarray:
    n = page_ids.shape[0]
    arrival = jnp.arange(n, dtype=jnp.int32)
    if num_pages is not None:
        # dense page-id space (e.g. experts): segment-min first arrival
        first = jnp.full(num_pages, n, jnp.int32).at[page_ids].min(arrival)
        key = first[page_ids]
    else:
        # sparse page-id space: first arrival via sort-scan-unsort
        order = jnp.argsort(page_ids, stable=True)
        sp = page_ids[order]
        sa = arrival[order]
        seg_start = jnp.concatenate(
            [jnp.ones(1, bool), sp[1:] != sp[:-1]])
        # broadcast each page-segment's first arrival across the segment
        first_sorted = _segment_broadcast_first(sa, seg_start)
        key = jnp.zeros(n, jnp.int32).at[order].set(first_sorted)
    return jnp.argsort(key, stable=True).astype(jnp.int32)


def _segment_broadcast_first(vals: jnp.ndarray, seg_start: jnp.ndarray) -> jnp.ndarray:
    """For sorted segments, broadcast each segment's first value across it."""
    def combine(a, b):
        (va, sa_), (vb, sb) = a, b
        return jnp.where(sb, vb, va), sa_ | sb
    out, _ = jax.lax.associative_scan(combine, (vals, seg_start))
    return out


def group_offsets(page_ids_sorted: jnp.ndarray, num_pages: int) -> jnp.ndarray:
    """Start offset of each page group in a MARS-sorted stream (dense ids).

    Returns int32[num_pages + 1]; group g spans [offsets[g], offsets[g+1]).
    Computed without data-dependent shapes (cumsum of bincount).
    """
    counts = jnp.bincount(page_ids_sorted, length=num_pages)
    return jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])


def inverse_permutation(perm: jnp.ndarray) -> jnp.ndarray:
    inv = jnp.zeros_like(perm)
    return inv.at[perm].set(jnp.arange(perm.shape[0], dtype=perm.dtype))


def mars_sort_by_page(page_ids: jnp.ndarray, num_pages: int):
    """One-stop helper for kernels: (perm, inv_perm, sorted_pages, offsets).

    Note: for *throughput* consumers (MoE dispatch) page order is
    irrelevant, so we sort by page id directly (cheaper key); the MARS
    first-arrival order matters for *latency* consumers (serving scheduler),
    which use ``mars_order``.
    """
    perm = jnp.argsort(page_ids, stable=True).astype(jnp.int32)
    sorted_pages = page_ids[perm]
    return perm, inverse_permutation(perm), sorted_pages, group_offsets(
        sorted_pages, num_pages)
