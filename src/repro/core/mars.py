"""MARS engine — cycle-level, hardware-faithful, as a pure ``jax.lax.scan``.

The three hardware structures of the paper map 1:1 onto fixed-size arrays:

  RequestQ       -> rq_* arrays of size Q (payload + intrusive linked list
                    ``rq_next`` + occupancy bit-vector ``rq_valid``)
  PhyPageList    -> (NSETS x WAYS) set-associative arrays keyed by physical
                    page number, each entry holding head/tail RequestQ slots
  PhyPageOrderQ  -> ring buffer of flat PhyPageList entry ids, FIFO in page
                    first-arrival order

One scan step == one GPU-boundary cycle.  The boundary has ``n_ports``
insertion ports (one per shader-core group — Figure 1 of the paper shows
multiple arbitration paths into the boundary buffer), each attempting one
insertion per cycle (paper Fig 5); a port whose head request hits a full
PhyPageList set or a full RequestQ stalls *itself* only, not its siblings.
One request per cycle is forwarded (paper Fig 6): always from the page that
holds the oldest buffered request (PhyPageOrderQ FIFO), draining that page
to exhaustion before moving on.

The scan emits the *original index* of each forwarded request (or -1 on an
idle cycle); compacting those gives the MARS-reordered permutation that the
DRAM model consumes.  Everything is jittable; no Python state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.streams import PAGE_SHIFT


@dataclasses.dataclass(frozen=True)
class MarsConfig:
    """Paper Section 4 configuration: 512-entry RequestQ, 128-entry 2-way
    set-associative PhyPageList."""

    request_q: int = 512
    page_entries: int = 128
    ways: int = 2
    # insertion ports at the GPU boundary (one per shader core group)
    n_ports: int = 8
    # max outstanding (buffered) requests per source core: shader cores have
    # a finite number of L1 MSHRs, which bounds how deep any single stream
    # can pile into the boundary queue
    mshr_per_core: int = 16

    @property
    def nsets(self) -> int:
        return self.page_entries // self.ways

    @property
    def order_q(self) -> int:
        # one PhyPageOrderQ slot per PhyPageList entry suffices (an entry is
        # pushed exactly once per allocation) -> never overflows.
        return self.page_entries


def _page_set_py(p: int, nsets: int) -> int:
    """XOR-fold all page bits down to the index width (python mirror)."""
    k = max(1, (nsets - 1).bit_length())
    s = p
    x = p >> k
    for _ in range(max(1, (31 + k - 1) // k)):
        s ^= x
        x >>= k
    return s % nsets


def _page_set(page: jnp.ndarray, nsets: int) -> jnp.ndarray:
    # XOR-fold ALL page bits down to the index width, as a real SRAM tag
    # array would (folding only adjacent bits aliases strided allocations)
    k = max(1, (nsets - 1).bit_length())
    s = page
    x = page >> k
    for _ in range(max(1, (31 + k - 1) // k)):
        s = s ^ x
        x = x >> k
    return s % nsets


class _State(NamedTuple):
    # RequestQ
    rq_page: jnp.ndarray    # int32[Q]
    rq_order: jnp.ndarray   # int32[Q] original stream index
    rq_next: jnp.ndarray    # int32[Q] intrusive list, -1 = tail
    rq_valid: jnp.ndarray   # bool[Q] occupancy bit-vector
    # PhyPageList (set-associative)
    ppl_page: jnp.ndarray   # int32[S, W]
    ppl_valid: jnp.ndarray  # bool[S, W]
    ppl_head: jnp.ndarray   # int32[S, W] RequestQ slot
    ppl_tail: jnp.ndarray   # int32[S, W]
    # PhyPageOrderQ ring buffer of flat (set*W + way) ids
    poq: jnp.ndarray        # int32[P]
    poq_head: jnp.ndarray   # int32
    poq_len: jnp.ndarray    # int32
    # per-port input cursors / stats
    cursors: jnp.ndarray    # int32[n_ports]
    stalls: jnp.ndarray     # int32 port-stall events
    inflight: jnp.ndarray   # int32[n_cores] outstanding per source core


def _init_state(cfg: MarsConfig, n_cores: int) -> _State:
    Q, S, W, P = cfg.request_q, cfg.nsets, cfg.ways, cfg.order_q
    i32 = jnp.int32
    return _State(
        rq_page=jnp.zeros(Q, i32), rq_order=jnp.zeros(Q, i32),
        rq_next=jnp.full(Q, -1, i32), rq_valid=jnp.zeros(Q, bool),
        ppl_page=jnp.zeros((S, W), i32), ppl_valid=jnp.zeros((S, W), bool),
        ppl_head=jnp.zeros((S, W), i32), ppl_tail=jnp.zeros((S, W), i32),
        poq=jnp.zeros(P, i32), poq_head=jnp.zeros((), i32),
        poq_len=jnp.zeros((), i32),
        cursors=jnp.zeros(cfg.n_ports, i32), stalls=jnp.zeros((), i32),
        inflight=jnp.zeros(max(n_cores, 1), i32),
    )


def _insert_port(state: _State, port: int, port_req: jnp.ndarray,
                 port_len: jnp.ndarray, pages: jnp.ndarray,
                 src: jnp.ndarray, cfg: MarsConfig) -> _State:
    """Paper Fig 5: one insertion attempt from one boundary port."""
    S, W = cfg.nsets, cfg.ways
    cur = state.cursors[port]
    plen = port_len[port]
    core = jnp.maximum(src[jnp.maximum(
        port_req[port, jnp.minimum(cur, jnp.maximum(plen - 1, 0))], 0)], 0)
    have_input = (cur < plen) & (state.inflight[core] < cfg.mshr_per_core)
    # global request index at this port's head
    g = port_req[port, jnp.minimum(cur, jnp.maximum(plen - 1, 0))]
    page = pages[jnp.maximum(g, 0)]
    s = _page_set(page, S)

    set_pages = state.ppl_page[s]          # [W]
    set_valid = state.ppl_valid[s]         # [W]
    hit_vec = set_valid & (set_pages == page)
    hit = jnp.any(hit_vec)
    hit_way = jnp.argmax(hit_vec)

    free_way_vec = ~set_valid
    have_free_way = jnp.any(free_way_vec)
    free_way = jnp.argmax(free_way_vec)

    rq_free_slot = jnp.argmin(state.rq_valid)          # first 0 bit
    rq_has_free = ~state.rq_valid[rq_free_slot]

    can_hit_insert = have_input & hit & rq_has_free
    can_miss_insert = have_input & ~hit & have_free_way & rq_has_free
    do_insert = can_hit_insert | can_miss_insert
    stall = have_input & ~do_insert

    slot = rq_free_slot
    way = jnp.where(hit, hit_way, free_way)

    # --- RequestQ write
    rq_page = state.rq_page.at[slot].set(
        jnp.where(do_insert, page, state.rq_page[slot]))
    rq_order = state.rq_order.at[slot].set(
        jnp.where(do_insert, g, state.rq_order[slot]))
    rq_next = state.rq_next.at[slot].set(
        jnp.where(do_insert, -1, state.rq_next[slot]))
    rq_valid = state.rq_valid.at[slot].set(state.rq_valid[slot] | do_insert)

    # --- link to previous tail on a page hit
    old_tail = state.ppl_tail[s, way]
    rq_next = rq_next.at[old_tail].set(
        jnp.where(can_hit_insert, slot, rq_next[old_tail]))

    # --- PhyPageList update (hit: move tail; miss: allocate entry)
    ppl_page = state.ppl_page.at[s, way].set(
        jnp.where(can_miss_insert, page, state.ppl_page[s, way]))
    ppl_valid = state.ppl_valid.at[s, way].set(
        state.ppl_valid[s, way] | can_miss_insert)
    ppl_head = state.ppl_head.at[s, way].set(
        jnp.where(can_miss_insert, slot, state.ppl_head[s, way]))
    ppl_tail = state.ppl_tail.at[s, way].set(
        jnp.where(do_insert, slot, state.ppl_tail[s, way]))

    # --- PhyPageOrderQ push on new page allocation
    flat = (s * W + way).astype(jnp.int32)
    tail_pos = (state.poq_head + state.poq_len) % cfg.order_q
    poq = state.poq.at[tail_pos].set(
        jnp.where(can_miss_insert, flat, state.poq[tail_pos]))
    poq_len = state.poq_len + can_miss_insert.astype(jnp.int32)

    return state._replace(
        rq_page=rq_page, rq_order=rq_order, rq_next=rq_next, rq_valid=rq_valid,
        ppl_page=ppl_page, ppl_valid=ppl_valid, ppl_head=ppl_head,
        ppl_tail=ppl_tail, poq=poq, poq_len=poq_len,
        cursors=state.cursors.at[port].add(do_insert.astype(jnp.int32)),
        stalls=state.stalls + stall.astype(jnp.int32),
        inflight=state.inflight.at[core].add(do_insert.astype(jnp.int32)),
    )


def _forward(state: _State, src: jnp.ndarray,
             cfg: MarsConfig) -> tuple[_State, jnp.ndarray]:
    """Paper Fig 6: forward the head request of the oldest page this cycle.

    Returns (new_state, emitted original index or -1).
    """
    W = cfg.ways
    have_page = state.poq_len > 0
    flat = state.poq[state.poq_head % cfg.order_q]
    s, way = flat // W, flat % W

    head = state.ppl_head[s, way]
    emit = jnp.where(have_page, state.rq_order[head], -1)

    nxt = state.rq_next[head]
    exhausted = have_page & (nxt < 0)

    rq_valid = state.rq_valid.at[head].set(
        jnp.where(have_page, False, state.rq_valid[head]))
    ppl_head = state.ppl_head.at[s, way].set(
        jnp.where(have_page & ~exhausted, nxt, state.ppl_head[s, way]))
    ppl_valid = state.ppl_valid.at[s, way].set(
        jnp.where(exhausted, False, state.ppl_valid[s, way]))
    poq_head = jnp.where(exhausted,
                         (state.poq_head + 1) % cfg.order_q, state.poq_head)
    poq_len = state.poq_len - exhausted.astype(jnp.int32)
    core = jnp.maximum(src[jnp.maximum(emit, 0)], 0)
    inflight = state.inflight.at[core].add(
        jnp.where(have_page, -1, 0).astype(jnp.int32))

    return state._replace(rq_valid=rq_valid, ppl_head=ppl_head,
                          ppl_valid=ppl_valid, poq_head=poq_head,
                          poq_len=poq_len, inflight=inflight), emit


@functools.partial(jax.jit, static_argnums=(4, 5, 6))
def _run(pages: jnp.ndarray, port_req: jnp.ndarray, port_len: jnp.ndarray,
         src: jnp.ndarray, n_req: int, n_cores: int, cfg: MarsConfig):
    def step(state, _):
        for p in range(cfg.n_ports):   # static unroll: one attempt per port
            state = _insert_port(state, p, port_req, port_len, pages, src, cfg)
        state, emit = _forward(state, src, cfg)
        return state, emit

    # forwarding needs n non-idle cycles; idle cycles are bounded by port
    # stalls which resolve as pages drain -> 3n + slack always completes.
    n_cycles = 3 * n_req + cfg.request_q + 64
    state, emits = jax.lax.scan(step, _init_state(cfg, n_cores), None,
                                length=n_cycles)
    return state, emits


def mars_reorder(addr: np.ndarray | jnp.ndarray,
                 ports: np.ndarray | None = None,
                 cfg: MarsConfig | None = None,
                 src: np.ndarray | None = None) -> tuple[np.ndarray, dict]:
    """Run the cycle-level MARS engine over a request stream.

    ``ports``: per-request boundary-port id (e.g. source shader-core group);
    defaults to distributing the stream round-robin over the ports, which
    preserves arrival order per port.

    Returns (perm, stats): ``perm`` is the permutation such that
    ``addr[perm]`` is the order requests leave MARS toward the memory
    controller; ``stats`` has stall/latency counters.
    """
    cfg = cfg or MarsConfig()
    addr = np.asarray(addr)
    n = int(addr.shape[0])
    if n == 0:
        return np.zeros(0, np.int64), {
            "stall_events": 0, "total_cycles": 0, "idle_frac": 0.0}
    pages = jnp.asarray(np.asarray(addr, np.int64) >> PAGE_SHIFT, jnp.int32)
    if ports is None:
        ports = np.arange(n) % cfg.n_ports
    ports = np.asarray(ports) % cfg.n_ports
    if src is None:
        src = ports.astype(np.int32)   # 1 "core" per port if not given
    src = np.asarray(src, np.int32)
    n_cores = int(src.max()) + 1 if n else 1
    # per-port request-index queues, padded to equal length
    port_lists = [np.flatnonzero(ports == p) for p in range(cfg.n_ports)]
    max_len = max((len(l) for l in port_lists), default=0)
    port_req = np.full((cfg.n_ports, max(max_len, 1)), -1, np.int32)
    for p, l in enumerate(port_lists):
        port_req[p, :len(l)] = l
    port_len = np.array([len(l) for l in port_lists], np.int32)

    state, emits = _run(pages, jnp.asarray(port_req), jnp.asarray(port_len),
                        jnp.asarray(src), n, n_cores, cfg)
    emits = np.asarray(emits)
    perm = emits[emits >= 0]
    if perm.shape[0] != n:  # engine must drain completely
        raise AssertionError(
            f"MARS drained {perm.shape[0]}/{n} requests — engine bug")
    if np.unique(perm).shape[0] != n:
        raise AssertionError("MARS emitted a non-permutation — engine bug")
    emit_cycles = np.flatnonzero(emits >= 0)
    stats = {
        "stall_events": int(state.stalls),
        "total_cycles": int(emit_cycles[-1] + 1) if n else 0,
        "idle_frac": 1.0 - n / float(emit_cycles[-1] + 1) if n else 0.0,
    }
    return perm, stats


def mars_reorder_reference(addr: np.ndarray, ports: np.ndarray | None = None,
                           cfg: MarsConfig | None = None,
                           src: np.ndarray | None = None) -> np.ndarray:
    """Slow pure-python oracle of the same engine (for tests)."""
    cfg = cfg or MarsConfig()
    pages = np.asarray(addr, np.int64) >> PAGE_SHIFT
    n = len(pages)
    if ports is None:
        ports = np.arange(n) % cfg.n_ports
    ports = np.asarray(ports) % cfg.n_ports
    if src is None:
        src = ports.astype(np.int32)
    src = np.asarray(src, np.int32)
    inflight: dict[int, int] = {}
    from collections import OrderedDict, deque
    queues = [deque(np.flatnonzero(ports == p)) for p in range(cfg.n_ports)]
    buffered: "OrderedDict[int, deque[int]]" = OrderedDict()  # page -> [gidx]
    setcnt: dict[int, set[int]] = {}
    total = 0
    out: list[int] = []
    while len(out) < n:
        for q in queues:                       # one attempt per port
            if not q:
                continue
            g = int(q[0])
            if inflight.get(int(src[g]), 0) >= cfg.mshr_per_core:
                continue
            p = int(pages[g])
            s = _page_set_py(p, cfg.nsets)
            if p in buffered:
                if total < cfg.request_q:
                    buffered[p].append(g)
                    total += 1
                    inflight[int(src[g])] = inflight.get(int(src[g]), 0) + 1
                    q.popleft()
            else:
                ways = setcnt.setdefault(s, set())
                if len(ways) < cfg.ways and total < cfg.request_q:
                    buffered[p] = deque([g])
                    ways.add(p)
                    total += 1
                    inflight[int(src[g])] = inflight.get(int(src[g]), 0) + 1
                    q.popleft()
        if buffered:                           # forward one request
            page0 = next(iter(buffered))       # oldest-allocated page
            lst = buffered[page0]
            gg = int(lst.popleft())
            out.append(gg)
            inflight[int(src[gg])] -= 1
            total -= 1
            if not lst:
                del buffered[page0]
                setcnt[_page_set_py(page0, cfg.nsets)].discard(page0)
    return np.asarray(out, np.int64)
