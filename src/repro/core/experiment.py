"""End-to-end paper experiment: workloads -> (baseline | MARS) -> DRAM.

Reproduces the paper's Figures 7 (achieved-bandwidth uplift) and 8
(CAS/ACT uplift) over workloads WL1-WL5, and Figure 2 (locality vs
observation window vs core count).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import dram, mars, streams


@dataclasses.dataclass(frozen=True)
class WorkloadResult:
    name: str
    baseline: dram.DramResult
    with_mars: dram.DramResult

    @property
    def bw_uplift(self) -> float:
        return self.with_mars.achieved_gbps / self.baseline.achieved_gbps - 1.0

    @property
    def cas_act_uplift(self) -> float:
        return self.with_mars.cas_per_act / self.baseline.cas_per_act - 1.0


def run_workload(name: str, *,
                 gpu: streams.GpuConfig | None = None,
                 mars_cfg: mars.MarsConfig | None = None,
                 dram_cfg: dram.DramConfig | None = None,
                 reqs_per_core: int = 512,
                 seed: int = 0) -> WorkloadResult:
    gpu = gpu or streams.GpuConfig()
    mars_cfg = mars_cfg or mars.MarsConfig()
    dram_cfg = dram_cfg or dram.DramConfig()
    wl = streams.make_workload(name, gpu, reqs_per_core=reqs_per_core, seed=seed)
    base = dram.simulate(wl.addr, dram_cfg, is_write=wl.is_write)
    # each shader-core group feeds its own boundary port
    ports = np.asarray(wl.source) // gpu.cores_per_group
    perm, _ = mars.mars_reorder(wl.addr, ports, mars_cfg,
                                src=np.asarray(wl.source))
    perm = np.asarray(perm)
    with_ = dram.simulate(np.asarray(wl.addr)[perm], dram_cfg,
                          is_write=np.asarray(wl.is_write)[perm])
    return WorkloadResult(name, base, with_)


def run_all(**kw) -> list[WorkloadResult]:
    return [run_workload(n, **kw) for n in streams.WORKLOADS]


def summarize(results: list[WorkloadResult]) -> dict:
    bw = np.array([r.bw_uplift for r in results])
    ca = np.array([r.cas_act_uplift for r in results])
    return {
        "mean_bw_uplift": float(bw.mean()),
        "mean_cas_act_uplift": float(ca.mean()),
        "per_wl_bw": {r.name: float(r.bw_uplift) for r in results},
        "per_wl_cas_act": {r.name: float(r.cas_act_uplift) for r in results},
    }


def locality_experiment(core_counts=(24, 40, 64),
                        windows=(128, 512, 2048, 8192, 16384),
                        reqs_per_core: int = 1024) -> dict:
    """Paper Figure 2: locality at a single cache vs at the GPU boundary,
    as core count grows."""
    out = {"single_cache": streams.locality_sweep(
        streams.single_cache_stream(reqs_per_core=16384), windows)}
    for n in core_counts:
        gpu = streams.GpuConfig(n_cores=n, cores_per_group=8)
        wl = streams.make_workload("WL1", gpu, reqs_per_core=reqs_per_core)
        out[f"gpu_boundary_{n}cores"] = streams.locality_sweep(wl.addr, windows)
    return out
