"""LPDDR4-3200 dual-channel DRAM timing model with an FR-FCFS controller.

Paper Section 2/4 memory system: dual-channel LPDDR4-3200, single rank,
8 banks, BL8, tCAS-tRCD-tRP = 15-15-15.  The controller has a *small*
pending-queue window per channel (the realistic baseline — row-hit-first
scheduling inside a limited lookahead).  MARS's whole premise is that this
window is too small to recover locality that multi-level arbitration
destroyed, while naively growing it is impractical.

Model (documented simplifications):
  * unit = DRAM command clock @ 1.6 GHz (LPDDR4-3200 => 2 transfers/clock)
  * one 64B line = BL8 burst = 4 data-bus clocks; per-channel peak
    bandwidth = 64 B / 4 clk = 25.6 GB/s, 51.2 GB/s total
  * row buffer 2 KB/bank/channel (32 lines); a 4 KB OS page maps to one
    (bank, row) pair in each channel -> requests of one page on one channel
    share a row, exactly the paper's memory-map-agnostic locality argument
  * row hit:   data start >= max(bus_free, bank_ready)
    row miss:  PRE (tRP, if a row was open) + ACT (ACT->CAS tRCD) off the
    critical path of other banks' transfers; tFAW (max 4 ACTs / 40 clk) and
    tRRD (8 clk) limit activate rate — these are what make a low CAS/ACT
    stream bandwidth-bound
  * read<->write direction switches pay a bus-turnaround penalty
    (tWTR / tRTW), so mixed-direction streams cap below pure-stream peak

Everything is a ``jax.lax.scan`` over served requests (one request per
step, FR-FCFS pick inside the window), fully jittable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DramConfig:
    n_channels: int = 2
    n_banks: int = 8
    lines_per_row: int = 32     # 2KB row buffer / 64B lines
    t_cas: int = 15
    t_rcd: int = 15
    t_rp: int = 15
    t_burst: int = 4            # BL8 @ 2 transfers/clock
    t_ccd: int = 4
    t_rrd: int = 8
    t_faw: int = 40
    t_wtr: int = 12             # write->read bus turnaround
    t_rtw: int = 8              # read->write bus turnaround
    window: int = 32            # MC pending-queue entries per channel
    clock_ghz: float = 1.6
    line_bytes: int = 64

    @property
    def peak_gbps(self) -> float:
        return self.n_channels * self.line_bytes / self.t_burst * self.clock_ghz


@dataclasses.dataclass(frozen=True)
class DramResult:
    cycles: int
    n_requests: int
    n_act: int
    achieved_gbps: float
    bus_utilization: float
    cas_per_act: float
    per_channel_cycles: tuple


def split_channels(addr: np.ndarray, cfg: DramConfig):
    """Address map: channel striped at 128B; within a channel the local
    line id is contiguous per page (see module docstring)."""
    a = np.asarray(addr, np.int64)
    if cfg.n_channels & (cfg.n_channels - 1):
        raise ValueError(
            f"n_channels must be a power of two, got {cfg.n_channels}: the "
            "128B channel stripe extracts the channel id as a bit field")
    ch_bits = int(np.log2(cfg.n_channels))
    ch = (a >> 1) & (cfg.n_channels - 1)
    local = ((a >> (1 + ch_bits)) << 1) | (a & 1)
    return ch, local


def _decode(local: jnp.ndarray, cfg: DramConfig):
    col = local % cfg.lines_per_row
    row = local // (cfg.lines_per_row * cfg.n_banks)
    # bank-address hashing (XOR-fold ALL row/page bits into the bank
    # select) — standard MC practice to break stride-induced bank
    # conflicts at any power-of-two stride
    k = max(1, (cfg.n_banks - 1).bit_length())
    page = local // cfg.lines_per_row
    b = page
    x = page >> k
    for _ in range(max(1, (31 + k - 1) // k)):
        b = b ^ x
        x = x >> k
    bank = b % cfg.n_banks
    return col, bank, row


def decode_lines(local: np.ndarray, cfg: DramConfig):
    """Public (col, bank, row) decode of channel-local line ids.

    Pure arithmetic — works element-wise on numpy or jax arrays alike.
    This is the exact map the FR-FCFS controller uses, exported so the
    live open-row model in ``obs/rowsim.py`` shares it instead of
    re-deriving the bank hash (one address map, one place).
    """
    return _decode(local, cfg)


class _ChState(NamedTuple):
    win_local: jnp.ndarray   # int32[W] local line ids
    win_arr: jnp.ndarray     # int32[W] arrival order
    win_wr: jnp.ndarray      # bool[W] write flag
    win_valid: jnp.ndarray   # bool[W]
    cursor: jnp.ndarray      # int32 next input idx
    open_row: jnp.ndarray    # int32[B], -1 closed
    bank_ready: jnp.ndarray  # int32[B] earliest data start on open row
    bus_free: jnp.ndarray    # int32
    act_hist: jnp.ndarray    # int32[4] ring of last ACT times (for tFAW)
    act_ptr: jnp.ndarray     # int32
    last_act: jnp.ndarray    # int32 (for tRRD)
    last_dir: jnp.ndarray    # int32 0=read 1=write
    n_act: jnp.ndarray       # int32
    t_end: jnp.ndarray       # int32 latest data end


_BIG = jnp.int32(1 << 29)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _run_channel(local: jnp.ndarray, is_write: jnp.ndarray, n: int,
                 cfg: DramConfig):
    W, B = cfg.window, cfg.n_banks
    pad = max(0, W - n)
    if pad:
        local = jnp.concatenate([local, jnp.zeros(pad, jnp.int32)])
        is_write = jnp.concatenate([is_write, jnp.zeros(pad, bool)])
    loc_pad = local

    init = _ChState(
        win_local=loc_pad[:W],
        win_arr=jnp.arange(W, dtype=jnp.int32),
        win_wr=is_write[:W],
        win_valid=jnp.arange(W) < n,
        cursor=jnp.int32(W),
        open_row=jnp.full(B, -1, jnp.int32),
        bank_ready=jnp.zeros(B, jnp.int32),
        bus_free=jnp.zeros((), jnp.int32),
        act_hist=jnp.full(4, -_BIG, jnp.int32),
        act_ptr=jnp.zeros((), jnp.int32),
        last_act=-_BIG * jnp.ones((), jnp.int32),
        last_dir=jnp.zeros((), jnp.int32),
        n_act=jnp.zeros((), jnp.int32),
        t_end=jnp.zeros((), jnp.int32),
    )

    def step(s: _ChState, _):
        col, bank, row = _decode(s.win_local, cfg)
        hit = s.win_valid & (s.open_row[bank] == row)
        # FR-FCFS: row hits first, oldest first; invalid slots never chosen
        key = jnp.where(s.win_valid, jnp.where(hit, 0, _BIG) + s.win_arr, 2 * _BIG)
        j = jnp.argmin(key)
        valid = s.win_valid[j]
        b, r = bank[j], row[j]
        is_hit = hit[j]

        was_open = s.open_row[b] >= 0
        # activate path (off other banks' data critical path)
        act_t = jnp.maximum(
            s.bank_ready[b] + jnp.where(was_open, cfg.t_rp, 0),
            jnp.maximum(s.act_hist[s.act_ptr] + cfg.t_faw,
                        s.last_act + cfg.t_rrd))
        row_ready = act_t + cfg.t_rcd
        # read<->write turnaround occupies the bus
        dirn = s.win_wr[j].astype(jnp.int32)
        turn = jnp.where(dirn == s.last_dir, 0,
                         jnp.where(dirn == 1, cfg.t_rtw, cfg.t_wtr))
        bus_avail = s.bus_free + turn
        start = jnp.where(is_hit,
                          jnp.maximum(bus_avail, s.bank_ready[b]),
                          jnp.maximum(bus_avail, row_ready))
        end = start + cfg.t_burst

        did_act = valid & ~is_hit
        new = s._replace(
            open_row=s.open_row.at[b].set(jnp.where(did_act, r, s.open_row[b])),
            bank_ready=s.bank_ready.at[b].set(
                jnp.where(valid, start + cfg.t_ccd, s.bank_ready[b])),
            bus_free=jnp.where(valid, end, s.bus_free),
            act_hist=s.act_hist.at[s.act_ptr].set(
                jnp.where(did_act, act_t, s.act_hist[s.act_ptr])),
            act_ptr=jnp.where(did_act, (s.act_ptr + 1) % 4, s.act_ptr),
            last_act=jnp.where(did_act, act_t, s.last_act),
            last_dir=jnp.where(valid, dirn, s.last_dir),
            n_act=s.n_act + did_act.astype(jnp.int32),
            t_end=jnp.maximum(s.t_end, jnp.where(valid, end, 0)),
        )
        # refill slot j from the input stream
        have_next = new.cursor < n
        nxt = local[jnp.minimum(new.cursor, n - 1)] if n else jnp.int32(0)
        nxt_wr = is_write[jnp.minimum(new.cursor, n - 1)] if n else jnp.bool_(False)
        new = new._replace(
            win_local=new.win_local.at[j].set(
                jnp.where(valid & have_next, nxt, new.win_local[j])),
            win_arr=new.win_arr.at[j].set(
                jnp.where(valid & have_next, new.cursor, new.win_arr[j])),
            win_wr=new.win_wr.at[j].set(
                jnp.where(valid & have_next, nxt_wr, new.win_wr[j])),
            win_valid=new.win_valid.at[j].set(valid & have_next),
            cursor=new.cursor + (valid & have_next).astype(jnp.int32),
        )
        return new, is_hit & valid

    final, hits = jax.lax.scan(step, init, None, length=n)
    return final.t_end, final.n_act, hits.sum()


def simulate(addr: np.ndarray, cfg: DramConfig | None = None,
             is_write: np.ndarray | None = None) -> DramResult:
    """Serve ``addr`` (64B-line ids, already in arrival order) and report
    achieved bandwidth + CAS/ACT."""
    cfg = cfg or DramConfig()
    ch, local = split_channels(addr, cfg)
    if is_write is None:
        is_write = np.zeros(len(addr), bool)
    is_write = np.asarray(is_write, bool)
    t_ends, n_acts = [], []
    n_total = len(addr)
    for c in range(cfg.n_channels):
        sel = ch == c
        l = jnp.asarray(local[sel], jnp.int32)
        n = int(l.shape[0])
        if n == 0:
            t_ends.append(0)
            n_acts.append(0)
            continue
        t_end, n_act, _ = _run_channel(l, jnp.asarray(is_write[sel]), n, cfg)
        t_ends.append(int(t_end))
        n_acts.append(int(n_act))
    cycles = max(t_ends) if t_ends else 0
    n_act = sum(n_acts)
    secs = cycles / (cfg.clock_ghz * 1e9) if cycles else 1.0
    gbps = n_total * cfg.line_bytes / secs / 1e9 if cycles else 0.0
    return DramResult(
        cycles=cycles, n_requests=n_total, n_act=max(n_act, 1),
        achieved_gbps=gbps,
        bus_utilization=gbps / cfg.peak_gbps if cycles else 0.0,
        cas_per_act=n_total / max(n_act, 1),
        per_channel_cycles=tuple(t_ends),
    )
