"""Serving steps: prefill (build cache) and decode (one token, batched).

``serve_step`` is what the decode_* / long_* dry-run shapes lower: one new
token against a KV/SSM cache of ``seq_len``.  It operates on the concrete
dense ``lm.Cache`` pytree so the dry-run can jit/shard it; everything
above this file speaks the ``KVBackend`` API (``kvcache.backend``) —
``greedy_generate`` works against any backend.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache: lm.Cache, tokens):
        """tokens: (B, 1) -> (next_token (B,1), logits, cache).  Pure over
        the dense Cache pytree (jit/shard/donate friendly)."""
        logits, cache = lm.dense_decode_step(params, cfg, tokens, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return serve_step


def make_prefill(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, tokens, frontend=None):
        return lm.dense_prefill(params, cfg, tokens, max_seq=max_seq,
                                frontend_emb=frontend)
    return prefill_step


def greedy_generate(params, cfg: ModelConfig, prompt, n_tokens: int,
                    max_seq: int = 0, frontend=None, backend=None):
    """Reference generation loop (used by examples + tests).

    Runs through the ``KVBackend`` API: dense by default (``max_seq``),
    or any backend passed in (e.g. a ``PagedBackend``) — the generated
    tokens must not depend on which backend holds the KV.
    """
    logits, backend = lm.prefill(params, cfg, prompt, max_seq=max_seq,
                                 frontend_emb=frontend, backend=backend)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]
    for _ in range(n_tokens - 1):
        logits, backend = lm.decode_step(params, cfg, tok, backend)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
