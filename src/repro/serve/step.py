"""Serving steps: prefill (build cache) and decode (one token, batched).

``serve_step`` is what the decode_* / long_* dry-run shapes lower: one new
token against a KV/SSM cache of ``seq_len``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache: lm.Cache, tokens):
        """tokens: (B, 1) -> (next_token (B,1), logits, cache)."""
        logits, cache = lm.decode_step(params, cfg, tokens, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return serve_step


def make_prefill(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, tokens, frontend=None):
        return lm.prefill(params, cfg, tokens, max_seq=max_seq,
                          frontend_emb=frontend)
    return prefill_step


def greedy_generate(params, cfg: ModelConfig, prompt, n_tokens: int,
                    max_seq: int, frontend=None):
    """Reference generation loop (used by examples + tests)."""
    logits, cache = lm.prefill(params, cfg, prompt, max_seq=max_seq,
                               frontend_emb=frontend)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [tok]
    step = jax.jit(make_decode_step(cfg))
    for _ in range(n_tokens - 1):
        tok, _, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
