"""Continuous-batching serve engine over the paged KV-cache pool.

The loop ties the whole MARS serving stack together, one step per call:

  admit    pop page-coherent batches from the ``MarsScheduler`` (which
           admits against pool capacity) into free decode lanes
  prefill  match the prompt against the prefix cache (ref-counted shared
           blocks), allocate the rest MARS-placed, write prompt KV
  decode   one token for every running lane; appends copy-on-write when a
           forked lane shares its tail block
  free     finished lanes release references; registered prefix blocks
           stay resident as evictable cache

Two model drivers:

  ``ToyModel``   deterministic single-layer attention LM (fixed random
                 embeddings + readout) decoded inline through
                 ``paged_attention`` — tests check the served tokens are
                 bit-identical whether KV lives densely or paged.
  ``PagedLM``    a real ``ModelConfig`` model (params + config) decoded
                 through ``kvcache.backend.PagedBackend``: every layer's
                 KV lives in the layered block pool, lanes decode ragged
                 (each at its own length) in one batched step, forks share
                 blocks copy-on-write.  Greedy sampling plus a per-fork
                 salt so parallel samples diverge.  The backend's
                 ``decode_mode`` picks the per-layer Pallas
                 ``paged_attention`` kernel path (default) or the gathered
                 dense-view oracle; ``use_kernel`` overrides it.

The LM decode round drives the backend's split-phase pipeline by default
(``flush -> dispatch_decode -> sync``; KV write-back commits one step
deferred) — ``ServeEngine(pipeline=False)`` restores the synchronous
``decode()`` wrapper.  Served tokens are identical either way.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import ops
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kvcache.pool import BlockPool
from repro.kvcache.prefix import BlockTable, PrefixCache
from repro.obs.metrics import StatGroup
from repro.serving.scheduler import MarsScheduler, Request


class ToyModel:
    """Single-layer attention LM with frozen random tables (deterministic)."""

    def __init__(self, vocab: int = 128, n_heads: int = 4,
                 n_kv_heads: int = 2, head_dim: int = 64, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab, self.n_heads = vocab, n_heads
        self.n_kv_heads, self.head_dim = n_kv_heads, head_dim
        s = 1.0 / np.sqrt(head_dim)
        self.emb_q = rng.normal(0, s, (vocab, n_heads, head_dim)).astype(np.float32)
        self.emb_k = rng.normal(0, s, (vocab, n_kv_heads, head_dim)).astype(np.float32)
        self.emb_v = rng.normal(0, s, (vocab, n_kv_heads, head_dim)).astype(np.float32)
        self.w_out = rng.normal(0, s, (n_heads * head_dim, vocab)).astype(np.float32)

    def kv_for(self, tokens):
        t = np.asarray(tokens, np.int64) % self.vocab
        return self.emb_k[t], self.emb_v[t]

    def q_for(self, tokens):
        return self.emb_q[np.asarray(tokens, np.int64) % self.vocab]

    def readout(self, o, salt):
        """attention out (B, H, D) + per-lane salt -> next tokens (B,)."""
        logits = np.asarray(o).reshape(len(o), -1) @ self.w_out
        return (np.argmax(logits, -1) + np.asarray(salt)) % self.vocab


class PagedLM:
    """Real-LM engine driver: (params, cfg) served through a PagedBackend."""

    def __init__(self, params, cfg, backend):
        from repro.kvcache.backend import PagedBackend, ShardedPagedBackend
        assert isinstance(backend, (PagedBackend, ShardedPagedBackend))
        self.params = params
        self.cfg = cfg
        self.backend = backend

    def next_token(self, logits, salt: int) -> int:
        """Greedy + per-fork salt (parallel samples diverge like ToyModel)."""
        return (int(np.argmax(np.asarray(logits, np.float32))) + salt) \
            % self.cfg.vocab


def make_paged_lm(params, cfg, pool: Optional[BlockPool] = None,
                  **backend_kw) -> PagedLM:
    from repro.kvcache.backend import PagedBackend
    return PagedLM(params, cfg, PagedBackend(cfg, pool=pool, **backend_kw))


@dataclasses.dataclass
class SeqState:
    rid: int
    tokens: list                 # prompt + generated
    table: BlockTable
    max_new: int
    salt: int = 0                # distinguishes forked samples
    n_generated: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    sid: int = -1                # PagedBackend sequence id (PagedLM driver)
    pending: Optional[int] = None  # first token, produced by prefill logits
    traffic_class: str = "default"  # scheduler stream (preemption policy)
    page: str = ""               # prefix-page key (re-routing on resume)

    @property
    def done(self) -> bool:
        return self.n_generated >= self.max_new


class EngineStats(StatGroup):
    """Engine counters as an ``obs.metrics.StatGroup`` facade (same
    attribute API as the old dataclass; adopted live by the metrics
    registry when an ``Observer`` is attached)."""
    FIELDS = {"steps": 0, "prefills": 0,
              "prefill_tokens": 0,        # prompt tokens run through prefill
              "decode_tokens": 0,         # generated tokens
              "shared_prompt_tokens": 0}


class ServeEngine:
    def __init__(self, pool: BlockPool, scheduler: MarsScheduler,
                 model: Optional[Union[ToyModel, PagedLM]] = None, *,
                 max_lanes: int = 8, use_kernel: Optional[bool] = None,
                 pipeline: bool = True):
        """``use_kernel``: ToyModel — decode inline through the Pallas
        kernel instead of the jnp oracle (default oracle).  PagedLM —
        override the backend's ``decode_mode`` ("kernel"/"gather");
        ``None`` leaves the backend as configured (kernel by default).

        ``pipeline``: PagedLM decode drives the split-phase backend
        lifecycle (``flush -> dispatch_decode -> sync``), leaving each
        step's KV write-back deferred until the next step's flush so the
        device->host copy overlaps host-side sampling/admission.
        ``False`` falls back to the synchronous ``decode()`` wrapper
        (every step fully committed before the engine sees its tokens);
        tokens are identical either way."""
        assert pool.k_pages is not None, "engine needs a pool with KV buffers"
        self.pool = pool
        # mesh-sharded pools: reservations are per-routed-request and lane
        # ordering carries the leading shard coordinate of the placement key
        self._sharded = bool(getattr(pool, "is_sharded", False))
        self.scheduler = scheduler
        if isinstance(model, PagedLM):
            assert model.backend.pool is pool, \
                "PagedLM backend must share the engine's pool"
            if use_kernel is not None:
                model.backend.decode_mode = \
                    "kernel" if use_kernel else "gather"
            self.model = model
            self.cache = getattr(model.backend, "prefix", None)
            self.use_kernel = model.backend.decode_mode == "kernel"
        else:
            assert not self._sharded, \
                "sharded pools serve through PagedLM + ShardedPagedBackend"
            self.model = model or ToyModel(n_kv_heads=pool.cfg.n_kv_heads,
                                           head_dim=pool.cfg.head_dim)
            self.cache = PrefixCache(pool.cfg.block_size)
            self.cache.attach(pool)
            self.use_kernel = bool(use_kernel)
        self.pipeline = pipeline
        self.max_lanes = max_lanes
        self.running: list[SeqState] = []
        # preempted decodes: (SeqState, pause record) pairs, oldest first.
        # Their KV pages left the pool (demoted to evictable cache / spill
        # tiers by ``pause_seq``); ``_try_resume`` restores them bitwise
        # when lanes and pool headroom return.
        self.paused: list = []
        self.finished: dict[int, list] = {}
        self.stats = EngineStats()
        self.obs = None          # telemetry hook (obs.Observer.attach)
        # admission-reservation bookkeeping per request: every actual block
        # allocation converts one reserved block into a live one; leftovers
        # release when the request's last lane finishes
        self._claims: dict[int, int] = {}
        self._live_seqs: dict[int, int] = {}
        self._sid_rid: dict[int, int] = {}

    @property
    def _lm(self) -> Optional[PagedLM]:
        return self.model if isinstance(self.model, PagedLM) else None

    def _unreserve(self, rid: int, n: int) -> None:
        """Release ``n`` of a request's admission reservation — routed to
        its shard for sharded pools (rid-keyed), aggregate otherwise."""
        if n == 0:
            return
        if self._sharded:
            self.pool.unreserve(n, rid=rid)
        else:
            self.pool.unreserve(n)

    def _claim(self, rid: int, n_allocs: int) -> None:
        take = min(self._claims.get(rid, 0), n_allocs)
        if take:
            self._unreserve(rid, take)
            self._claims[rid] -= take

    def _on_alloc(self, sid: int, n_allocs: int) -> None:
        self._claim(self._sid_rid[sid], n_allocs)

    def _finish_seq(self, seq: SeqState) -> None:
        if self.obs is not None:
            self.obs.trace.event("engine.free", rid=seq.rid, sid=seq.sid,
                                 tokens=seq.n_generated)
        self.finished.setdefault(seq.rid, []).append(seq.out_tokens)
        if self._lm is not None:
            self._lm.backend.free_seq(seq.sid)
            del self._sid_rid[seq.sid]
        else:
            self.cache.release(seq.table, self.pool)
        self._live_seqs[seq.rid] -= 1
        if self._live_seqs[seq.rid] == 0:
            del self._live_seqs[seq.rid]
            self._unreserve(seq.rid, self._claims.pop(seq.rid, 0))

    # -- admission / prefill -------------------------------------------------

    def submit(self, req: Request) -> bool:
        return self.scheduler.offer(req)

    def _prefill(self, req: Request) -> list[SeqState]:
        prompt = list(req.prompt)
        if self.obs is not None:
            shared0 = self.stats.shared_prompt_tokens
            with self.obs.trace.span("engine.prefill", rid=req.rid,
                                     tokens=len(prompt)) as sp:
                seqs = self._prefill_impl(req, prompt)
                sp["lanes"] = len(seqs)
                sp["shared"] = self.stats.shared_prompt_tokens - shared0
                return seqs
        return self._prefill_impl(req, prompt)

    def _prefill_impl(self, req: Request, prompt: list) -> list[SeqState]:
        self._claims[req.rid] = self._claims.get(req.rid, 0) \
            + req.blocks_needed(self.pool.cfg.block_size)
        self._live_seqs[req.rid] = self._live_seqs.get(req.rid, 0) \
            + req.n_samples
        if self._lm is not None:
            seqs = self._prefill_lm(req, prompt)
        else:
            seqs = self._prefill_toy(req, prompt)
        cname = getattr(req, "_cls", getattr(req, "traffic_class", "default"))
        for s in seqs:
            s.traffic_class = cname
            s.page = req.page
        self.stats.prefills += 1
        self.stats.prefill_tokens += len(prompt)
        return seqs

    def _prefill_toy(self, req: Request, prompt: list) -> list[SeqState]:
        bids, n = self.cache.match(prompt, self.pool)
        table = BlockTable(bids, n)
        rest = prompt[n:]
        allocs0 = self.pool.stats.allocs
        table.extend(self.pool, rest, seq_tokens=prompt, cache=self.cache,
                     kv=self.model.kv_for(rest))
        self._claim(req.rid, self.pool.stats.allocs - allocs0)
        self.stats.shared_prompt_tokens += n
        seqs = [SeqState(req.rid, prompt, table, req.max_new)]
        for i in range(1, req.n_samples):  # forks share all blocks (CoW later)
            seqs.append(SeqState(req.rid, list(prompt), table.fork(self.pool),
                                 req.max_new, salt=i))
        return seqs

    def _prefill_lm(self, req: Request, prompt: list) -> list[SeqState]:
        lm = self._lm
        allocs0 = self.pool.stats.allocs
        kw = {}
        if self._sharded:
            # honor the scheduler's routing decision (prefix-page affinity
            # + shard load); None falls back to the backend's own pick
            kw["shard"] = getattr(req, "_shard", None)
        sid, logits, shared = lm.backend.new_seq(lm.params, prompt, **kw)
        self._sid_rid[sid] = req.rid
        self._claim(req.rid, self.pool.stats.allocs - allocs0)
        self.stats.shared_prompt_tokens += shared
        seqs = []
        for i in range(req.n_samples):
            s = sid if i == 0 else lm.backend.fork_seq(sid)
            self._sid_rid[s] = req.rid
            seqs.append(SeqState(req.rid, list(prompt), lm.backend.table(s),
                                 req.max_new, salt=i, sid=s,
                                 pending=lm.next_token(logits, i)))
        return seqs

    # -- one engine step ------------------------------------------------------

    def step(self, now: float = 0.0) -> int:
        """Admit + prefill into free lanes, then decode one token on every
        running lane.  Returns number of tokens generated this step.
        A no-op (returns 0 untouched) when nothing runs and nothing is
        queued."""
        if not self.running and not self.paused \
                and not len(self.scheduler):
            return 0
        obs = self.obs
        t0 = time.perf_counter() if obs is not None else 0.0
        # overload first: a latency-class arrival bounced since the last
        # step -> pause a throughput decode so this round's admission and
        # shard routing see the freed headroom
        preempted = self._maybe_preempt()
        free = self.max_lanes - len(self.running)
        if free > 0:
            # a request occupies one decode lane per forked sample
            for req in self.scheduler.schedule_batch(
                    free, now=now, cost_fn=lambda r: r.n_samples):
                if obs is not None:
                    obs.trace.event("engine.admit", rid=req.rid,
                                    n_samples=req.n_samples)
                self.running.extend(self._prefill(req))
        if not preempted:
            self._try_resume()
        if not self.running:
            return 0
        # page-coherent lane order: tail blocks grouped by row neighborhood
        # (leading shard coordinate first when the pool is mesh-sharded —
        # block ids are shard-local, so cross-shard ids must not collide)
        shard_ids = None
        if self._sharded and self._lm is not None:
            shard_ids = [self._lm.backend.shard_of(s.sid)
                         for s in self.running]
        order = ops.batch_lane_order(
            [s.table for s in self.running],
            self.pool.cfg.blocks_per_group, shard_ids=shard_ids)
        self.running = [self.running[i] for i in order]

        nxt = self._decode_lm() if self._lm is not None \
            else self._decode_toy()

        still: list[SeqState] = []
        for seq, tok in zip(self.running, nxt):
            tok = self._commit_token(seq, int(tok))
            if seq.done:
                self._finish_seq(seq)
            else:
                if self._lm is None:
                    # append the token's KV for the next step (copy-on-write
                    # if the tail block is shared with a fork); the LM driver
                    # writes KV inside backend.decode instead
                    allocs0 = self.pool.stats.allocs
                    seq.table.extend(self.pool, [tok], seq_tokens=seq.tokens,
                                     cache=self.cache,
                                     kv=self.model.kv_for([tok]))
                    self._claim(seq.rid, self.pool.stats.allocs - allocs0)
                still.append(seq)
        self.running = still
        self.stats.steps += 1
        if obs is not None:
            obs.step_done(self, (time.perf_counter() - t0) * 1e3,
                          lanes=len(nxt), tokens=len(nxt))
        return len(nxt)

    # -- decode preemption (overload) ----------------------------------------

    def _maybe_preempt(self) -> bool:
        """Consume the scheduler's overload hint (a latency-class request
        bounced on pool capacity or deferred on shard headroom) by pausing
        the running throughput-class decode with the most work left.

        ``pause_seq`` drains the decode pipeline (flush barrier), captures
        the victim's KV pages host-side verbatim, and releases its blocks
        to evictable cache — demotable to spill tiers from there — so the
        next admission round actually sees the headroom; the victim's
        remaining admission reservation releases with it.  LM-driver,
        single-lane requests only: forked lanes share blocks CoW and
        would free almost nothing."""
        lm = self._lm
        if lm is None or not self.scheduler.take_preempt_hint():
            return False
        classes = getattr(self.scheduler, "classes", {})

        def latency(name: str) -> bool:
            c = classes.get(name)
            return c is not None and c.latency

        cand = [s for s in self.running
                if s.sid >= 0 and not latency(s.traffic_class)
                and self._live_seqs.get(s.rid, 0) == 1]
        if not cand:
            return False
        victim = max(cand, key=lambda s: s.max_new - s.n_generated)
        rec = lm.backend.pause_seq(victim.sid)
        if self.obs is not None:
            self.obs.trace.event("engine.pause", rid=victim.rid,
                                 sid=victim.sid,
                                 traffic_class=victim.traffic_class,
                                 tokens=victim.n_generated)
        self.running.remove(victim)
        del self._sid_rid[victim.sid]
        victim.sid = -1
        del self._live_seqs[victim.rid]
        self._unreserve(victim.rid, self._claims.pop(victim.rid, 0))
        self.paused.append((victim, rec))
        self.scheduler.note_preempt(victim.traffic_class)
        return True

    def _try_resume(self) -> None:
        """Opportunistic un-pause, oldest first: when a decode lane and
        pool headroom are both available again, re-reserve the paused
        sequence's remaining worst-case blocks (re-routed through the
        sharded pool's page-affinity logic when applicable) and restore
        it bitwise via ``resume_seq``.  Stops at the first sequence that
        doesn't fit — paused order is FIFO, like the scheduler's bounded
        delay."""
        lm = self._lm
        while self.paused and len(self.running) < self.max_lanes:
            seq, rec = self.paused[0]
            bs = self.pool.cfg.block_size
            # worst case for the rest of this sequence's life: KV for
            # every token so far plus everything still to generate
            need = -(-(len(seq.tokens) + seq.max_new - seq.n_generated)
                     // bs)
            if not self.pool.can_reserve(need):
                return
            self.pool.reserve(need)
            kw = {}
            if self._sharded:
                shard = self.pool.route(seq.rid, seq.page, need,
                                        tier_hint=rec.get("shard"))
                if shard is None:
                    self.pool.cancel_pending(need)
                    return
                kw["shard"] = shard
            self.paused.pop(0)
            self._claims[seq.rid] = self._claims.get(seq.rid, 0) + need
            self._live_seqs[seq.rid] = self._live_seqs.get(seq.rid, 0) + 1
            allocs0 = self.pool.stats.allocs
            sid = lm.backend.resume_seq(rec, **kw)
            self._sid_rid[sid] = seq.rid
            self._claim(seq.rid, self.pool.stats.allocs - allocs0)
            seq.sid = sid
            seq.table = lm.backend.table(sid)
            if self.obs is not None:
                self.obs.trace.event("engine.resume", rid=seq.rid, sid=sid,
                                     traffic_class=seq.traffic_class,
                                     tokens=seq.n_generated)
            self.running.append(seq)

    def _commit_token(self, seq: SeqState, tok: int) -> int:
        """The single decode-token commit path: every driver (toy and LM,
        gather and kernel decode modes, forked lanes included) accounts
        exactly one decode token per *sequence stepped* here — the
        per-step/per-sequence split the stats regression tests pin."""
        seq.tokens.append(tok)
        seq.out_tokens.append(tok)
        seq.n_generated += 1
        self.stats.decode_tokens += 1
        if self.obs is not None:
            self.obs.trace.event("engine.token", rid=seq.rid, sid=seq.sid,
                                 n=seq.n_generated)
        return tok

    def _decode_toy(self) -> list:
        if self.obs is not None:
            # live row-locality: the kernel-order page walk for this step
            # (the LM driver feeds the same walk inside backend.decode)
            self.obs.observe_kv_walk(0, ops.kv_read_trace_kernel(
                [s.table for s in self.running],
                block_size=self.pool.cfg.block_size))
        pt, lengths = ops.pool_page_tables([s.table for s in self.running])
        q = self.model.q_for([s.tokens[-1] for s in self.running])
        # stage the host-mutated pool buffers to device once per step
        # (layer plane 0 — the toy model is single-layer)
        kp = jnp.asarray(self.pool.k_pages[0])
        vp = jnp.asarray(self.pool.v_pages[0])
        if self.use_kernel:
            from repro.kernels.paged_attention.paged_attention import \
                paged_attention
            o = paged_attention(q, kp, vp, pt, lengths, interpret=True)
        else:
            o = paged_attention_ref(q, kp, vp, pt, lengths)
        return list(self.model.readout(o, [s.salt for s in self.running]))

    def _decode_lm(self) -> list:
        """One ragged decode round: lanes holding a prefill-produced first
        token emit it; the rest advance through the backend together."""
        lm = self._lm
        nxt: dict[int, int] = {}
        live = [s for s in self.running if s.pending is None]
        for s in self.running:
            if s.pending is not None:
                nxt[id(s)] = s.pending
                s.pending = None
        if live:
            if self.pipeline:
                logits = self._decode_lm_pipelined(live)
            else:
                logits = lm.backend.decode(
                    lm.params, [s.sid for s in live],
                    [s.tokens[-1] for s in live], on_alloc=self._on_alloc)
            for s, lg in zip(live, logits):
                nxt[id(s)] = lm.next_token(lg, s.salt)
        return [nxt[id(s)] for s in self.running]

    def _decode_lm_pipelined(self, live: list) -> list:
        """Split-phase decode round: ``flush`` commits the PREVIOUS step's
        deferred KV write-back (the one-step lag MARS's lookahead buffer
        affords), ``dispatch_decode`` launches this step on every shard
        without blocking, ``sync`` blocks on the logits only — the new
        KV rides a non-blocking device->host copy that lands before the
        next flush.  Phase wall-clock splits feed the
        ``engine.{commit,dispatch,sync}_ms`` histograms."""
        lm, obs = self._lm, self.obs
        backend = lm.backend
        t0 = time.perf_counter()
        backend.flush()
        t1 = time.perf_counter()
        step = backend.dispatch_decode(
            lm.params, [s.tokens[-1] for s in live],
            sids=[s.sid for s in live], on_alloc=self._on_alloc)
        t2 = time.perf_counter()
        logits = backend.sync(step)
        t3 = time.perf_counter()
        if obs is not None:
            obs.registry.observe("engine.commit_ms", (t1 - t0) * 1e3)
            obs.registry.observe("engine.dispatch_ms", (t2 - t1) * 1e3)
            obs.registry.observe("engine.sync_ms", (t3 - t2) * 1e3)
        return logits

    def run(self, requests, *, max_steps: int = 10_000) -> dict[int, list]:
        """Drive submit/step to completion (the offline serving loop)."""
        pending = list(requests)
        for step_i in range(max_steps):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            made = self.step(now=float(step_i))
            if not pending and not self.running and not self.paused \
                    and not len(self.scheduler):
                break
            if self.paused:
                continue   # a paused decode resumes once headroom returns
            if made == 0 and not self.running:
                # idle engine that still holds work: decide if it can ever
                # make progress again
                if len(self.scheduler):
                    # all lanes free yet nothing scheduled -> the head
                    # request's fork fan-out exceeds the lane budget
                    raise RuntimeError(
                        f"queued request needs more than max_lanes="
                        f"{self.max_lanes} decode lanes for its n_samples")
                if pending:
                    # pool is as empty as it will ever get and admission
                    # still failed -> the request can never fit
                    req = pending[0]
                    raise RuntimeError(
                        f"request {req.rid} needs "
                        f"{req.blocks_needed(self.pool.cfg.block_size)} "
                        f"blocks but the pool only ever frees "
                        f"{self.pool.num_free + self.pool.num_cached}")
        else:
            raise RuntimeError("engine did not drain within max_steps")
        return self.finished
