"""Logical-axis -> mesh-axis rules.

Model code annotates every parameter with logical axes (see
``models/layers.py``); these rules translate them into ``PartitionSpec``s
for a concrete mesh.  The production mesh axes are ("pod",) "data", "model":

  TP  : heads / kv_heads / mlp / vocab / ssm_in  -> "model"
  EP  : expert                                   -> "model"
  FSDP: embed (weight rows)                      -> "data"  (ZeRO-3 style)
  DP  : batch                                    -> ("pod", "data")

Explicit input shardings must divide dimensions exactly, so every mapping
is divisibility-checked with fallbacks: a head count that doesn't divide
the model axis (56 heads on 16-way TP) moves the sharding to the head_dim
("head") instead; dimensions with no valid mapping replicate.  All
fallbacks are honest — the roofline table shows their cost.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# when the primary mapping doesn't divide, move the mesh axis to the dim
# with this logical name instead (if present and divisible)
_FALLBACK_DIM = {
    "heads": "head",
    "kv_heads": "head",
    "vocab": "embed",
    "ssm_heads": None,
}


def logical_rules(mesh: jax.sharding.Mesh, fsdp: bool = True) -> dict:
    has_pod = "pod" in mesh.axis_names
    data_axes = ("pod", "data") if has_pod else ("data",)
    return {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",
        "ssm_in": "model",
        "ssm_small": None,
        "ssm_heads": "model",
        "embed": data_axes if fsdp else None,
        "head": None,
        "conv": None,
        "seq": None,
        "layers": None,
        "batch": data_axes,
    }


def _axis_size(mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def spec_for(axes, shape, rules, mesh) -> P:
    """Divisibility-checked PartitionSpec for one parameter."""
    n = len(axes)
    out = [None] * n
    used = set()

    def mark(m):
        used.update(m if isinstance(m, tuple) else (m,))

    # first pass: primary mappings that divide
    pending = []
    for i, a in enumerate(axes):
        m = rules.get(a)
        if m is None:
            continue
        ms = tuple(x for x in (m if isinstance(m, tuple) else (m,))
                   if x not in used)
        if not ms:
            continue
        m2 = ms if len(ms) > 1 else ms[0]
        if shape[i] % _axis_size(mesh, m2) == 0:
            out[i] = m2
            mark(m2)
        else:
            pending.append((i, a, m2))
    # second pass: fallback dims for failed mappings
    for i, a, m in pending:
        fb = _FALLBACK_DIM.get(a)
        if fb is None:
            continue
        if isinstance(m, tuple) or m in used:
            continue
        for j, b in enumerate(axes):
            if b == fb and out[j] is None \
                    and shape[j] % _axis_size(mesh, m) == 0:
                out[j] = m
                mark(m)
                break
    return P(*out)


def param_shardings(specs_tree, params_abs, mesh, fsdp: bool = True):
    """Map the logical-spec tree + abstract params to NamedShardings."""
    rules = logical_rules(mesh, fsdp)

    def one(axes, aval):
        return NamedSharding(mesh, spec_for(axes, aval.shape, rules, mesh))
    return jax.tree.map(one, specs_tree, params_abs,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_sharding(mesh, batch: int | None = None) -> NamedSharding:
    has_pod = "pod" in mesh.axis_names
    cand = [("pod", "data"), ("data",), ("pod",)] if has_pod else [("data",)]
    if batch is not None:
        for axes in cand:
            if batch % _axis_size(mesh, axes) == 0:
                return NamedSharding(mesh, P(axes))
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(cand[0]))


def sharded_bytes_per_device(tree, shardings, mesh) -> int:
    """Analytic per-device bytes of a (possibly abstract) array tree under
    the given shardings (ceil per sharded dim, matching GSPMD padding)."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(tree),
                        jax.tree.leaves(shardings,
                                        is_leaf=lambda x: x is None)):
        if leaf is None:
            continue
        n = 1
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec)) \
            if sh is not None else [None] * leaf.ndim
        for dim, ax in zip(leaf.shape, spec):
            k = _axis_size(mesh, ax) if ax is not None else 1
            n *= -(-dim // k)
        total += n * leaf.dtype.itemsize
    return total


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def pool_shard_count(mesh) -> int:
    """How many shards a mesh gives the KV block pool: the size of the
    model axis (one per-device pool per model shard —
    ``kvcache.sharded_pool.ShardedBlockPool``); 1 without a mesh or when
    the mesh has no model axis."""
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def cache_shardings(mesh, cfg, batch: int, backend: str = "dense") -> Any:
    """KV cache (L,B,S,K,dh): batch on data axes; kv heads on model when
    divisible, otherwise the *sequence* dim shards on model (flash-decoding
    style partial attention, resolved by GSPMD collectives).  SSM states
    shard heads on model when divisible.

    Only the dense ``lm.Cache`` layout is covered (``backend="dense"``).
    A paged backend's KV lives in a host-side ``BlockPool`` with layout
    ``(L, num_blocks, page, K, dh)`` — handing these specs to it would
    silently shard the *page* axis as if it were the sequence axis, so
    any other ``backend`` raises: paged caches shard across the mesh via
    ``kvcache.sharded_pool.ShardedBlockPool`` (per-shard pools driving
    per-shard kernel calls), not via GSPMD cache specs.
    """
    if backend != "dense":
        raise NotImplementedError(
            f"cache_shardings covers the dense lm.Cache layout only; "
            f"backend {backend!r} caches do not shard via GSPMD specs — "
            f"use kvcache.sharded_pool.ShardedBlockPool (mesh-partitioned "
            f"block pools) for paged serving")
    has_pod = "pod" in mesh.axis_names
    d = ("pod", "data") if has_pod else ("data",)
    nm = mesh.shape["model"]
    nd = _axis_size(mesh, d)
    bspec = d if batch % nd == 0 else None
    kv_on_heads = cfg.n_kv_heads % nm == 0
    if kv_on_heads:
        kv = P(None, bspec, None, "model", None)
    else:
        kv = P(None, bspec, "model", None, None)
    from repro.models import ssm as ssm_mod
    if cfg.has_ssm:
        _, H, _, _ = ssm_mod.ssm_dims(cfg)
        ssm = P(None, bspec, "model" if H % nm == 0 else None, None, None)
        # conv state is tiny; its (x|bc) channel split is shard-misaligned,
        # so replicate the channel dim rather than permute on every decode
        conv = P(None, bspec, None, None)
    else:
        ssm = conv = P()
    enc_kv = P(None, bspec, None, "model" if cfg.n_kv_heads % nm == 0
               else None, None)

    def ns(p):
        return NamedSharding(mesh, p)
    from repro.models.lm import Cache
    return Cache(
        k=ns(kv) if cfg.has_attention else None,
        v=ns(kv) if cfg.has_attention else None,
        ssm=ns(ssm) if cfg.has_ssm else None,
        conv=ns(conv) if cfg.has_ssm else None,
        xk=ns(enc_kv) if cfg.family == "encdec" else None,
        xv=ns(enc_kv) if cfg.family == "encdec" else None,
        length=ns(P()),
    )
