"""Ambient mesh registry.

Layers that need explicit collectives (MoE dispatch via shard_map) look up
the active mesh here; single-device tests never set one and get the local
fallback path.  ``launch/`` sets the mesh for real runs and dry-runs.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

_MESH: Optional[jax.sharding.Mesh] = None


def current_mesh() -> Optional[jax.sharding.Mesh]:
    return _MESH


def data_axes(mesh: jax.sharding.Mesh) -> tuple:
    """All mesh axes used for data parallelism (pod+data when multi-pod)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: jax.sharding.Mesh) -> str:
    return "model"


@contextlib.contextmanager
def use_mesh(mesh: Optional[jax.sharding.Mesh]):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield mesh
    finally:
        _MESH = prev
