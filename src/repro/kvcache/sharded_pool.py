"""Mesh-sharded block pools: device-aware MARS placement, one level up.

The paper's argument is about one memory device: give the controller a
large enough lookahead and interleaved streams can be reordered by
row-buffer address to recover locality.  With multiple memory *devices*
(a TPU mesh, one HBM stack per chip) the same argument applies one level
up: a stream must first be routed to the right device before row-group
placement within that device can help — the heterogeneous multi-source
problem of staged memory scheduling (Ausavarungnirun et al.).

``ShardedBlockPool`` partitions a ``BlockPool`` across the shards of a
device mesh: one independent per-shard ``BlockPool`` (its own free list,
refcounts, prefix storage and KV buffer), so the full placement key for
a block becomes

    (shard, row_group, block)        -- see ``placement.placement_key``

with the **device/shard coordinate leading** the existing bank+row-group
key: a sequence's blocks all land on one shard (chosen once, at
admission) and MARS row-group packing happens *within* that shard.
Copy-on-write forks allocate from the parent's shard pool, so forks stay
shard-local by construction.

Routing (``route``) is what the ``MarsScheduler`` calls when it admits a
request into a batch:

  1. **prefix-page affinity** — requests whose prompts hash to a page
     already routed keep going to the same shard, so shared prefixes
     co-locate and the per-shard prefix caches actually hit;
  2. **shard load** — otherwise the least-loaded shard (live + reserved
     blocks) with enough headroom wins, balancing KV footprint.

Reservations are two-phase because the scheduler reserves *before* it
routes: ``reserve`` books capacity against the aggregate pool at
``offer`` time (a sequence must fit on a single shard, so ``can_reserve``
also requires the request to fit one shard's capacity); ``route`` then
converts the aggregate booking into a concrete per-shard reservation at
schedule time, and may return ``None`` (leave the request queued) when
no shard currently has headroom.  ``unreserve`` releases a routed
request's shard reservation as the engine claims real allocations.

Mesh discovery reuses the ambient registry: with ``n_shards=None`` the
shard count comes from the mesh's model axis (``sharding.rules
.pool_shard_count`` over ``sharding.context.current_mesh()`` or an
explicit ``mesh=``); ``launch/mesh.py`` builds the serving mesh.

>>> from repro.kvcache.pool import PoolConfig
>>> sp = ShardedBlockPool(PoolConfig(num_blocks=16, block_size=4),
...                       n_shards=2)
>>> sp.n_shards, sp.shards[0].cfg.num_blocks
(2, 8)
>>> sp.reserve(2)
>>> sp.route(rid=0, page="a", n=2)          # least-loaded: shard 0
0
>>> sp.reserve(2); sp.route(rid=1, page="a", n=2)   # page affinity sticks
0
>>> sp.unreserve(2, rid=0); sp.unreserve(2, rid=1)
>>> sp.reserved
0
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.kvcache.pool import BlockPool, PoolConfig, PoolStats
from repro.obs.observer import shard_load_snapshot

# sticky page->shard affinity entries kept (LRU beyond this); bounds the
# map under a stream of unique prompts while vastly exceeding any
# plausible simultaneously-hot prefix count
PAGE_AFFINITY_CAP = 4096


def discover_shards(n_shards: Optional[int], mesh=None) -> int:
    """Resolve a shard count: an explicit ``n_shards`` wins; otherwise
    the model-axis size of ``mesh`` (or the ambient
    ``sharding.context.current_mesh()``), 1 without a mesh.  The single
    discovery routine shared by ``ShardedBlockPool``,
    ``ShardedPagedBackend`` and ``make_backend`` sizing."""
    if n_shards is not None:
        return n_shards
    from repro.sharding import context, rules
    return rules.pool_shard_count(
        mesh if mesh is not None else context.current_mesh())


class ShardedBlockPool:
    """One ``BlockPool`` per shard of the mesh's model axis.

    Invariants:
      * every block lives in exactly one shard pool; block ids are
        shard-local (the global placement key is ``(shard, group, id)``);
      * ``reserved == pending (offered, unrouted) + sum of per-shard
        reservations (routed)``, and a routed request's reservation sits
        entirely on its one shard;
      * per-shard pools never share blocks — cross-shard sharing is
        impossible, which is exactly what keeps CoW forks shard-local.
    """

    is_sharded = True     # duck-type marker for scheduler/engine branches

    def __init__(self, cfg: PoolConfig, n_shards: Optional[int] = None,
                 mesh=None):
        """Partition ``cfg.num_blocks`` across ``n_shards`` pools.

        Args:
          cfg: the *aggregate* pool config; ``num_blocks`` is the total
            across shards and must divide evenly.
          n_shards: shard count; ``None`` discovers it from ``mesh`` (or
            the ambient ``sharding.context.current_mesh()``) via
            ``sharding.rules.pool_shard_count`` — 1 without a mesh.
          mesh: optional explicit ``jax.sharding.Mesh`` for discovery.
        """
        n_shards = discover_shards(n_shards, mesh)
        assert n_shards >= 1
        assert cfg.num_blocks % n_shards == 0, \
            (f"num_blocks {cfg.num_blocks} must divide evenly across "
             f"{n_shards} shards")
        self.cfg = cfg
        self.n_shards = n_shards
        self.shard_blocks = cfg.num_blocks // n_shards
        shard_cfg = dataclasses.replace(cfg, num_blocks=self.shard_blocks)
        self.shards = [BlockPool(shard_cfg) for _ in range(n_shards)]
        # offered-but-not-yet-routed aggregate reservations (phase 1)
        self._pending = 0
        # routed requests: rid -> shard, rid -> outstanding reserved blocks
        self._rid_shard: dict[int, int] = {}
        self._rid_reserved: dict[int, int] = {}
        # sticky prefix-page affinity: page hash -> last routed shard
        # (LRU-bounded at PAGE_AFFINITY_CAP — unlike the rid maps, pages
        # have no release event to clean up on)
        self._page_shard: dict[str, int] = {}
        self.obs = None          # telemetry hook (obs.Observer.attach)

    # -- aggregate capacity (scheduler/engine-facing) -----------------------

    @property
    def num_free(self) -> int:
        return sum(s.num_free for s in self.shards)

    @property
    def num_cached(self) -> int:
        return sum(s.num_cached for s in self.shards)

    @property
    def num_live(self) -> int:
        return sum(s.num_live for s in self.shards)

    @property
    def reserved(self) -> int:
        """Outstanding reservations: unrouted (pending) + routed (shard)."""
        return self._pending + sum(s.reserved for s in self.shards)

    @property
    def stats(self) -> PoolStats:
        """Aggregated per-shard counters (a fresh snapshot per read)."""
        agg = PoolStats()
        names = agg.fields()
        for s in self.shards:
            for f in names:
                setattr(agg, f, getattr(agg, f) + getattr(s.stats, f))
        return agg

    @property
    def k_pages(self):
        """Non-None iff the shard pools carry KV buffers (shard 0's)."""
        return self.shards[0].k_pages

    @property
    def v_pages(self):
        return self.shards[0].v_pages

    # -- two-phase admission reservations -----------------------------------

    def can_reserve(self, n: int) -> bool:
        """Admission check: aggregate headroom covers ``n`` more blocks AND
        the request could ever fit on a single shard (a sequence and its
        CoW forks never span shards)."""
        if n > self.shard_blocks:
            return False
        headroom = sum(s.num_free + s.num_cached - s.reserved
                       for s in self.shards)
        return headroom - self._pending >= n

    def reserve(self, n: int) -> None:
        """Phase 1 (offer time): book ``n`` blocks against the aggregate
        pool; no shard is chosen yet."""
        self._pending += n

    def cancel_pending(self, n: int) -> None:
        """Give up an aggregate (phase-1) booking that was never routed —
        the backpressure path for callers that reserved but then dropped
        the request instead of waiting for a shard to free."""
        assert n <= self._pending, (n, self._pending)
        self._pending -= n

    def route(self, rid: int, page: str, n: int,
              tier_hint: Optional[int] = None) -> Optional[int]:
        """Phase 2 (schedule time): commit request ``rid``'s pending
        reservation of ``n`` blocks to a shard.

        Shard choice: the sticky ``page`` affinity shard if it still has
        headroom (shared prefixes co-locate); else ``tier_hint`` — the
        shard whose *spill tiers* hold the request's prefix (a promotable
        lower-tier hit, stamped by ``MarsScheduler.tier_probe``), so
        landing there turns a recompute into a shard-local promotion;
        else the least-loaded shard (live + reserved blocks) that can
        hold ``n``.  Returns the shard id, or ``None`` when no shard
        currently has headroom — the caller leaves the request queued
        and retries after sequences finish.
        """
        assert n <= self._pending, (n, self._pending)
        s = self._page_shard.get(page)
        if s is None or not self.shards[s].can_reserve(n):
            if tier_hint is not None \
                    and self.shards[tier_hint].can_reserve(n):
                s = tier_hint
            else:
                # rank shards off the shared load snapshot — same numbers
                # the obs gauges publish (headroom == can_reserve, load ==
                # live + reserved), so routing and telemetry never disagree
                fits = [r for r in shard_load_snapshot(self)
                        if r["headroom"] >= n]
                if not fits:
                    return None
                s = min(fits, key=lambda r: (r["load"], r["shard"]))["shard"]
        self._pending -= n
        self.shards[s].reserve(n)
        # refresh LRU position, then trim the oldest entry past the cap
        self._page_shard.pop(page, None)
        self._page_shard[page] = s
        if len(self._page_shard) > PAGE_AFFINITY_CAP:
            self._page_shard.pop(next(iter(self._page_shard)))
        if n > 0:      # a zero-block request needs no release bookkeeping
            self._rid_shard[rid] = s
            self._rid_reserved[rid] = self._rid_reserved.get(rid, 0) + n
        return s

    def unreserve(self, n: int, rid: int) -> None:
        """Release ``n`` of routed request ``rid``'s shard reservation (the
        engine converts reservations into real allocations as sequences
        grow, and releases the remainder when the request finishes)."""
        if n == 0:
            return
        s = self._rid_shard[rid]
        assert n <= self._rid_reserved[rid], (n, self._rid_reserved[rid])
        self.shards[s].unreserve(n)
        self._rid_reserved[rid] -= n
        if self._rid_reserved[rid] == 0:
            del self._rid_reserved[rid]
            del self._rid_shard[rid]

    def shard_of(self, rid: int) -> Optional[int]:
        """Shard a routed request was committed to (None once released)."""
        return self._rid_shard.get(rid)

    def load(self, shard: int) -> int:
        """Routing load metric for one shard: live + reserved blocks."""
        s = self.shards[shard]
        return s.num_live + s.reserved

    def least_loaded(self) -> int:
        """Shard with the lowest load (ties -> lowest index); the routing
        fallback when no prefix-page affinity applies."""
        return min(shard_load_snapshot(self),
                   key=lambda r: (r["load"], r["shard"]))["shard"]

    # -- invariants ----------------------------------------------------------

    def check_invariants(self, incremental: bool = False) -> None:
        """Per-shard allocator ground truth plus reservation accounting.
        ``incremental`` forwards to each shard's O(dirty) sweep (the
        cross-shard reservation accounting below is O(live rids) either
        way)."""
        for s in self.shards:
            s.check_invariants(incremental=incremental)
        assert self._pending >= 0
        assert all(v > 0 for v in self._rid_reserved.values())
        assert set(self._rid_reserved) == set(self._rid_shard)
        for rid, s in self._rid_shard.items():
            assert 0 <= s < self.n_shards, (rid, s)
        # every routed reservation is backed by its shard's counter
        per_shard: dict[int, int] = {}
        for rid, n in self._rid_reserved.items():
            s = self._rid_shard[rid]
            per_shard[s] = per_shard.get(s, 0) + n
        for i, s in enumerate(self.shards):
            assert s.reserved == per_shard.get(i, 0), \
                (i, s.reserved, per_shard.get(i, 0))
