"""MARS-aware block placement.

In the DRAM model (``core.dram``) a 4KB page maps to one (bank, row) pair
per channel, and the ``n_banks`` consecutive pages of a *row group* span
all banks exactly once.  A decode batch interleaves KV reads from every
running sequence's tail blocks — the same multi-stream interleave that
destroys row locality at the GPU boundary in the paper.  Two interleaved
blocks in the same bank but different rows thrash the row buffer (every
switch pays PRE+ACT); two blocks in the same row group occupy *different*
banks, so their rows stay open across the interleave.

MARS-aware placement therefore packs co-scheduled sequences' blocks into
as few row groups as possible (same neighborhood, distinct banks), and
keeps a sequence's own blocks near the groups it already occupies.  The
naive baseline is the classic slab free list: LIFO pop, which after
allocation churn hands out blocks scattered across many row groups.
"""
from __future__ import annotations

from typing import Iterable, Sequence


def row_group_of(block_id: int, blocks_per_group: int) -> int:
    """DRAM-row neighborhood of a block (block == one 4KB page)."""
    return block_id // blocks_per_group


def placement_key(block_id: int, blocks_per_group: int,
                  shard: int = 0) -> tuple[int, int, int]:
    """Full MARS placement key of a block: ``(shard, row_group, block)``.

    The **leading device/shard coordinate** orders placement decisions one
    level above the bank+row-group key: with a mesh-sharded pool
    (``kvcache.sharded_pool``) a stream is first routed to a memory
    *device* (shard), then row-group-packed within it — block ids are
    shard-local, so comparing keys across shards is only meaningful with
    the shard coordinate in front.  Single-pool callers keep ``shard=0``
    and the key degenerates to the PR-1 ``(group, block)`` order.
    """
    return (shard, row_group_of(block_id, blocks_per_group), block_id)


class PlacementPolicy:
    """Chooses which free blocks an allocation gets.

    Maintains the free set twice, mirroring how the MARS engine keeps both
    the RequestQ bit-vector and the per-page lists: a LIFO stack (arrival
    order of frees — the naive slab order) and per-row-group sets (the
    neighborhood index the MARS policy searches).
    """

    def __init__(self, num_blocks: int, blocks_per_group: int,
                 mode: str = "mars"):
        if mode not in ("mars", "naive"):
            raise ValueError(f"unknown placement mode {mode!r}")
        self.mode = mode
        self.num_blocks = num_blocks
        self.blocks_per_group = blocks_per_group
        self.n_groups = -(-num_blocks // blocks_per_group)
        self._stack: list[int] = list(range(num_blocks - 1, -1, -1))
        self._group_free: list[set[int]] = [
            set(range(g * blocks_per_group,
                      min((g + 1) * blocks_per_group, num_blocks)))
            for g in range(self.n_groups)]

    # -- free-set maintenance (called only by BlockPool) --------------------

    def add_free(self, bid: int) -> None:
        self._stack.append(bid)
        self._group_free[row_group_of(bid, self.blocks_per_group)].add(bid)

    def _take(self, bid: int) -> None:
        self._group_free[row_group_of(bid, self.blocks_per_group)].remove(bid)
        # lazy stack deletion would break the free invariant checks; the
        # stack is short (<= num_blocks) and removal is O(stack) worst case
        if self._stack and self._stack[-1] == bid:
            self._stack.pop()
        else:
            self._stack.remove(bid)

    @property
    def num_free(self) -> int:
        return len(self._stack)

    def free_ids(self) -> list[int]:
        return list(self._stack)

    # -- allocation order ---------------------------------------------------

    def choose(self, n: int,
               hint_groups: Iterable[int] = ()) -> list[int] | None:
        """Pick ``n`` free blocks; None if fewer than ``n`` are free."""
        if n > len(self._stack):
            return None
        if self.mode == "naive":
            out = [self._stack[-1 - i] for i in range(n)]
        else:
            out = self._choose_mars(n, hint_groups)
        for bid in out:
            self._take(bid)
        return out

    def _choose_mars(self, n: int, hint_groups: Iterable[int]) -> list[int]:
        hints = [g for g in dict.fromkeys(hint_groups)
                 if 0 <= g < self.n_groups]
        # neighborhoods the caller's gang already occupies first, then the
        # emptiest neighborhoods (pack the allocation into few row groups)
        rest = sorted((g for g in range(self.n_groups) if g not in hints),
                      key=lambda g: (-len(self._group_free[g]), g))
        out: list[int] = []
        for g in hints + rest:
            if len(out) >= n:
                break
            out.extend(sorted(self._group_free[g])[:n - len(out)])
        return out

    def groups_of(self, block_ids: Sequence[int]) -> list[int]:
        """Distinct row groups a set of blocks occupies (insertion order)."""
        return list(dict.fromkeys(
            row_group_of(b, self.blocks_per_group) for b in block_ids))
