"""Paged KV-cache subsystem — the memory-management half of MARS serving.

The serving analogue of the paper's memory system, one module per layer:

  pool       fixed-capacity slab allocator over a preallocated KV buffer
             (free-list + occupancy bitmap, the RequestQ bookkeeping style)
  placement  MARS-aware block placement: co-scheduled sequences' blocks land
             in the same DRAM-row neighborhood (bank-parallel, no row thrash)
  prefix     ref-counted prefix sharing + copy-on-write block tables
  evict      reclaim of cached (refcount-0) blocks: first-arrival order
             (the PhyPageOrderQ policy) or LRU
  backend    the unified KV-backend API: ``KVBackend`` protocol with
             ``DenseBackend`` (concrete per-layer cache) and
             ``PagedBackend`` (block tables over a layered pool)

``backend`` imports jax + the model stack; it is intentionally NOT
re-exported here so the allocator modules stay importable numpy-only —
use ``from repro.kvcache.backend import ...``.
"""
from repro.kvcache.evict import EvictionPolicy
from repro.kvcache.placement import PlacementPolicy, row_group_of
from repro.kvcache.pool import BlockPool, PoolConfig
from repro.kvcache.prefix import BlockTable, PrefixCache

__all__ = [
    "BlockPool", "PoolConfig", "BlockTable", "PrefixCache",
    "PlacementPolicy", "EvictionPolicy", "row_group_of",
]
