"""Paged KV-cache subsystem — the memory-management half of MARS serving.

The serving analogue of the paper's memory system, one module per layer:

  pool       fixed-capacity slab allocator over a preallocated KV buffer
             (free-list + occupancy bitmap, the RequestQ bookkeeping style)
  placement  MARS-aware block placement: co-scheduled sequences' blocks land
             in the same DRAM-row neighborhood (bank-parallel, no row thrash)
  prefix     ref-counted prefix sharing + copy-on-write block tables
  evict      reclaim of cached (refcount-0) blocks: first-arrival order
             (the PhyPageOrderQ policy) or LRU
  sharded_pool  mesh-sharded pools: one ``BlockPool`` per device-mesh
             shard, the shard coordinate leading the placement key;
             admission routing by prefix-page affinity + shard load
  tiers      tiered KV memory: eviction demotes registered prefix blocks
             to host/remote spill tiers, misses promote them back via a
             MARS-reordered batched copy-in; cost-aware eviction scoring
  backend    the unified KV-backend API: ``KVBackend`` protocol with
             ``DenseBackend`` (concrete per-layer cache), ``PagedBackend``
             (block tables over a layered pool) and
             ``ShardedPagedBackend`` (one paged backend per pool shard)

``backend`` imports jax + the model stack; it is intentionally NOT
re-exported here so the allocator modules stay importable numpy-only —
use ``from repro.kvcache.backend import ...``.  (``ShardedBlockPool``
only touches jax when asked to discover its shard count from a mesh.)
"""
from repro.kvcache.evict import EvictionPolicy
from repro.kvcache.placement import PlacementPolicy, placement_key, \
    row_group_of
from repro.kvcache.pool import BlockPool, PoolConfig
from repro.kvcache.prefix import BlockTable, PrefixCache
from repro.kvcache.sharded_pool import ShardedBlockPool
from repro.kvcache.tiers import TierManager, TierSpec, default_tiers

__all__ = [
    "BlockPool", "PoolConfig", "BlockTable", "PrefixCache",
    "PlacementPolicy", "EvictionPolicy", "row_group_of", "placement_key",
    "ShardedBlockPool", "TierManager", "TierSpec", "default_tiers",
]
