"""Fixed-capacity block pool: a slab allocator over a preallocated KV buffer.

Bookkeeping mirrors the fixed-array style of the MARS engine
(``core.mars``): an occupancy bit-vector (``used``, the RequestQ
``rq_valid`` analogue), a refcount array, and first-arrival / last-use
ticks per block.  The physical KV storage is a pair of arrays of shape
``(n_layers, num_blocks, block_size, n_kv_heads, head_dim)`` allocated
once up front (host-resident, mutated in place; the engine stages them to
device per step) — block ids index directly into the paged-attention
kernel's ``k_pages``/``v_pages`` operands, so the allocator's placement
decisions *are* the kernel's gather addresses.

The leading **layer axis** makes one block id address a token-chunk's KV
for *every* model layer at once: a multi-layer LM (``kvcache.backend``)
keeps a single block table per sequence, and one placement decision
co-locates a token's per-layer blocks in the same DRAM row group — the
multi-layer rendering of MARS placement the single-layer engine of PR 1
could not express.

Blocks move through three states::

    free  --alloc-->  live (refcount >= 1)
    live  --decref(cache=True), refcount hits 0-->  cached (evictable)
    live  --decref(cache=False), refcount hits 0--> free
    cached --reuse--> live        cached --evict--> free

``content`` carries an opaque per-block payload tag (the token tuple the
block holds) used by prefix matching and by the soak tests to prove
copy-on-write never mutates a shared block.

A metadata-only pool (no KV buffer) is enough to watch the allocator
life-cycle:

>>> pool = BlockPool(PoolConfig(num_blocks=8, block_size=4))
>>> a = pool.alloc(2)
>>> pool.num_live, pool.num_free, pool.num_cached
(2, 6, 0)
>>> pool.decref(a[0])                 # free outright
>>> pool.decref(a[1], cache=True)     # retain as evictable prefix storage
>>> pool.num_live, pool.num_free, pool.num_cached
(0, 7, 1)
>>> pool.check_invariants()
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional

import numpy as np

from repro.kvcache.evict import EvictionPolicy
from repro.kvcache.placement import PlacementPolicy, row_group_of
from repro.obs.metrics import StatGroup

# one block == one 4KB page of the DRAM model (64 x 64B lines)
LINES_PER_BLOCK = 64


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, falling back to ml_dtypes for the low-precision
    names numpy lacks (bfloat16, float8_*) — available wherever jax is."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    num_blocks: int = 256
    block_size: int = 16          # tokens per block
    blocks_per_group: int = 8     # DRAM row neighborhood = n_banks pages
    placement: str = "mars"       # "mars" | "naive"
    eviction: str = "fifo"        # "fifo" (PhyPageOrderQ) | "lru" | "cost"
    # KV buffer shape; None = metadata-only pool (simulation / tests)
    n_kv_heads: Optional[int] = None
    head_dim: Optional[int] = None
    n_layers: int = 1             # leading layer axis of the KV buffer
    dtype: str = "float32"


class PoolStats(StatGroup):
    """Allocator counters, now an ``obs.metrics.StatGroup`` facade: the
    same attribute API the old dataclass had (``stats.allocs += n``),
    but the fields are live ``Counter`` objects a ``MetricsRegistry``
    adopts — the pool and the metrics snapshot share one copy of each
    number."""
    FIELDS = {"allocs": 0, "frees": 0, "evictions": 0, "cow_copies": 0,
              "prefix_hits": 0, "alloc_fails": 0}


class BlockPool:
    def __init__(self, cfg: PoolConfig):
        self.cfg = cfg
        n = cfg.num_blocks
        self.used = np.zeros(n, bool)            # occupancy bit-vector
        self.refcount = np.zeros(n, np.int32)
        self.arrival = np.zeros(n, np.int64)     # allocation tick
        self.last_use = np.zeros(n, np.int64)
        self.content: list[object] = [None] * n
        self._tick = 0
        self.placement = PlacementPolicy(n, cfg.blocks_per_group,
                                         cfg.placement)
        self.eviction = EvictionPolicy(cfg.eviction)
        # cached (refcount-0, still resident) blocks, insertion-ordered
        self._evictable: dict[int, None] = {}
        # prefix cache hook: called with a block id as it is evicted
        self.on_evict: Optional[Callable[[int], None]] = None
        # admission reservations (see reserve()): blocks promised to
        # admitted-but-not-yet-allocated work.  Held until the owning
        # request claims (allocates) or releases them — NOT dropped at
        # schedule time, otherwise lazily-allocated decode blocks would
        # over-commit the pool.
        self.reserved = 0
        self.stats = PoolStats()
        # telemetry (obs.Observer.attach): None = uninstrumented; events
        # carry obs_shard so sharded pools tag their shard index
        self.obs = None
        self.obs_shard = 0
        # blocks whose allocator state changed since the last incremental
        # invariant sweep (check_invariants(incremental=True)) — the
        # O(dirty) working set the --paranoid serve mode validates
        self._meta_dirty: set[int] = set()
        # KV payload: host-resident, mutated in place (a functional
        # .at[].set would copy the whole pool per token); staged to device
        # once per engine step when the kernel consumes it
        self.k_pages = self.v_pages = None
        # blocks whose payload changed since the last drain_dirty() —
        # lets a device mirror re-stage only what was written instead of
        # the whole pool every step (single consumer: whoever drains)
        self.dirty: set[int] = set()
        if cfg.n_kv_heads is not None and cfg.head_dim is not None:
            shape = (cfg.n_layers, n, cfg.block_size,
                     cfg.n_kv_heads, cfg.head_dim)
            self.k_pages = np.zeros(shape, _np_dtype(cfg.dtype))
            self.v_pages = np.zeros(shape, _np_dtype(cfg.dtype))

    # -- capacity -----------------------------------------------------------

    @property
    def num_free(self) -> int:
        return self.placement.num_free

    @property
    def num_cached(self) -> int:
        return len(self._evictable)

    @property
    def num_live(self) -> int:
        return int(self.used.sum()) - self.num_cached

    def can_alloc(self, n: int) -> bool:
        """True iff ``alloc(n)`` would succeed right now (free blocks plus
        cached blocks reclaimable by eviction); ignores reservations —
        use ``can_reserve`` for admission decisions."""
        return self.num_free + self.num_cached >= n

    # -- admission reservations ---------------------------------------------

    def can_reserve(self, n: int) -> bool:
        """Admission capacity check: could ``n`` more blocks be promised
        on top of every outstanding reservation?  (free + cached −
        reserved ≥ n; cached counts because eviction reclaims it.)"""
        return self.num_free + self.num_cached - self.reserved >= n

    def reserve(self, n: int) -> None:
        """Promise ``n`` blocks to admitted-but-not-yet-allocated work.
        Reservations are bookkeeping only — they do not pin specific
        blocks; the holder converts them into real allocations over the
        sequence's lifetime and must ``unreserve`` the remainder."""
        self.reserved += n
        if self.obs is not None:
            self.obs.trace.event("pool.reserve", n=n, shard=self.obs_shard)

    def unreserve(self, n: int) -> None:
        """Release ``n`` previously reserved blocks (n ≤ reserved,
        asserted).  Invariant: 0 ≤ reserved ≤ num_blocks always holds."""
        assert n <= self.reserved, (n, self.reserved)
        self.reserved -= n
        if self.obs is not None:
            self.obs.trace.event("pool.unreserve", n=n,
                                 shard=self.obs_shard)

    # -- alloc / ref / free -------------------------------------------------

    def alloc(self, n: int = 1,
              hint_blocks: Iterable[int] = ()) -> list[int]:
        """Allocate ``n`` blocks at refcount 1.

        Args:
          n: block count; cached blocks are evicted (oldest-first per the
            eviction policy) when the free list is short.
          hint_blocks: blocks the requesting gang already holds — MARS
            placement packs the new blocks into (or next to) the DRAM row
            groups those occupy.
        Returns:
          the chosen block ids, placement-ordered.
        Raises:
          RuntimeError("pool exhausted ...") if free + cached < n; the
          pool is unchanged in that case (the check precedes eviction).
        """
        short = n - self.num_free
        if short > 0:
            if short > self.num_cached:
                self.stats.alloc_fails += 1
                if self.obs is not None:
                    self.obs.trace.event("pool.alloc_fail", n=n,
                                         shard=self.obs_shard)
                raise RuntimeError(
                    f"pool exhausted: want {n}, free {self.num_free}, "
                    f"cached {self.num_cached}")
            self._evict(short)
        hint_groups = self.placement.groups_of(list(hint_blocks))
        out = self.placement.choose(n, hint_groups)
        assert out is not None
        self._tick += 1
        for bid in out:
            self.used[bid] = True
            self.refcount[bid] = 1
            self.arrival[bid] = self._tick
            self.last_use[bid] = self._tick
            self.content[bid] = None
        self.stats.allocs += n
        self._meta_dirty.update(out)
        if self.obs is not None:
            self.obs.trace.event("pool.alloc", n=n, shard=self.obs_shard)
        return out

    def incref(self, bid: int) -> None:
        assert self.used[bid] and self.refcount[bid] > 0
        self.refcount[bid] += 1

    def decref(self, bid: int, cache: bool = False) -> None:
        """Drop one reference; at zero either retain as evictable prefix
        storage (``cache=True``) or free outright."""
        assert self.used[bid] and self.refcount[bid] > 0, bid
        self.refcount[bid] -= 1
        if self.refcount[bid] == 0:
            if cache:
                self._evictable[bid] = None
                self._meta_dirty.add(bid)
            else:
                self._free_block(bid)

    def reuse_cached(self, bid: int) -> None:
        """Revive a cached block (prefix hit): refcount 0 -> 1."""
        assert bid in self._evictable, bid
        del self._evictable[bid]
        self.refcount[bid] = 1
        self._tick += 1
        self.last_use[bid] = self._tick
        self.stats.prefix_hits += 1
        self._meta_dirty.add(bid)

    def touch(self, bid: int) -> None:
        self._tick += 1
        self.last_use[bid] = self._tick

    def _free_block(self, bid: int) -> None:
        self.used[bid] = False
        self.refcount[bid] = 0
        self.content[bid] = None
        # dirty-staging contract: a freed (evicted/demoted) id must not
        # linger in the dirty set — the single drain consumer would
        # re-scatter a dead slot's payload into the device mirror after
        # the slot is reused (demotion captures the pending payload
        # before this point; see kvcache.tiers.TierManager)
        self.dirty.discard(bid)
        self.placement.add_free(bid)
        self.stats.frees += 1
        self._meta_dirty.add(bid)

    def _evict(self, n: int) -> None:
        victims = self.eviction.select(self._evictable, self.arrival,
                                       self.last_use, n)
        for bid in victims:
            del self._evictable[bid]
            if self.on_evict is not None:
                self.on_evict(bid)
            self._free_block(bid)
            self.stats.evictions += 1
        if victims and self.obs is not None:
            self.obs.trace.event("pool.evict", n=len(victims),
                                 shard=self.obs_shard)

    # -- KV payload ---------------------------------------------------------

    def write_kv(self, bid: int, offset: int, k, v) -> None:
        """Write ``t`` token KV rows into a block at ``offset``, for every
        layer plane at once, and mark the block dirty for staging.

        Args:
          bid: destination block (must be live; offset + t ≤ block_size,
            asserted).
          offset: first token slot written within the block.
          k, v: (n_layers, t, n_kv_heads, head_dim) arrays; a layerless
            (t, n_kv_heads, head_dim) is accepted when the pool has a
            single layer plane (the PR-1 single-layer engine path).
        """
        k, v = np.asarray(k), np.asarray(v)
        if k.ndim == 3:
            assert self.cfg.n_layers == 1, "layered pool needs layered KV"
            k, v = k[None], v[None]
        t = k.shape[1]
        assert offset + t <= self.cfg.block_size
        self.k_pages[:, bid, offset:offset + t] = k
        self.v_pages[:, bid, offset:offset + t] = v
        self.dirty.add(bid)

    def copy_block(self, src: int, dst: int) -> None:
        """Copy-on-write payload copy (content tag + all layer planes)."""
        self.content[dst] = self.content[src]
        if self.k_pages is not None:
            self.k_pages[:, dst] = self.k_pages[:, src]
            self.v_pages[:, dst] = self.v_pages[:, src]
            self.dirty.add(dst)
        self.stats.cow_copies += 1
        if self.obs is not None:
            self.obs.trace.event("pool.cow", src=src, dst=dst,
                                 shard=self.obs_shard)

    def forget_dirty(self, bid: int) -> None:
        """Drop a block from the dirty-staging set without draining.

        For owners that invalidate a block's pending payload out of band
        (e.g. ``kvcache.tiers.TierManager`` capturing a demoted block's
        KV before the slot is reused) — everyone else goes through
        ``write_kv``/``copy_block``/``drain_dirty`` and must never touch
        ``dirty`` directly (enforced by ``tools/lint.py``,
        rule ``pool-kv-mutation``).
        """
        self.dirty.discard(bid)

    def drain_dirty(self) -> list[int]:
        """Block ids whose payload changed since the last drain (sorted),
        clearing the set.

        This is the dirty-block staging contract: the pool mutates its KV
        buffers host-side in place (``write_kv``/``copy_block`` add to
        ``dirty``); a **single consumer** — the owning backend's device
        mirror — drains the set once per decode step and re-uploads
        exactly those blocks instead of the whole pool.  Two consumers
        would each see only a partial dirty stream and serve stale pages,
        which is why a pool belongs to one backend (and, mesh-sharded,
        each shard's pool to that shard's backend/mirror/device).
        """
        out = sorted(self.dirty)
        self.dirty.clear()
        if out and self.obs is not None:
            self.obs.trace.event("pool.drain_dirty", n=len(out),
                                 shard=self.obs_shard)
        return out

    # -- invariants ---------------------------------------------------------

    def check_invariants(self, incremental: bool = False) -> None:
        """Allocator ground truth.

        ``incremental=False`` is the exhaustive O(num_blocks) sweep the
        tests run.  ``incremental=True`` validates only the blocks whose
        allocator state changed since the previous incremental sweep
        (``_meta_dirty`` — O(dirty), typically a handful of blocks per
        engine step) plus O(1) aggregate counts, cheap enough for the
        serving loop to run every N steps (``--metrics --paranoid``).
        Both modes raise AssertionError on the first violation.
        """
        if incremental:
            self._check_incremental()
            return
        free = self.placement.free_ids()
        assert len(free) == len(set(free)), "free list holds duplicates"
        free_set = set(free)
        group_union = set().union(*self.placement._group_free) \
            if self.placement._group_free else set()
        assert free_set == group_union, "stack / group free sets diverged"
        for bid in range(self.cfg.num_blocks):
            if bid in free_set:
                assert not self.used[bid], f"block {bid} free AND used"
                assert self.refcount[bid] == 0
            else:
                assert self.used[bid], f"block {bid} leaked (not free, not used)"
        cached = set(self._evictable)
        for bid in cached:
            assert self.used[bid] and self.refcount[bid] == 0
        live = [b for b in range(self.cfg.num_blocks)
                if self.used[b] and b not in cached]
        for bid in live:
            assert self.refcount[bid] > 0, f"live block {bid} has refcount 0"
        assert len(free_set) + len(cached) + len(live) == self.cfg.num_blocks
        assert 0 <= self.reserved <= self.cfg.num_blocks
        self._meta_dirty.clear()   # full sweep subsumes the pending one

    def _check_incremental(self) -> None:
        """O(dirty) slice of the invariant sweep: aggregate accounting
        plus per-block state for every block touched since the last
        sweep.  Free-set membership is O(1) via the placement policy's
        per-row-group free sets (kept in lockstep with the stack)."""
        n = self.cfg.num_blocks
        n_used = int(self.used.sum())
        assert self.num_free + n_used == n, \
            (self.num_free, n_used, "free/used partition lost blocks")
        assert self.num_cached <= n_used, (self.num_cached, n_used)
        assert 0 <= self.reserved <= n, self.reserved
        bpg = self.placement.blocks_per_group
        for bid in self._meta_dirty:
            in_free = bid in \
                self.placement._group_free[row_group_of(bid, bpg)]
            if in_free:
                assert not self.used[bid], f"block {bid} free AND used"
                assert self.refcount[bid] == 0, bid
            else:
                assert self.used[bid], \
                    f"block {bid} leaked (not free, not used)"
                if bid in self._evictable:
                    assert self.refcount[bid] == 0, bid
                else:
                    assert self.refcount[bid] > 0, \
                        f"live block {bid} has refcount 0"
        self._meta_dirty.clear()
