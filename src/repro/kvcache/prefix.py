"""Ref-counted prefix sharing: block tables, prefix cache, copy-on-write.

Requests whose prompts share a prefix share the physical blocks that hold
it (the serving rendering of the paper's "physical page": many streams,
one row).  Sharing is at full-block granularity via an exact-prefix map;
forked sequences (parallel sampling) additionally share their *partial*
tail block, which makes appends hit the copy-on-write path: a shared
block is never written in place — the writer gets a fresh block, the
payload is copied, and the old block's refcount drops by one.

Full blocks register in the ``PrefixCache`` keyed by the exact token
prefix they complete; when their last reference drops they linger in the
pool as evictable cached blocks until memory pressure reclaims them
(``kvcache.evict``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.kvcache.pool import BlockPool


@dataclasses.dataclass
class BlockTable:
    """Per-sequence ordered list of pool block ids + logical token count."""

    blocks: list[int] = dataclasses.field(default_factory=list)
    num_tokens: int = 0

    def tail_room(self, block_size: int) -> int:
        return -self.num_tokens % block_size

    def fork(self, pool: BlockPool) -> "BlockTable":
        """Share every block (including a partial tail) with a new table."""
        for bid in self.blocks:
            pool.incref(bid)
        return BlockTable(list(self.blocks), self.num_tokens)

    def extend(self, pool: BlockPool, tokens: Sequence[int], *,
               seq_tokens: Sequence[int],
               cache: Optional["PrefixCache"] = None,
               kv=None) -> None:
        """Append ``tokens`` (the new suffix of ``seq_tokens``), allocating
        and copy-on-writing blocks as needed.

        ``kv``: optional (k, v) arrays of shape (len(tokens), Hkv, D) —
        or (n_layers, len(tokens), Hkv, D) for a layered pool — to store
        into the pool's KV buffer alongside the token tags.
        """
        bs = pool.cfg.block_size
        assert len(seq_tokens) == self.num_tokens + len(tokens)
        done = 0
        while done < len(tokens):
            fill = self.num_tokens % bs
            if fill == 0:
                bid = pool.alloc(1, hint_blocks=self.blocks)[0]
                self.blocks.append(bid)
            else:
                bid = self.blocks[-1]
                if pool.refcount[bid] > 1:        # copy-on-write
                    new = pool.alloc(1, hint_blocks=self.blocks)[0]
                    pool.copy_block(bid, new)
                    pool.decref(bid)
                    bid = self.blocks[-1] = new
            take = min(bs - fill, len(tokens) - done)
            chunk = tuple(tokens[done:done + take])
            prev = pool.content[bid] or ()
            assert len(prev) == fill, (prev, fill)
            pool.content[bid] = prev + chunk
            if kv is not None:
                k, v = kv
                # token axis is -3 for both layerless and layered shapes
                pool.write_kv(bid, fill, k[..., done:done + take, :, :],
                              v[..., done:done + take, :, :])
            pool.touch(bid)
            self.num_tokens += take
            done += take
            if cache is not None and self.num_tokens % bs == 0:
                cache.register(tuple(seq_tokens[:self.num_tokens]), bid, pool)


class PrefixCache:
    """Exact-prefix map: full-block token prefixes -> pool block id."""

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._by_key: dict[tuple, int] = {}
        self._by_bid: dict[int, tuple] = {}

    def attach(self, pool: BlockPool) -> None:
        pool.on_evict = self.on_evict

    # -- lookup -------------------------------------------------------------

    def match(self, prompt: Sequence[int],
              pool: BlockPool) -> tuple[list[int], int]:
        """Longest chain of cached full blocks covering a prompt prefix.

        Matched blocks are referenced (revived from the evictable set if
        needed) before returning, so they cannot be evicted out from under
        the caller.  Never matches the *whole* prompt — the last token must
        be recomputed so the sequence has a writable tail position.
        """
        bs = self.block_size
        bids: list[int] = []
        n = 0
        while n + bs < len(prompt):
            key = tuple(prompt[:n + bs])
            bid = self._by_key.get(key)
            if bid is None:
                break
            assert pool.content[bid] == key[n:], "prefix cache corrupt"
            if pool.refcount[bid] == 0:
                pool.reuse_cached(bid)
            else:
                pool.incref(bid)
                pool.stats.prefix_hits += 1
            bids.append(bid)
            n += bs
        return bids, n

    # -- registration / teardown ---------------------------------------------

    def register(self, prefix: tuple, bid: int, pool: BlockPool) -> None:
        """Publish a just-completed full block; first writer wins (a later
        identical prefix keeps its private copy unregistered)."""
        if prefix in self._by_key or bid in self._by_bid:
            return
        self._by_key[prefix] = bid
        self._by_bid[bid] = prefix

    def on_evict(self, bid: int) -> None:
        key = self._by_bid.pop(bid, None)
        if key is not None:
            del self._by_key[key]

    def is_registered(self, bid: int) -> bool:
        return bid in self._by_bid

    def release(self, table: BlockTable, pool: BlockPool) -> None:
        """Drop a finished sequence's references; registered blocks stay
        resident as evictable cache, private ones free immediately."""
        for bid in table.blocks:
            pool.decref(bid, cache=self.is_registered(bid))
        table.blocks = []
        table.num_tokens = 0

    def __len__(self) -> int:
        return len(self._by_key)
