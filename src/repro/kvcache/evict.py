"""Eviction of cached (refcount-zero) prefix blocks.

A block whose last reference drops is not necessarily freed: if it holds a
registered prompt prefix it stays resident so a future request can reuse
it, exactly like a clean page in a page cache.  When an allocation finds
the free list short, cached blocks are reclaimed in one of two orders:

  fifo   first-arrival order of the block's allocation — the PhyPageOrderQ
         policy of the MARS engine (drain the oldest page first), which
         bounds how long any block can squat in the pool
  lru    least-recently-used, the classic comparison point
  cost   recompute-vs-refetch aware: victims are ranked by what
         re-acquiring the block would cost (cheapest first), via a
         ``cost_fn`` hook — ``kvcache.tiers.TierManager`` installs its
         scoring (0 for a clean tier copy, bytes x tier fetch cost for a
         demotable block, tokens-to-recompute x prefill cost for a drop);
         ties and an uninstalled hook fall back to LRU order
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np


class EvictionPolicy:
    def __init__(self, mode: str = "fifo",
                 cost_fn: Optional[Callable[[int], float]] = None):
        if mode not in ("fifo", "lru", "cost"):
            raise ValueError(f"unknown eviction mode {mode!r}")
        self.mode = mode
        # re-acquisition cost of evicting a block id now (microseconds);
        # consulted only in "cost" mode, installed post-construction by
        # whoever owns the cost model (the tier manager)
        self.cost_fn = cost_fn

    def select(self, evictable: "dict[int, None]", arrival: np.ndarray,
               last_use: np.ndarray, n: int) -> list[int]:
        """Pick ``n`` victims from the evictable id set (keys of an
        insertion-ordered dict, oldest insertion first)."""
        ids = list(evictable)
        if n >= len(ids):
            return ids
        if self.mode == "cost" and self.cost_fn is not None:
            fn = self.cost_fn
            ids.sort(key=lambda b: (fn(b), int(last_use[b]), b))
            return ids[:n]
        key = arrival if self.mode == "fifo" else last_use
        ids.sort(key=lambda b: (int(key[b]), b))
        return ids[:n]
