"""Eviction of cached (refcount-zero) prefix blocks.

A block whose last reference drops is not necessarily freed: if it holds a
registered prompt prefix it stays resident so a future request can reuse
it, exactly like a clean page in a page cache.  When an allocation finds
the free list short, cached blocks are reclaimed in one of two orders:

  fifo   first-arrival order of the block's allocation — the PhyPageOrderQ
         policy of the MARS engine (drain the oldest page first), which
         bounds how long any block can squat in the pool
  lru    least-recently-used, the classic comparison point
"""
from __future__ import annotations

import numpy as np


class EvictionPolicy:
    def __init__(self, mode: str = "fifo"):
        if mode not in ("fifo", "lru"):
            raise ValueError(f"unknown eviction mode {mode!r}")
        self.mode = mode

    def select(self, evictable: "dict[int, None]", arrival: np.ndarray,
               last_use: np.ndarray, n: int) -> list[int]:
        """Pick ``n`` victims from the evictable id set (keys of an
        insertion-ordered dict, oldest insertion first)."""
        ids = list(evictable)
        if n >= len(ids):
            return ids
        key = arrival if self.mode == "fifo" else last_use
        ids.sort(key=lambda b: (int(key[b]), b))
        return ids[:n]
