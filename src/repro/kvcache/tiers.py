"""Tiered KV memory: host / mock-remote spill tiers behind ``BlockPool``.

The pool is a single fixed-capacity tier; production prefix caches are
not.  This module turns eviction into *demotion*: when memory pressure
reclaims a registered prefix block, its KV payload moves to a slower
tier (host memory, then a mock "remote" store with a configurable
latency/bandwidth model) instead of vanishing.  A later prefix-cache
miss that hits a lower tier *promotes* the blocks back.

The promotion path is the paper's source-side reorder applied to
inter-tier traffic: promotions accumulate in a lookahead queue over a
batched prefill (``TierManager.match`` enqueues, the owning backend
flushes once per batch) and the batched copy-in is MARS-reordered by
**destination row group** — group writes by DRAM row neighborhood,
groups in first-arrival order, FIFO within a group (``promotion_order``
is the numpy rendering of ``core.reorder.mars_order``).  The destination
blocks are MARS-placed against the requesting sequence's blocks, so the
reordered copy-in stream is row-contiguous where the arrival-interleaved
stream is not — ``benchmarks/kvcache_bench.py`` replays both through
``core/dram.simulate`` and gates the gap.

Eviction becomes cost-aware (``EvictionPolicy(mode="cost")``): victims
are ranked by what re-acquiring the block would cost — ~0 for a block
whose clean copy already sits in a tier, ``bytes / tier bandwidth +
latency`` for a demotable block, ``tokens-to-recompute x prefill cost``
for one that would have to be recomputed — instead of pure recency.
``TierManager`` installs the scoring hook on pools configured with
``eviction="cost"``.

Kept numpy-only (like the rest of the allocator layer) so it is
importable without jax; the jax-facing wiring lives in
``kvcache.backend``.

>>> from repro.kvcache.pool import BlockPool, PoolConfig
>>> from repro.kvcache.prefix import BlockTable, PrefixCache
>>> pool = BlockPool(PoolConfig(num_blocks=4, block_size=2,
...                             n_kv_heads=1, head_dim=2))
>>> cache = PrefixCache(2); cache.attach(pool)
>>> tiers = TierManager(pool, cache)
>>> t = BlockTable()
>>> t.extend(pool, [1, 2, 3, 4], seq_tokens=[1, 2, 3, 4], cache=cache)
>>> cache.release(t, pool)              # blocks linger as evictable cache
>>> _ = pool.alloc(4)                   # pressure: eviction demotes
>>> tiers.tiers[0].holds((1, 2)), pool.num_cached
(True, 0)
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np

from repro.kvcache.placement import row_group_of
from repro.kvcache.pool import BlockPool, LINES_PER_BLOCK
from repro.kvcache.prefix import PrefixCache
from repro.obs.metrics import StatGroup

# recompute cost model for cost-aware eviction: microseconds of prefill
# per token that would have to be re-run to rebuild a dropped prefix
# block (depth tokens — prefill is causal, the whole prefix reruns).
# Only the ratio against TierSpec fetch costs matters.
PREFILL_US_PER_TOKEN = 25.0


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One spill tier's capacity + fetch-cost model.

    ``fetch_us`` is the modeled stall of pulling ``n_bytes`` up from this
    tier in one batch: a flat per-batch ``latency_us`` plus the transfer
    at ``gbps`` sustained bandwidth.
    """

    name: str
    capacity_blocks: int          # entries held; <= 0 means unbounded
    latency_us: float = 0.0       # per-batch fetch latency
    gbps: float = 10.0            # sustained fetch bandwidth

    def fetch_us(self, n_bytes: int) -> float:
        # GB/s == bytes/ns: n_bytes / (gbps * 1000) is microseconds
        return self.latency_us + n_bytes / (self.gbps * 1e3)


def default_tiers(num_blocks: int) -> tuple[TierSpec, ...]:
    """Host DRAM behind the pool, a mock remote store behind that.
    Sized relative to the pool so spill cascades are reachable in tests
    and smokes without hand-tuning."""
    return (TierSpec("host", 4 * num_blocks, latency_us=5.0, gbps=20.0),
            TierSpec("remote", 32 * num_blocks, latency_us=200.0, gbps=2.0))


@dataclasses.dataclass
class TierEntry:
    """A demoted block: the prefix it completes + its captured payload."""

    key: tuple                    # full-token prefix (PrefixCache key)
    content: tuple                # the block's own token span (pool tag)
    k: np.ndarray                 # (n_layers, block_size, Hkv, dh) copy
    v: np.ndarray

    @property
    def depth(self) -> int:
        """Tokens a from-scratch recompute of this block would prefill."""
        return len(self.key)

    @property
    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes


class SpillTier:
    """One LRU-ordered tier of demoted block payloads, keyed by prefix."""

    def __init__(self, spec: TierSpec):
        self.spec = spec
        self._entries: "OrderedDict[tuple, TierEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def holds(self, key: tuple) -> bool:
        return key in self._entries

    def get(self, key: tuple) -> Optional[TierEntry]:
        """Fetch (and LRU-refresh) an entry; None on miss."""
        e = self._entries.get(key)
        if e is not None:
            self._entries.move_to_end(key)
        return e

    def put(self, entry: TierEntry) -> list[TierEntry]:
        """Insert an entry, returning whatever overflowed (oldest first)
        for the caller to cascade into the next tier (or drop)."""
        self._entries.pop(entry.key, None)
        self._entries[entry.key] = entry
        out: list[TierEntry] = []
        cap = self.spec.capacity_blocks
        while cap > 0 and len(self._entries) > cap:
            _, old = self._entries.popitem(last=False)
            out.append(old)
        return out

    @property
    def occupancy(self) -> float:
        cap = self.spec.capacity_blocks
        return len(self._entries) / cap if cap > 0 else 0.0


class TierStats(StatGroup):
    """Tier-boundary counters (``obs.metrics.StatGroup`` facade adopted
    by the registry as ``tier.shardN.<field>``)."""
    FIELDS = {"demotes": 0, "promotes": 0, "promoted_tokens": 0,
              "refetched_bytes": 0, "drops": 0, "clean_drops": 0,
              "stall_us": 0.0}


def promotion_order(group_ids: Sequence[int]) -> list[int]:
    """MARS emission order for a promotion batch, keyed by destination
    row group: writes grouped by row group, groups in first-arrival
    order, FIFO within a group — the numpy rendering of
    ``core.reorder.mars_order`` (tested equivalent against it).

    >>> promotion_order([3, 1, 3, 1, 2])
    [0, 2, 1, 3, 4]
    """
    first: dict[int, int] = {}
    for i, g in enumerate(group_ids):
        first.setdefault(g, i)
    return sorted(range(len(group_ids)),
                  key=lambda i: (first[group_ids[i]], i))


def _key_tag(key: tuple) -> str:
    """Short stable hash of a prefix key for trace events (the tier
    analogue of ``Request.page``)."""
    return hashlib.sha1(repr(key).encode()).hexdigest()[:12]


class TierManager:
    """Demote-on-evict / promote-on-miss glue between one ``BlockPool``,
    its ``PrefixCache``, and a cascade of ``SpillTier``s.

    Shard-local by construction: a manager owns exactly one pool (mesh-
    sharded deployments build one manager per shard pool inside that
    shard's backend), so demoted payloads never cross shards.

    Wiring: interposes on ``pool.on_evict`` (chaining to whatever was
    installed — normally ``PrefixCache.on_evict``) so eviction of a
    registered block captures its payload *before* the prefix cache
    unregisters it and the pool frees the slot, and drains the block's
    pending dirty state (an evicted id must never linger in
    ``pool.dirty`` — the captured copy is the freshest payload by
    construction, the host arrays being the source of truth).

    Promotion protocol (what ``PagedBackend`` drives):

      1. ``match(prompt)`` — prefix-cache match first; each further
         full-block miss that hits a tier allocates a MARS-placed
         destination block and *enqueues* the copy-in (lookahead queue,
         shared across all rows of a batched prefill; a second row
         wanting the same pending key references the queued block).
      2. ``flush_promotions()`` — once per batch: reorder the queue by
         destination row group (``promotion_order``), do the batched
         copy-in, mark blocks dirty (the backend's staged device mirror
         re-uploads them before the next kernel step — promotion always
         completes before a promoted page can enter a decode batch),
         register the prefixes, and charge the modeled fetch stall.
      3. ``cancel_promotions()`` — rollback path: forget the queue
         without copying (the destination blocks are released by the
         caller's table rollback; tier entries were never removed).
    """

    def __init__(self, pool: BlockPool, prefix: PrefixCache,
                 specs: Optional[Sequence[TierSpec]] = None, *,
                 reorder: bool = True):
        self.pool = pool
        self.prefix = prefix
        self.tiers = [SpillTier(s) for s in
                      (specs if specs is not None
                       else default_tiers(pool.cfg.num_blocks))]
        assert self.tiers, "need at least one spill tier"
        self.reorder = reorder
        self.stats = TierStats()
        self.obs = None           # telemetry hook (obs.Observer.attach)
        self.obs_shard = 0
        # lookahead promotion queue: (dst block id, entry, tier index)
        self._pending: list[tuple[int, TierEntry, int]] = []
        self._pending_by_key: dict[tuple, int] = {}
        # interpose on eviction, chaining to the prefix cache's hook
        self._chain = pool.on_evict
        pool.on_evict = self._on_evict
        # cost-aware eviction: install the scoring hook when configured
        if pool.eviction.mode == "cost":
            pool.eviction.cost_fn = self.evict_cost

    # -- demotion (the eviction path) ---------------------------------------

    def _on_evict(self, bid: int) -> None:
        key = self.prefix._by_bid.get(bid)
        if key is not None:
            self._demote(bid, key)
        # pending payload of an evicted block must not be re-staged: the
        # demotion above captured the freshest copy; the slot is free
        self.pool.forget_dirty(bid)
        if self._chain is not None:
            self._chain(bid)       # prefix cache unregisters the block
        self._publish()

    def _demote(self, bid: int, key: tuple) -> None:
        for t in self.tiers:
            if t.holds(key):
                # registered full blocks are immutable once complete, so
                # a resident tier copy is clean — dropping is free
                t.get(key)                 # LRU refresh
                self.stats.clean_drops += 1
                return
        pool = self.pool
        # bookkeeping-only pools (no KV buffers) demote placement state
        # alone — benches and allocator tests run the full tier protocol
        # without paying for payload copies
        empty = np.zeros(0, np.float32)
        entry = TierEntry(
            key=key, content=pool.content[bid],
            k=np.array(pool.k_pages[:, bid])
            if pool.k_pages is not None else empty,
            v=np.array(pool.v_pages[:, bid])
            if pool.v_pages is not None else empty)
        self.stats.demotes += 1
        if self.obs is not None:
            self.obs.trace.event("tier.demote", key=_key_tag(key),
                                 shard=self.obs_shard,
                                 tier=self.tiers[0].spec.name)
        self._cascade(entry, 0)

    def _cascade(self, entry: TierEntry, level: int) -> None:
        """Insert at ``level``; overflow demotes down the cascade, and
        overflow past the last tier is dropped (counted)."""
        for displaced in self.tiers[level].put(entry):
            if level + 1 < len(self.tiers):
                self._cascade(displaced, level + 1)
            else:
                self.stats.drops += 1

    # -- promotion (the miss path) ------------------------------------------

    def _lookup(self, key: tuple) -> tuple[Optional[TierEntry], int]:
        for i, t in enumerate(self.tiers):
            e = t.get(key)
            if e is not None:
                return e, i
        return None, -1

    def holds_prefix(self, prompt: Sequence[int]) -> bool:
        """True iff the first full prompt block is promotable from a
        tier — what shard routing counts as a lower-tier prefix hit."""
        bs = self.prefix.block_size
        if len(prompt) <= bs:
            return False
        key = tuple(prompt[:bs])
        return any(t.holds(key) for t in self.tiers)

    def match(self, prompt: Sequence[int]) -> tuple[list[int], int]:
        """``PrefixCache.match`` extended one level down: after the
        in-pool chain ends, keep walking full blocks through the tiers,
        enqueueing a promotion per hit.  Returned blocks are referenced
        (pending destinations included) so nothing can be evicted out
        from under the caller; queued copy-ins land at the next
        ``flush_promotions``.  Never raises on pool pressure — a
        promotion that cannot get a destination block simply stops the
        chain (the tokens are recomputed instead)."""
        pool = self.pool
        bids, n = self.prefix.match(prompt, pool)
        bs = self.prefix.block_size
        while n + bs < len(prompt):
            key = tuple(prompt[:n + bs])
            dst = self._pending_by_key.get(key)
            if dst is not None:      # another row already queued this key
                pool.incref(dst)
                pool.stats.prefix_hits += 1
                bids.append(dst)
                n += bs
                continue
            entry, level = self._lookup(key)
            if entry is None:
                break
            try:
                dst = pool.alloc(1, hint_blocks=bids)[0]
            except RuntimeError:
                break                # no room to promote: recompute
            pool.content[dst] = entry.content
            self._pending.append((dst, entry, level))
            self._pending_by_key[key] = dst
            pool.stats.prefix_hits += 1
            bids.append(dst)
            n += bs
        return bids, n

    @property
    def pending(self) -> int:
        """Queued promotions awaiting ``flush_promotions``."""
        return len(self._pending)

    def flush_promotions(self) -> list[int]:
        """Drain the lookahead queue as one batched copy-in, MARS-ordered
        by destination row group.  Returns the destination block ids in
        copy order (the write stream the benches replay through the DRAM
        model).  Promoted blocks are marked dirty — the owning backend's
        staged mirror re-uploads them before the next decode step — and
        their prefixes register in the cache.  Tier entries stay resident
        (inclusive cache: a later eviction of the promoted block is a
        free clean-drop)."""
        if not self._pending:
            return []
        pend, self._pending = self._pending, []
        self._pending_by_key.clear()
        pool, bpg = self.pool, self.pool.cfg.blocks_per_group
        order = promotion_order([row_group_of(d, bpg)
                                 for d, _, _ in pend]) \
            if self.reorder else range(len(pend))
        dsts: list[int] = []
        tier_bytes: dict[int, int] = {}
        for i in order:
            dst, entry, level = pend[i]
            if pool.k_pages is not None:
                # full-block copy-in through the sanctioned write path so
                # the dirty-staging contract marks dst for the mirror
                pool.write_kv(dst, 0, entry.k, entry.v)
            self.prefix.register(entry.key, dst, pool)
            tier_bytes[level] = tier_bytes.get(level, 0) + entry.nbytes
            self.stats.promotes += 1
            self.stats.promoted_tokens += len(entry.content)
            self.stats.refetched_bytes += entry.nbytes
            dsts.append(dst)
            if self.obs is not None:
                self.obs.trace.event("tier.promote", key=_key_tag(entry.key),
                                     shard=self.obs_shard, dst=dst,
                                     tier=self.tiers[level].spec.name)
        stall = sum(self.tiers[lv].spec.fetch_us(nb)
                    for lv, nb in tier_bytes.items())
        self.stats.stall_us += stall
        if self.obs is not None:
            self.obs.trace.event("tier.stall", shard=self.obs_shard,
                                 blocks=len(dsts),
                                 us=round(stall, 3))
            self.obs.observe_promotion(self.obs_shard,
                                       self.write_trace(dsts))
        self._publish()
        return dsts

    def cancel_promotions(self) -> None:
        """Forget the queue without copying (prefill rollback: the
        destination blocks are being released by the caller, the tier
        entries were never removed)."""
        self._pending.clear()
        self._pending_by_key.clear()

    @staticmethod
    def write_trace(dsts: Sequence[int], chunk_lines: int = 8,
                    queue_depth: int = 4) -> np.ndarray:
        """64B-line write addresses of a promotion copy-in stream — the
        operand ``core/dram.simulate`` (and the live promotion open-row
        model) replays.

        Models the copy engine rather than an idealized memcpy: each
        destination block is one DMA descriptor issued in
        ``chunk_lines``-line bursts, with ``queue_depth`` descriptors in
        flight and the bus round-robining among them (how multi-queue
        DMA engines actually merge).  That makes the *submission order*
        — the thing ``flush_promotions`` reorders — decide bank/row
        behavior: a MARS-ordered queue keeps the in-flight set inside
        one destination row group (distinct banks, one open row each),
        while arrival order mixes groups and thrashes the shared banks.
        """
        if not len(dsts):
            return np.zeros(0, np.int64)
        queue = [[int(d) * LINES_PER_BLOCK, LINES_PER_BLOCK]
                 for d in dsts]
        inflight: list[list[int]] = []
        out: list[np.ndarray] = []
        i = 0
        while inflight or i < len(queue):
            while len(inflight) < queue_depth and i < len(queue):
                inflight.append(queue[i])
                i += 1
            d = inflight.pop(0)
            n = min(chunk_lines, d[1])
            out.append(np.arange(d[0], d[0] + n, dtype=np.int64))
            d[0] += n
            d[1] -= n
            if d[1]:
                inflight.append(d)
        return np.concatenate(out)

    # -- cost-aware eviction -------------------------------------------------

    def evict_cost(self, bid: int) -> float:
        """Re-acquisition cost (microseconds) of evicting ``bid`` now:
        ~0 when a clean copy already sits in a tier, the first tier's
        fetch cost when demotion would keep it refetchable, the causal
        recompute cost (prefix depth x prefill cost) when the cascade
        would drop it."""
        key = self.prefix._by_bid.get(bid)
        if key is None:
            return 0.0               # unregistered: nothing to refetch
        if any(t.holds(key) for t in self.tiers):
            return 0.0               # clean copy below: drop is free
        nbytes = 0
        if self.pool.k_pages is not None:
            nbytes = self.pool.k_pages[:, bid].nbytes * 2
        cap = sum(max(t.spec.capacity_blocks, 0) for t in self.tiers)
        held = sum(len(t) for t in self.tiers)
        if any(t.spec.capacity_blocks <= 0 for t in self.tiers) \
                or held < cap:
            return self.tiers[0].spec.fetch_us(nbytes)
        return len(key) * PREFILL_US_PER_TOKEN

    # -- telemetry / invariants ----------------------------------------------

    def _publish(self) -> None:
        if self.obs is None:
            return
        reg = self.obs.registry
        for t in self.tiers:
            stem = f"tier.shard{self.obs_shard}.{t.spec.name}"
            reg.set(f"{stem}.blocks", len(t))
            reg.set(f"{stem}.occupancy", t.occupancy)

    def check(self) -> None:
        """Tier-layer ground truth (the tests' sweep):
        pending destinations are live and mutually consistent, no key is
        resident in two tiers, every tier respects its capacity."""
        pool = self.pool
        assert len(self._pending) == len(self._pending_by_key)
        for dst, entry, level in self._pending:
            assert pool.used[dst] and pool.refcount[dst] >= 1, dst
            assert self._pending_by_key[entry.key] == dst
            assert 0 <= level < len(self.tiers)
        seen: set[tuple] = set()
        for t in self.tiers:
            keys = set(t._entries)
            assert not (keys & seen), "key resident in two tiers"
            seen |= keys
            cap = t.spec.capacity_blocks
            assert cap <= 0 or len(t) <= cap, (t.spec.name, len(t), cap)
            for key, e in t._entries.items():
                assert e.key == key
                assert len(e.content) == pool.cfg.block_size
