"""Unified KV-backend API: dense and paged serving caches, one interface.

The model (``models.lm``) speaks to its KV storage only through
``KVBackend``: ``prefill`` runs a prompt batch and stores every layer's
K/V, ``decode_step`` advances every lane one token.  Two implementations:

  DenseBackend   wraps the concrete per-layer ``lm.Cache`` pytree — the
                 training/dry-run storage.  Reads of ``.k``/``.v``/
                 ``.length`` forward to the cache, so code written against
                 the old concrete-Cache API keeps working.
  PagedBackend   per-sequence block tables over a layered ``BlockPool``
                 (one block id addresses a token-chunk's KV for *every*
                 layer — a single MARS placement decision co-locates a
                 token's per-layer blocks in one DRAM row group).  Supports
                 ragged continuous-batching decode, prefix sharing and
                 copy-on-write forks, and is what ``serve.engine`` drives.
                 Hybrid (attention + SSM) families keep their per-sequence
                 SSM/conv decode state host-side next to the block tables
                 (forked with the sequence, freed with it).

A third implementation scales the paged path across a device mesh:
``ShardedPagedBackend`` drives a ``kvcache.sharded_pool
.ShardedBlockPool`` with one complete ``PagedBackend`` per shard (own
pool, prefix cache, device mirror, optionally own mesh device) — the
kernel runs per shard over shard-local page tables, sequences never span
shards, and the scheduler routes admissions so shared prefixes co-locate.

Decode through the paged backend has two modes (``decode_mode``):

  "kernel"   the default: ``lm.paged_decode_step`` reads each layer's KV
             straight from the pool's layered page buffers via the Pallas
             ``paged_attention`` kernel (online-softmax merge of the
             in-flight token) — the MARS placement decisions *are* the
             kernel's page-walk addresses, nothing is flattened first.
             Sliding-window configs run natively: the scan flips the
             kernel's window mask per layer (``global_every`` hybrids
             keep their global layers unmasked).
  "gather"   the fallback/oracle: gather each lane's pages into a dense
             per-layer view and run the *same* ``lm.dense_decode_step``
             math as the dense backend, so gather-path logits agree with
             the dense backend bit-for-bit.

Either way the new token's K/V is extracted from the step and written
back into the pool host-side after attention (the pool mutates in place,
exactly like the single-layer engine of PR 1), so the kernel never reads
a partially-written page.  The pool buffers are staged to device through
double-buffered mirrors that re-upload only the blocks dirtied since the
slot was last staged (``BlockPool.drain_dirty``) — never the whole pool
per token.

Decode is a split-phase pipeline (MARS's lookahead buffer applied to the
serving loop — enough in-flight work ahead of the memory system to
overlap data movement with compute):

    step = backend.dispatch_decode(params, tokens, sids=...)  # launch
    logits = backend.sync(step)        # block on logits only
    ...                                # sample / emit while KV is in flight
    backend.flush()                    # commit the deferred KV write-back

``dispatch_decode`` launches the jitted step (jax dispatches
asynchronously) against a freshly staged mirror slot and returns a
``DecodeStep`` handle; ``sync`` blocks on the logits and starts the
non-blocking device→host copy of the new K/V; ``commit`` (normally via
``flush`` or the next ``dispatch_decode``) appends that K/V to the pool
one step late.  Every path that could observe or allocate pool state —
``new_seq``/``prefill``, ``fork_seq``, ``free_seq``, ``release`` —
flushes first, so a dispatched step's capacity precheck stays valid
until its commit and CoW forks always see committed KV.  ``decode`` /
``decode_step`` remain as thin compatibility wrappers (dispatch + sync
+ commit) for call sites that want the old synchronous semantics.

A released backend (``release()``) drains any pending deferred
write-back (no dirty block is dropped at shutdown), then raises a clear
"backend released" error from every serving entry point instead of an
opaque NoneType / KeyError; build a new backend to serve again.

Construction goes through ``make_backend`` — the single documented
entry point (``decode_mode`` / ``kernel_interpret`` / ``tiered`` /
``shards`` / ``device`` keyword surface).  Passing a pool positionally
to ``PagedBackend``/``ShardedPagedBackend`` is deprecated; pass
``pool=``.  Adding a backend: implement the protocol against
``lm.prefill_parts`` (storage-agnostic prompt run) and
``lm.dense_decode_step`` (ragged one-token step), register a
constructor in ``make_backend``.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, Optional, Protocol, Sequence, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache.pool import BlockPool, PoolConfig
from repro.kvcache.prefix import BlockTable, PrefixCache
from repro.models.config import ModelConfig


@dataclasses.dataclass
class DecodeStep:
    """Handle for one in-flight decode step.

    ``dispatch_decode`` returns one; ``sync(step)`` fills ``logits`` and
    flips ``synced``; ``commit(step)`` (or ``flush()``, or the next
    ``dispatch_decode``) lands the deferred KV write-back and flips
    ``committed``.  ``dev`` holds the backend's in-flight device futures
    (logits, new K/V, hybrid state) and ``parts`` the per-shard inner
    steps of a sharded dispatch — both backend-internal.
    """
    index: int                       # per-backend dispatch counter
    sids: list                       # sequences this step advances
    tokens: list                     # tokens[i] fed to sids[i]
    staged: int = 0                  # mirror blocks staged at dispatch
    synced: bool = False
    committed: bool = False
    batch_api: bool = False          # dispatched via the (B, 1) batch API
    logits: Any = None               # host logits after sync
    dev: dict = dataclasses.field(default_factory=dict)
    seqs: Optional[list] = None      # resolved _PagedSeq refs (plain)
    on_alloc: Optional[Callable[[int, int], None]] = None
    parts: Optional[list] = None     # sharded: (shard, inner step, idxs)


@runtime_checkable
class KVBackend(Protocol):
    """What the model needs from its KV storage — nothing more.

    Decode is split-phase: ``dispatch_decode`` → ``sync`` → ``commit``
    with a ``DecodeStep`` handle (``flush()`` is the sync+commit
    barrier); ``decode_step`` remains the synchronous compatibility
    wrapper over the three phases.
    """

    cfg: ModelConfig

    def prefill(self, params, tokens, frontend_emb=None):
        """Run a prompt batch and store every layer's K/V.

        Args:
          params: the model parameter tree (``lm.init(cfg).params``).
          tokens: (B, S) int32 prompt batch; replaces any lanes a prior
            ``prefill`` stored (the batch-level API serves one fixed
            batch at a time).
          frontend_emb: precomputed modality embeddings for families with
            frontends; backends that hold no frontend state reject it.
        Returns:
          last-position logits, shape (B, 1, V).
        Invariant: after the call ``lengths[b] == S`` for every lane.
        """
        ...

    def decode_step(self, params, tokens):
        """Advance every prefill lane one token.

        Compatibility wrapper: equivalent to ``dispatch_decode`` +
        ``sync`` + ``commit`` in one synchronous call.

        Args:
          params: the model parameter tree.
          tokens: (B, 1) int32 — lane ``b``'s next input token.
        Returns:
          next-token logits, shape (B, 1, V).
        Invariant: each call appends exactly one cached position per lane
        (``lengths`` increases by 1 elementwise); must follow ``prefill``.
        """
        ...

    def dispatch_decode(self, params, tokens, *, sids=None,
                        on_alloc=None) -> DecodeStep:
        """Launch one decode step without blocking on its results.

        Commits any pending prior step first (the one-step-deferred
        write-back), prechecks pool capacity so the eventual commit
        cannot fail, stages the dirty-block mirror, and dispatches the
        jitted step.  ``sids=None`` advances the ``prefill`` batch lanes
        (``tokens`` is the (B, 1) batch); paged backends also take the
        sequence-level form (``sids`` + per-sid token list).  At most
        one step may be in flight (dispatched, un-synced) per backend.
        Returns the ``DecodeStep`` handle to pass to ``sync``/``commit``.
        """
        ...

    def sync(self, step: DecodeStep):
        """Block on a dispatched step's logits (KV write-back stays
        deferred; the device→host KV copy starts here, non-blocking).
        Idempotent — a synced step returns its stored logits.  Returns
        float32 (len(sids), V) row-aligned to sids, or (B, 1, V) for a
        batch-API step."""
        ...

    def commit(self, step: Optional[DecodeStep] = None) -> None:
        """Land the pending synced step's KV write-back into the pool
        (host-side ``table.extend`` per lane, ``on_alloc`` callbacks).
        ``step=None`` commits whatever is pending; a committed step is a
        no-op.  Normally driven by ``flush()`` or the next
        ``dispatch_decode`` — decode step N commits step N-1."""
        ...

    def flush(self) -> None:
        """Barrier: sync any in-flight step and commit any pending
        write-back.  Idempotent.  Required before anything that must see
        committed KV — parity checks, ``fork_seq``/``free_seq``/prefill
        (which call it themselves), and shutdown."""
        ...

    @property
    def lengths(self) -> np.ndarray:
        """Per-lane cached token counts, int32 (B,) — what a position
        index may address in the next ``decode_step``."""
        ...

    def release(self) -> None:
        """Drain any pending deferred write-back (an implicit ``flush``
        — no dirty block is dropped at shutdown), then drop all storage
        (paged: decref every block back to the pool — registered prefix
        blocks stay evictable, private ones free).  Idempotence is not
        promised; every subsequent entry point raises a clear "backend
        released" ``RuntimeError``."""
        ...


# ---------------------------------------------------------------------------
# Dense backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _dense_decode(params, cfg, tokens, cache):
    from repro.models import lm
    return lm.dense_decode_step(params, cfg, tokens, cache)


class DenseBackend:
    """The old concrete ``lm.Cache`` behind the backend interface."""

    def __init__(self, cfg: ModelConfig, batch: int, max_seq: int,
                 enc_len: int = 0):
        from repro.models import lm
        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self._cache = lm.init_dense_cache(cfg, batch, max_seq, enc_len)
        self._steps = 0

    def _check_released(self) -> None:
        if self._cache is None:
            raise RuntimeError(
                "DenseBackend released: release() dropped the cache "
                "storage; build a new backend to serve again")

    # -- backend API --------------------------------------------------------

    def prefill(self, params, tokens, frontend_emb=None):
        """Dense prompt run: builds a fresh ``lm.Cache`` sized ``max_seq``
        and fills positions [0, S).  tokens: (B, S) int32 with
        B == ``self.batch``.  Returns last-position logits (B, 1, V)."""
        from repro.models import lm
        self._check_released()
        logits, self._cache = lm.dense_prefill(
            params, self.cfg, tokens, self.max_seq, frontend_emb)
        return logits

    def decode_step(self, params, tokens):
        """One dense decode step at slot ``length`` (jitted; the cache
        pytree is threaded functionally).  tokens: (B, 1) int32.
        Returns next-token logits (B, 1, V).  Compatibility wrapper over
        the split-phase lifecycle."""
        step = self.dispatch_decode(params, tokens)
        logits = self.sync(step)
        self.commit(step)
        return logits

    # -- split-phase decode lifecycle ----------------------------------------
    # The dense cache is updated functionally inside the jitted step, so
    # "dispatch" already carries the write-back: sync marks the step
    # committed and commit/flush are no-ops (no deferred state exists).

    def dispatch_decode(self, params, tokens, *, sids=None,
                        on_alloc=None) -> DecodeStep:
        """Launch one dense decode step (jax dispatches asynchronously;
        nothing blocks until ``sync``).  The dense backend has no
        sequence-level lanes: ``sids`` must be None."""
        self._check_released()
        if sids is not None:
            raise ValueError("DenseBackend has no sequence-level lanes; "
                             "dispatch with sids=None (the (B, 1) batch)")
        logits, self._cache = _dense_decode(params, self.cfg, tokens,
                                            self._cache)
        step = DecodeStep(index=self._steps, sids=[], tokens=[],
                          batch_api=True)
        step.dev["logits"] = logits
        self._steps += 1
        return step

    def sync(self, step: DecodeStep):
        """Return the step's (B, 1, V) logits (blocking happens when the
        caller materializes them).  The dense write-back landed inside
        the jitted step, so the step is committed here too."""
        if not step.synced:
            step.logits = step.dev.pop("logits")
            step.synced = step.committed = True
        return step.logits

    def commit(self, step: Optional[DecodeStep] = None) -> None:
        """No deferred write-back exists on the dense path."""

    def flush(self) -> None:
        """No-op barrier (nothing is ever pending); raises once
        released, like every other entry point."""
        self._check_released()

    @property
    def inflight_steps(self) -> int:
        """Dispatched-or-pending step count — always 0: the dense cache
        commits inside the jitted step."""
        return 0

    @property
    def lengths(self) -> np.ndarray:
        """(B,) int32 — the dense cache keeps one shared scalar length
        (all lanes advance in lockstep), broadcast to per-lane form."""
        self._check_released()
        ln = np.asarray(self._cache.length, np.int32)
        return np.broadcast_to(np.atleast_1d(ln), (self.batch,)).copy()

    def release(self) -> None:
        """Drop the cache pytree; later reads raise "backend released"."""
        self._cache = None

    # -- concrete-Cache compatibility reads ---------------------------------

    @property
    def cache(self):
        return self._cache

    def __getattr__(self, name):
        # k / v / ssm / conv / xk / xv / length forwarded to the pytree
        if name in ("k", "v", "ssm", "conv", "xk", "xv", "length"):
            if self.__dict__.get("_cache") is None:
                raise RuntimeError(
                    f"DenseBackend released: cannot read .{name} after "
                    "release(); build a new backend to serve again")
            if name in ("k", "v"):
                # legacy concrete-Cache reads; removal note in README
                warnings.warn(
                    f"DenseBackend.{name} is a deprecated concrete-Cache "
                    f"compatibility read; use backend.cache.{name} "
                    "(scheduled for removal — see README)",
                    DeprecationWarning, stacklevel=2)
            return getattr(self._cache, name)
        raise AttributeError(name)


# ---------------------------------------------------------------------------
# Paged backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _paged_decode(params, cfg, tokens, k_pages, v_pages, page_tables,
                  lengths, ssm, conv):
    """Gather each lane's pages into a dense per-layer view, run the ragged
    dense decode step, and extract the new token's K/V for write-back.

    k/v_pages: (L, P, page, K, dh); page_tables: (B, n_pages) int32;
    lengths: (B,) int32 — the padded view always has room for slot
    ``lengths[b]`` (the backend pads the table before calling).
    ssm/conv: hybrid side state (L, B, H, P, N) / (L, B, k-1, ch), or
    None for attention-only families.
    Returns (logits, k_new (L, B, 1, K, dh), v_new, ssm_new, conv_new).
    """
    from repro.models import lm
    L = k_pages.shape[0]
    K, dh = k_pages.shape[-2:]
    B = tokens.shape[0]
    k = k_pages[:, page_tables].reshape(L, B, -1, K, dh)
    v = v_pages[:, page_tables].reshape(L, B, -1, K, dh)
    cache = lm.Cache(k=k, v=v, ssm=ssm, conv=conv, xk=None, xv=None,
                     length=lengths)
    logits, new = lm.dense_decode_step(params, cfg, tokens, cache)
    idx = lengths.astype(jnp.int32)[None, :, None, None, None]
    k_new = jnp.take_along_axis(new.k, idx, axis=2)
    v_new = jnp.take_along_axis(new.v, idx, axis=2)
    return logits, k_new, v_new, new.ssm, new.conv


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def _paged_decode_kernel(params, cfg, tokens, k_pages, v_pages,
                         page_tables, lengths, ssm, conv, interpret=True):
    """Kernel-path decode: per-layer Pallas paged attention straight over
    the pool's layered page buffers (no dense gather).  Same operand and
    result shapes as ``_paged_decode``."""
    from repro.models import lm
    return lm.paged_decode_step(params, cfg, tokens, k_pages, v_pages,
                                page_tables, lengths, ssm_state=ssm,
                                conv_state=conv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_prefill_parts(params, cfg, tokens):
    from repro.models import lm
    return lm.prefill_parts(params, cfg, tokens)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks(dev, idx, vals):
    """Write dirty block planes into the device mirror.  The mirror is
    donated so XLA updates it in place — no pool-sized device copy per
    step.  ``idx`` may repeat (pow2 padding); duplicate indices write the
    same value twice, harmlessly."""
    return dev.at[:, idx].set(vals)


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class _PagedSeq:
    sid: int
    table: BlockTable
    tokens: list            # tokens whose KV is cached
    # hybrid side state the pool cannot hold: per-sequence SSM recurrent
    # state (L, H, P, N) float32 and conv trailing context (L, k-1, ch),
    # host-side, forked with the sequence, freed with it
    ssm: Optional[np.ndarray] = None
    conv: Optional[np.ndarray] = None


class PagedBackend:
    """Per-sequence block tables over a layered ``BlockPool``.

    Sequence-level API (what the serve engine drives): ``new_seq`` /
    ``fork_seq`` / ``decode`` / ``free_seq``.  The batch-level
    ``KVBackend`` API (``prefill`` / ``decode_step``) runs the same
    machinery over a fixed batch, giving drop-in parity with
    ``DenseBackend``.

    Prompt K/V is always recomputed (prefill logits need the full
    context); prefix sharing is at the *storage* level — matched blocks
    are referenced instead of re-allocated, which is what bounds pool
    occupancy under hot prefixes.
    """

    def __init__(self, cfg: ModelConfig, *_legacy_pool,
                 pool: Optional[BlockPool] = None,
                 num_blocks: int = 256, block_size: int = 16,
                 placement: str = "mars", eviction: str = "fifo",
                 share_prefixes: bool = True, decode_mode: str = "kernel",
                 kernel_interpret: bool = True, device=None,
                 tiered: bool = False, tier_specs=None):
        """Build a paged backend over ``pool`` (or a fresh pool sized by
        ``num_blocks``/``block_size`` matching the model config).
        Prefer ``make_backend(cfg, "paged", ...)`` — the one documented
        construction surface.

        Args:
          cfg: model config; must be an attention-bearing decoder-only
            family (encoder-decoder / VLM state is not paged yet).
          pool: existing layered ``BlockPool`` to share; its KV buffer
            shape must match ``cfg`` (asserted).  Keyword-only in
            spirit: passing it positionally is deprecated.
          placement/eviction: pool policies when building a fresh pool
            ("cost" eviction pairs naturally with ``tiered``: the tier
            manager installs its recompute-vs-refetch scoring hook).
          share_prefixes: storage-level prefix sharing via ``PrefixCache``.
          decode_mode: "kernel" (Pallas paged_attention per layer, the
            default) or "gather" (dense-view oracle).
          kernel_interpret: run the Pallas kernel in interpret mode
            (CPU/CI); pass False on real TPU.
          device: jax device the staged KV mirror and decode operands are
            committed to; ``None`` uses the default device.  A mesh-
            sharded deployment (``ShardedPagedBackend``) gives each
            shard's backend its own device.
          tiered: put host/mock-remote spill tiers behind the pool
            (``kvcache.tiers.TierManager``): eviction demotes registered
            prefix blocks instead of dropping them, and prefix misses
            that hit a lower tier promote blocks back through a
            MARS-reordered batched copy-in.  Requires prefix sharing.
          tier_specs: ``TierSpec`` sequence overriding
            ``tiers.default_tiers`` (capacity / latency / bandwidth).
        """
        if _legacy_pool:
            if len(_legacy_pool) > 1 or pool is not None:
                raise TypeError("PagedBackend takes at most one pool")
            warnings.warn(
                "passing the pool positionally to PagedBackend is "
                "deprecated; pass pool= by keyword (or use make_backend)",
                DeprecationWarning, stacklevel=2)
            pool = _legacy_pool[0]
        if not cfg.has_attention or cfg.enc_layers \
                or cfg.family in ("encdec", "vlm"):
            raise NotImplementedError(
                f"PagedBackend pages attention KV plus per-sequence "
                f"SSM/conv decode state; family {cfg.family!r} needs "
                f"state the pool does not hold yet (encoder KV / "
                f"frontend prefixes, or has no attention KV at all)")
        if decode_mode not in ("kernel", "gather"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        self.decode_mode = decode_mode
        self.kernel_interpret = kernel_interpret
        self.device = device
        self.cfg = cfg
        if pool is None:
            pool = BlockPool(PoolConfig(
                num_blocks=num_blocks, block_size=block_size,
                placement=placement, eviction=eviction,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.d_head,
                n_layers=cfg.n_layers, dtype=str(cfg.kvdtype)))
        assert pool.k_pages is not None, "paged backend needs a KV pool"
        assert pool.cfg.n_layers == cfg.n_layers \
            and pool.cfg.n_kv_heads == cfg.n_kv_heads \
            and pool.cfg.head_dim == cfg.d_head, \
            "pool KV buffer does not match the model config"
        self.pool = pool
        self.prefix = PrefixCache(pool.cfg.block_size)
        if share_prefixes:
            self.prefix.attach(pool)
        self.share_prefixes = share_prefixes
        # tiered KV memory: demote-on-evict / promote-on-miss behind the
        # pool (kvcache.tiers).  The manager interposes on pool.on_evict
        # AFTER prefix.attach so demotion captures the payload before
        # the prefix cache unregisters the block.
        self.tiers = None
        if tiered:
            assert share_prefixes, \
                "tiered KV spills registered prefix blocks; enable " \
                "share_prefixes"
            from repro.kvcache.tiers import TierManager
            self.tiers = TierManager(pool, self.prefix, tier_specs)
        self._seqs: dict[int, _PagedSeq] = {}
        self._next_sid = 0
        self._batch: list[int] = []      # batch-level API lane order
        self._released = False
        # telemetry (obs.Observer.attach): spans + the live row-locality
        # feed; obs_shard tags events with this backend's shard index
        self.obs = None
        self.obs_shard = 0
        # double-buffered device mirrors of the pool's KV buffers: two
        # (k, v) slots, swapped every stage, each with its own pending-
        # dirty set (both fed from pool.drain_dirty — this backend is the
        # pool's single drain_dirty consumer).  Staging slot A writes
        # only blocks dirtied since A was last staged, and can overlap
        # the kernel still reading slot B.
        self._mirrors: list = [None, None]
        self._slot_dirty: list = [set(), set()]
        self._slot = 0                   # slot the next stage writes
        self._staged_slot: Optional[int] = None  # slot staged last
        self.staged_blocks_last_step = 0
        # split-phase decode pipeline: at most one dispatched-un-synced
        # step (_inflight) and one synced-un-committed step (_pending)
        self._inflight: Optional[DecodeStep] = None
        self._pending: Optional[DecodeStep] = None
        self._steps = 0

    def _check_released(self) -> None:
        if self._released:
            raise RuntimeError(
                "PagedBackend released: release() returned every block "
                "to the pool; build a new backend to serve again")

    # -- device staging ------------------------------------------------------

    def _put(self, x):
        """Commit an operand to this backend's device (default device when
        unset) — per-shard backends keep their mirrors and decode inputs
        on their own mesh device."""
        a = jnp.asarray(x)
        return a if self.device is None else jax.device_put(a, self.device)

    def _staged_pages(self):
        """Stage the pool's host-mutated KV buffers into the next mirror
        slot, uploading only blocks written since *that slot* was last
        staged (both slots are built with a full upload the first time).
        Alternating slots lets this scatter overlap a kernel still
        reading the other slot, and the donated scatter keeps it free of
        pool-sized copies.  ``staged_blocks_last_step`` records how many
        blocks moved — steady-state that is the union of the last two
        steps' dirty sets (one step per slot).  Returns the freshly
        staged ``(k, v)`` device pair."""
        pool = self.pool
        if self._mirrors[0] is None:
            pool.drain_dirty()           # full upload covers everything
            for s in (0, 1):
                self._mirrors[s] = (self._put(pool.k_pages),
                                    self._put(pool.v_pages))
                self._slot_dirty[s].clear()
            self.staged_blocks_last_step = pool.cfg.num_blocks
            self._staged_slot, self._slot = 0, 1
        else:
            fresh = pool.drain_dirty()
            self._slot_dirty[0].update(fresh)
            self._slot_dirty[1].update(fresh)
            s = self._slot
            pend = sorted(self._slot_dirty[s])
            self.staged_blocks_last_step = len(pend)
            if pend:
                # pad the id list to a power of two (repeating the last
                # id) so the donated scatter compiles O(log) variants
                pad = pend + [pend[-1]] * (_pow2(len(pend)) - len(pend))
                idx = self._put(np.asarray(pad, np.int32))
                k, v = self._mirrors[s]
                self._mirrors[s] = (
                    _scatter_blocks(k, idx, self._put(pool.k_pages[:, pad])),
                    _scatter_blocks(v, idx, self._put(pool.v_pages[:, pad])))
            self._slot_dirty[s].clear()
            self._staged_slot, self._slot = s, 1 - s
        if self.obs is not None:
            self.obs.trace.event("backend.stage", shard=self.obs_shard,
                                 blocks=self.staged_blocks_last_step,
                                 slot=self._staged_slot)
        return self._mirrors[self._staged_slot]

    @property
    def _k_dev(self):
        """K plane of the most recently staged mirror slot (None before
        the first stage) — the buffer the next kernel launch reads."""
        return None if self._staged_slot is None \
            else self._mirrors[self._staged_slot][0]

    @property
    def _v_dev(self):
        return None if self._staged_slot is None \
            else self._mirrors[self._staged_slot][1]

    # -- sequence-level API (continuous batching) ---------------------------

    def new_seq(self, params, prompt: Sequence[int],
                on_alloc: Optional[Callable[[int, int], None]] = None
                ) -> tuple[int, Any, int]:
        """Prefill one sequence into the pool.

        Args:
          params: model parameter tree.
          prompt: token ids; the prompt's full-block prefix is matched
            against the prefix cache first (matched blocks are referenced,
            not re-stored).
          on_alloc: callback ``(sid, n_fresh_blocks)`` fired once with the
            number of blocks this prefill actually allocated (the engine
            converts admission reservations into claims with it).
        Returns:
          (sid, last-position logits (V,) float32, shared-prefix tokens).
        Invariant: atomic under pool exhaustion — on RuntimeError nothing
        stays live (see ``_add_seqs``).
        """
        logits, sids, shared = self._add_seqs(
            params, np.asarray([list(prompt)], np.int32), on_alloc)
        return sids[0], logits[0], shared[0]

    def _add_seqs(self, params, tokens: np.ndarray,
                  on_alloc=None) -> tuple[Any, list[int], list[int]]:
        """Batched prompt prefill -> one new sequence per row.

        Atomic under pool exhaustion: if any row's ``table.extend``
        raises, the partial table (prefix-matched increfed blocks plus
        blocks allocated before the failure) is decref'd back and rows
        already added by this call are freed, then the error re-raises —
        nothing stays live.
        """
        self._check_released()
        # flush barrier: prefill allocates, and the prefix match reads
        # refcounts/tokens — both must see the deferred step committed
        # (this is also what keeps a dispatched step's capacity precheck
        # valid until its own commit)
        self.flush()
        if self.obs is not None:
            with self.obs.trace.span("backend.prefill",
                                     shard=self.obs_shard,
                                     rows=int(tokens.shape[0])) as sp:
                out = self._add_seqs_impl(params, tokens, on_alloc)
                sp["shared_tokens"] = int(sum(out[2]))
                return out
        return self._add_seqs_impl(params, tokens, on_alloc)

    def _add_seqs_impl(self, params, tokens: np.ndarray,
                       on_alloc=None) -> tuple[Any, list[int], list[int]]:
        B, S = tokens.shape
        logits, parts = _jit_prefill_parts(
            params, self.cfg, jnp.asarray(tokens, jnp.int32))
        kvd = self.cfg.kvdtype
        k_all = np.asarray(parts["k"].astype(kvd))   # (L, B, S, K, dh)
        v_all = np.asarray(parts["v"].astype(kvd))
        ssm_all = conv_all = None
        if self.cfg.has_ssm:
            ssm_all = np.asarray(parts["ssm"], np.float32)
            conv_all = np.asarray(parts["conv"])
        sids, shared = [], []
        for b in range(B):
            prompt = [int(t) for t in tokens[b]]
            if not self.share_prefixes:
                bids, n = [], 0
            elif self.tiers is not None:
                # tier-aware match: in-pool chain first, then promotable
                # lower-tier blocks — copy-ins queue in the manager's
                # lookahead buffer and land batched (flushed below)
                bids, n = self.tiers.match(prompt)
            else:
                bids, n = self.prefix.match(prompt, self.pool)
            table = BlockTable(list(bids), n)
            allocs0 = self.pool.stats.allocs
            try:
                table.extend(
                    self.pool, prompt[n:], seq_tokens=prompt,
                    cache=self.prefix if self.share_prefixes else None,
                    kv=(k_all[:, b, n:], v_all[:, b, n:]))
            except RuntimeError:
                # roll back: queued promotions first (their destination
                # blocks are released with the tables below; the tier
                # entries were never removed), then this row's partial
                # table (registered blocks stay as evictable cache,
                # private ones free), then the rows this call already
                # created — batched prefill is all-or-nothing
                if self.tiers is not None:
                    self.tiers.cancel_promotions()
                self.prefix.release(table, self.pool)
                for sid in sids:
                    self.free_seq(sid)
                raise
            sid = self._next_sid
            self._next_sid += 1
            seq = _PagedSeq(sid, table, list(prompt))
            if ssm_all is not None:
                seq.ssm = np.ascontiguousarray(ssm_all[:, b])
                seq.conv = np.ascontiguousarray(conv_all[:, b])
            self._seqs[sid] = seq
            if on_alloc is not None:
                on_alloc(sid, self.pool.stats.allocs - allocs0)
            sids.append(sid)
            shared.append(n)
        if self.tiers is not None:
            # the whole batch's promotions land in one MARS-reordered
            # copy-in; the dirtied blocks re-stage to the device mirror
            # before the next decode step touches them
            self.tiers.flush_promotions()
        return np.asarray(logits[:, 0], np.float32), sids, shared

    def fork_seq(self, sid: int) -> int:
        """Fork a sequence, sharing every block (CoW on first append);
        the hybrid side state is copied — it is mutated every step.
        Forces a flush barrier first: the fork's CoW bookkeeping (and
        its copied SSM/conv state) must see committed KV, not a step
        still in flight."""
        self._check_released()
        self.flush()
        src = self._seqs[sid]
        nsid = self._next_sid
        self._next_sid += 1
        self._seqs[nsid] = _PagedSeq(
            nsid, src.table.fork(self.pool), list(src.tokens),
            ssm=None if src.ssm is None else src.ssm.copy(),
            conv=None if src.conv is None else src.conv.copy())
        return nsid

    # -- decode preemption (pause -> demote -> resume) -----------------------

    def pause_seq(self, sid: int) -> dict:
        """Preempt a live decode: flush the pipeline FIRST (the paused
        lane may still be owed a deferred write-back token — pausing
        mid-step would capture half a state), capture the sequence's
        full decode state host-side (cached tokens, every block's KV
        payload + content tag, hybrid ssm/conv), then release its
        blocks.  Registered prefix blocks stay resident as evictable
        cache — demotable to the spill tiers under pressure via the
        existing ``TierManager`` eviction hook — so a prompt resume
        usually re-matches them for free; private blocks free outright.

        Returns the opaque pause record ``resume_seq`` restores from.
        The captured payloads are verbatim pool bytes, which is what
        makes resumption bitwise: nothing is ever recomputed."""
        self._check_released()
        self.flush()
        if self.obs is not None:
            self.obs.trace.event("backend.pause", shard=self.obs_shard,
                                 sid=sid)
        seq = self._seqs.pop(sid)
        pool = self.pool
        blocks = []
        for bid in seq.table.blocks:
            blocks.append({
                "content": pool.content[bid],
                "k": np.array(pool.k_pages[:, bid]),
                "v": np.array(pool.v_pages[:, bid]),
            })
        rec = {
            "tokens": list(seq.tokens),
            "num_tokens": seq.table.num_tokens,
            "blocks": blocks,
            "ssm": None if seq.ssm is None else seq.ssm.copy(),
            "conv": None if seq.conv is None else seq.conv.copy(),
        }
        self.prefix.release(seq.table, pool)
        return rec

    def resume_seq(self, rec: dict,
                   on_alloc: Optional[Callable[[int, int], None]] = None
                   ) -> int:
        """Re-admit a paused sequence bitwise-identically under a new
        sid.  No prefill recompute anywhere: the leading blocks re-enter
        through the prefix cache and tiers (``match`` returns the SAME
        bytes — registration is exact-prefix keyed and tier demotion
        captured payloads verbatim), and whatever the caches no longer
        hold is restored from the pause record's captured pages with
        plain ``alloc`` + ``write_kv``.  Atomic under pool exhaustion:
        on RuntimeError every matched reference is released and nothing
        stays live."""
        self._check_released()
        self.flush()
        if self.obs is not None:
            self.obs.trace.event("backend.resume", shard=self.obs_shard,
                                 tokens=len(rec["tokens"]))
        pool = self.pool
        bs = pool.cfg.block_size
        tokens = list(rec["tokens"])
        num = rec["num_tokens"]
        if not self.share_prefixes:
            bids, n = [], 0
        elif self.tiers is not None:
            bids, n = self.tiers.match(tokens)
        else:
            bids, n = self.prefix.match(tokens, pool)
        # as in ``_add_seqs_impl``: the on_alloc claim counts only the
        # restore's own allocations (tier promotion destinations are the
        # tier manager's business, not the caller's reservation)
        allocs0 = pool.stats.allocs
        start = n // bs
        need = len(rec["blocks"]) - start
        try:
            if not pool.can_alloc(need):
                raise RuntimeError(
                    f"pool exhausted: resume needs {need} blocks, "
                    f"free {pool.num_free}, cached {pool.num_cached}")
            fresh = pool.alloc(need, hint_blocks=bids) if need else []
        except RuntimeError:
            if self.tiers is not None:
                self.tiers.cancel_promotions()
            self.prefix.release(BlockTable(list(bids), n), pool)
            raise
        for j, bid in enumerate(fresh):
            src = rec["blocks"][start + j]
            pool.content[bid] = src["content"]
            pool.write_kv(bid, 0, src["k"], src["v"])
            pool.touch(bid)
            end = (start + j + 1) * bs
            if self.share_prefixes and end <= num:
                self.prefix.register(tuple(tokens[:end]), bid, pool)
        if self.tiers is not None:
            self.tiers.flush_promotions()
        sid = self._next_sid
        self._next_sid += 1
        seq = _PagedSeq(sid, BlockTable(list(bids) + list(fresh), num),
                        tokens,
                        ssm=None if rec["ssm"] is None
                        else rec["ssm"].copy(),
                        conv=None if rec["conv"] is None
                        else rec["conv"].copy())
        self._seqs[sid] = seq
        if on_alloc is not None:
            on_alloc(sid, pool.stats.allocs - allocs0)
        return sid

    def decode(self, params, sids: Sequence[int], tokens: Sequence[int],
               on_alloc: Optional[Callable[[int, int], None]] = None):
        """One ragged decode step over live sequences — the synchronous
        compatibility wrapper: ``dispatch_decode`` + ``sync`` +
        ``commit`` in one call (KV is committed before it returns).

        Args:
          sids: sequences to advance (any subset of the live set, each at
            its own length).
          tokens: ``tokens[i]`` is fed to ``sids[i]``.
          on_alloc: per-sequence callback ``(sid, n_fresh_blocks)`` — a
            lane allocates at most one block per step (new tail or CoW).
        Returns:
          next-token logits, float32 (len(sids), V), row-aligned to sids.
        Invariants: the new K/V is written back host-side *after* the
        step (the kernel never reads a half-written page); a capacity
        precheck makes the step all-or-nothing — on "pool exhausted"
        every sequence is exactly as it was.
        """
        step = self.dispatch_decode(params, tokens, sids=sids,
                                    on_alloc=on_alloc)
        out = self.sync(step)
        self.commit(step)
        return out

    # -- split-phase decode lifecycle ----------------------------------------

    def dispatch_decode(self, params, tokens, *, sids=None,
                        on_alloc: Optional[Callable[[int, int], None]]
                        = None) -> DecodeStep:
        """Launch one ragged decode step without blocking.

        Commits the pending prior step first (decode step N lands step
        N-1's dirty blocks), prechecks capacity for *this* step, stages
        the next mirror slot, and dispatches the jitted step — jax
        queues the kernel and returns immediately, so the scatter and
        kernel execution overlap whatever the host does until ``sync``.

        The dispatch-time capacity precheck is sufficient for the
        deferred commit because every allocating path (``_add_seqs``,
        ``fork_seq``) and every refcount-changing path (``free_seq``)
        flushes first — between a dispatch and its commit the pool can
        only have gained capacity.

        ``sids=None`` dispatches the batch-API lanes (``tokens`` is the
        (B, 1) int32 batch); otherwise ``tokens[i]`` feeds ``sids[i]``.
        Raising ("pool exhausted", or a second dispatch while one step
        is in flight) leaves every sequence exactly as it was.
        """
        from repro.kernels.paged_attention import ops
        self._check_released()
        batch_api = sids is None
        if batch_api:
            sids = list(self._batch)
            tokens = [int(t) for t in np.asarray(tokens).reshape(-1)]
        assert sids, "no active sequences to decode (prefill first)"
        if self._inflight is not None:
            raise RuntimeError(
                "a decode step is already in flight; sync() it before "
                "dispatching the next")
        self._commit_pending()
        # tier contract: every queued promotion flushed (copy-in complete,
        # block dirtied for staging) before a promoted page can enter a
        # decode batch — prefill flushes per batch, so the queue must be
        # empty here
        assert self.tiers is None or self.tiers.pending == 0, \
            "unflushed tier promotions entering a decode batch"
        seqs = [self._seqs[s] for s in sids]
        B = len(seqs)
        page = self.pool.cfg.block_size
        # capacity precheck so the deferred write-back cannot die halfway
        # (rolling back a committed lane would mean undoing CoW/eviction
        # side effects): each lane needs at most one fresh block — a new
        # tail, or a CoW copy of a shared tail.  Raising here leaves
        # every sequence exactly as it was before the step.
        need = 0
        for s in seqs:
            fill = s.table.num_tokens % page
            if fill == 0 or \
                    self.pool.refcount[s.table.blocks[-1]] > 1:
                need += 1
        if not self.pool.can_alloc(need):
            raise RuntimeError(
                f"pool exhausted: decode step needs {need} blocks, "
                f"free {self.pool.num_free}, cached {self.pool.num_cached}")
        # padded operand pack: every lane needs room for its new slot on
        # the gather path (the kernel path attends the in-flight token
        # out of registers, but shares the padding so both compile alike)
        pt, lengths, toks = ops.decode_step_operands(
            [s.table for s in seqs], tokens, page)
        kp, vp = self._staged_pages()
        if self.obs is not None:
            # live row-locality: this step's page walk in kernel issue
            # order (sequence-major, page-contiguous — the MARS-reordered
            # stream; defined the same way on the gather path so the
            # gauge is mode-independent), fed to this shard's open-row
            # model
            self.obs.observe_kv_walk(
                self.obs_shard,
                ops.kv_read_trace_kernel([s.table for s in seqs],
                                         block_size=page))
        ssm = conv = None
        if self.cfg.has_ssm:
            # batch the per-sequence hybrid side state (padded lanes get
            # zeros; their outputs are discarded at sync)
            L = self.cfg.n_layers
            Bp = toks.shape[0]
            ssm_np = np.zeros((L, Bp) + seqs[0].ssm.shape[1:],
                              seqs[0].ssm.dtype)
            conv_np = np.zeros((L, Bp) + seqs[0].conv.shape[1:],
                               seqs[0].conv.dtype)
            for i, s in enumerate(seqs):
                ssm_np[:, i] = s.ssm
                conv_np[:, i] = s.conv
            ssm = self._put(ssm_np)
            conv = self._put(conv_np)
        if self.decode_mode == "kernel":
            logits, k_new, v_new, ssm_new, conv_new = _paged_decode_kernel(
                params, self.cfg, self._put(toks), kp, vp,
                self._put(pt), self._put(lengths), ssm, conv,
                interpret=self.kernel_interpret)
        else:
            logits, k_new, v_new, ssm_new, conv_new = _paged_decode(
                params, self.cfg, self._put(toks), kp, vp,
                self._put(pt), self._put(lengths), ssm, conv)
        step = DecodeStep(index=self._steps, sids=list(sids),
                          tokens=[int(t) for t in tokens],
                          staged=self.staged_blocks_last_step,
                          batch_api=batch_api, seqs=seqs,
                          on_alloc=on_alloc)
        step.dev.update(logits=logits, k=k_new, v=v_new,
                        ssm=ssm_new, conv=conv_new)
        self._steps += 1
        self._inflight = step
        if self.obs is not None:
            self.obs.trace.event("backend.dispatch", shard=self.obs_shard,
                                 step=step.index, lanes=B,
                                 staged=step.staged)
        return step

    def sync(self, step: DecodeStep):
        """Block on a dispatched step's logits.  The new K/V stays on
        device (its non-blocking device→host copy starts here); the
        write-back commits one step later.  Idempotent on a synced
        step.  Returns float32 (len(sids), V) row-aligned to the
        dispatched sids — or (B, 1, V) for a batch-API step."""
        self._check_released()
        if step.synced:
            return step.logits
        if step is not self._inflight:
            raise RuntimeError(
                "sync() of a step that is not in flight on this backend")
        B = len(step.sids)
        if self.obs is not None:
            # the span measures the blocking wait — dispatch-to-sync gap
            with self.obs.trace.span("backend.decode",
                                     shard=self.obs_shard,
                                     step=step.index, lanes=B) as sp:
                sp["staged"] = step.staged
                logits = np.asarray(step.dev.pop("logits"))
        else:
            logits = np.asarray(step.dev.pop("logits"))
        # logits landing means the step finished; start the KV transfer
        # for the deferred commit without blocking on it
        for name in ("k", "v", "ssm", "conv"):
            arr = step.dev.get(name)
            if arr is not None and hasattr(arr, "copy_to_host_async"):
                arr.copy_to_host_async()
        step.logits = np.asarray(logits[:B, 0], np.float32)
        if step.batch_api:
            step.logits = jnp.asarray(step.logits)[:, None, :]
        step.synced = True
        self._inflight = None
        self._pending = step
        return step.logits

    def commit(self, step: Optional[DecodeStep] = None) -> None:
        """Land the pending synced step's KV write-back (see
        ``_commit_pending``).  ``step=None`` commits whatever is
        pending; committing an already-committed step is a no-op;
        committing an un-synced step is an error."""
        self._check_released()
        if step is not None:
            if step.committed:
                return
            if step is not self._pending:
                raise RuntimeError(
                    "commit() of a step that is not pending on this "
                    "backend (sync() it first)")
        self._commit_pending()

    def _commit_pending(self) -> None:
        """The deferred write-back: append the pending step's new K/V to
        each lane's block table (CoW on shared tails), update hybrid
        side state, fire ``on_alloc``.  Cannot fail: capacity was
        prechecked at dispatch and every alloc/refcount path since has
        flushed first."""
        step = self._pending
        if step is None:
            return
        self._pending = None
        k_new = np.asarray(step.dev.pop("k"))   # (L, Bp, 1, K, dh)
        v_new = np.asarray(step.dev.pop("v"))
        ssm_new = step.dev.pop("ssm")
        conv_new = step.dev.pop("conv")
        if ssm_new is not None:
            ssm_new = np.asarray(ssm_new)       # (L, Bp, H, P, N)
            conv_new = np.asarray(conv_new)
        for i, (s, tok) in enumerate(zip(step.seqs, step.tokens)):
            allocs0 = self.pool.stats.allocs
            new_tokens = s.tokens + [int(tok)]
            s.table.extend(
                self.pool, [int(tok)], seq_tokens=new_tokens,
                cache=self.prefix if self.share_prefixes else None,
                kv=(k_new[:, i], v_new[:, i]))
            s.tokens = new_tokens     # commit only after the extend
            if ssm_new is not None:
                s.ssm = np.ascontiguousarray(ssm_new[:, i])
                s.conv = np.ascontiguousarray(conv_new[:, i])
            if step.on_alloc is not None:
                step.on_alloc(s.sid, self.pool.stats.allocs - allocs0)
        step.committed = True
        step.seqs = None
        if self.obs is not None:
            self.obs.trace.event("backend.commit", shard=self.obs_shard,
                                 step=step.index, lanes=len(step.sids))

    def flush(self) -> None:
        """Barrier: sync any in-flight step and commit any pending
        write-back.  Idempotent — flushing twice (or with nothing
        outstanding) is a no-op.  ``release()`` drains through here, so
        a released backend never holds pending work; flushing after
        release raises like every other entry point."""
        self._check_released()
        if self._inflight is not None:
            self.sync(self._inflight)
        self._commit_pending()

    @property
    def inflight_steps(self) -> int:
        """Steps between dispatch and commit: 0 (drained), 1 (one step
        dispatched or pending), or 2 (one in flight + one pending)."""
        return int(self._inflight is not None) + \
            int(self._pending is not None)

    def free_seq(self, sid: int) -> None:
        """Finished sequence: registered prefix blocks stay evictable;
        the hybrid side state dies with the sequence.  Flushes first —
        the deferred step may still owe this sequence (and others) a
        committed token, and freeing mid-step would strand it."""
        self._check_released()
        self.flush()
        seq = self._seqs.pop(sid)
        self.prefix.release(seq.table, self.pool)

    def table(self, sid: int) -> BlockTable:
        self._check_released()
        return self._seqs[sid].table

    def block_of(self, sid: int, layer: int, token_index: int) -> int:
        """Pool block holding a token's KV for one layer — the layer axis
        shares the block id, so one placement covers all layers."""
        assert 0 <= layer < self.cfg.n_layers
        seq = self._seqs[sid]
        assert token_index < seq.table.num_tokens
        return seq.table.blocks[token_index // self.pool.cfg.block_size]

    # -- batch-level KVBackend API ------------------------------------------

    def prefill(self, params, tokens, frontend_emb=None):
        """Protocol ``prefill``: one new sequence per row of the (B, S)
        batch, freeing any lanes a prior call created.  Returns
        last-position logits (B, 1, V)."""
        self._check_released()
        self.flush()    # barrier: lagged write-back lands before re-batch
        assert frontend_emb is None, "paged backend has no frontend state"
        old, self._batch = self._batch, []
        for sid in old:              # re-prefill replaces the batch lanes
            self.free_seq(sid)
        logits, self._batch, _ = self._add_seqs(params, np.asarray(tokens))
        return jnp.asarray(logits)[:, None, :]

    def decode_step(self, params, tokens):
        """Protocol ``decode_step``: advance the prefill lanes one token
        (tokens (B, 1) int32, row order = prefill row order).  Returns
        next-token logits (B, 1, V)."""
        self._check_released()
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        logits = self.decode(params, self._batch, toks)
        return jnp.asarray(logits)[:, None, :]

    @property
    def lengths(self) -> np.ndarray:
        """(B,) int32 cached token count per prefill lane — genuinely
        ragged (unlike the dense backend's broadcast scalar)."""
        self._check_released()
        return np.asarray(
            [self._seqs[s].table.num_tokens for s in self._batch], np.int32)

    def release(self) -> None:
        """Drain the decode pipeline (implicit flush — a pending step's
        dirty blocks land in the pool, never silently dropped), free
        every live sequence (registered prefix blocks stay as evictable
        cache), drop the mirror slots, and poison the backend: all later
        entry points raise "backend released"."""
        if not self._released:
            if self._inflight is not None:
                self.sync(self._inflight)
            self._commit_pending()
        for sid in list(self._seqs):
            self.free_seq(sid)
        self._batch = []
        self._mirrors = [None, None]
        self._slot_dirty = [set(), set()]
        self._slot, self._staged_slot = 0, None
        self._released = True


# ---------------------------------------------------------------------------
# Mesh-sharded paged backend
# ---------------------------------------------------------------------------

class ShardedPagedBackend:
    """One ``PagedBackend`` per shard of a ``ShardedBlockPool``.

    Each shard owns a complete serving stack: its own block pool, prefix
    cache, staged-dirty device mirror, and — when ``devices`` are given —
    its own mesh device, so ``lm.paged_decode_step`` runs the kernel
    **per shard over shard-local pools** (per-shard page tables; no
    global block-id space exists).  A sequence lives entirely on one
    shard: ``fork_seq`` forks within the parent's shard (CoW stays
    shard-local) and prefix sharing only ever matches blocks the same
    shard stored — which is why the scheduler routes shared prefixes to
    one shard in the first place.

    Sequence ids handed out here are backend-global; the mapping to
    (shard, inner sid) is internal.  ``decode`` accepts any mix of
    sequences, groups them by shard, runs one ragged kernel step per
    shard, and reassembles logits in call order — so the engine's lane
    loop is shard-agnostic.  The batch-level ``KVBackend`` API routes
    prefill rows to the least-loaded shard, giving drop-in parity with
    ``DenseBackend``/``PagedBackend``.
    """

    def __init__(self, cfg: ModelConfig, *_legacy_pool, pool=None,
                 n_shards: Optional[int] = None, mesh=None,
                 devices: Optional[Sequence] = None,
                 num_blocks: int = 256, block_size: int = 16,
                 placement: str = "mars", eviction: str = "fifo", **kw):
        """Prefer ``make_backend(cfg, "paged", shards=N, ...)`` — the one
        documented construction surface.

        Args:
          pool: a ``ShardedBlockPool`` to drive, or None to build one
            (``num_blocks`` total across shards).  Passing it
            positionally is deprecated; pass ``pool=``.
          n_shards/mesh: shard-count discovery when building the pool —
            forwarded to ``ShardedBlockPool`` (mesh model axis; 1
            without a mesh).
          devices: per-shard jax devices for the staged mirrors + decode
            (length ``n_shards``; entries may repeat when fewer devices
            than shards exist).  None keeps everything on the default
            device — pool sharding still partitions placement.
          num_blocks: total capacity request when building a pool; it is
            rounded *up* to a multiple of the shard count, so any
            capacity request is honored.
          Remaining kwargs (decode_mode, kernel_interpret,
          share_prefixes, ...) configure every per-shard backend alike.
        """
        from repro.kvcache.sharded_pool import ShardedBlockPool, \
            discover_shards
        if _legacy_pool:
            if len(_legacy_pool) > 1 or pool is not None:
                raise TypeError(
                    "ShardedPagedBackend takes at most one pool")
            warnings.warn(
                "passing the pool positionally to ShardedPagedBackend is "
                "deprecated; pass pool= by keyword (or use make_backend)",
                DeprecationWarning, stacklevel=2)
            pool = _legacy_pool[0]
        if pool is None:
            n_shards = discover_shards(n_shards, mesh)
            num_blocks = -(-num_blocks // n_shards) * n_shards
            pool = ShardedBlockPool(
                PoolConfig(num_blocks=num_blocks, block_size=block_size,
                           placement=placement, eviction=eviction,
                           n_kv_heads=cfg.n_kv_heads, head_dim=cfg.d_head,
                           n_layers=cfg.n_layers, dtype=str(cfg.kvdtype)),
                n_shards=n_shards, mesh=mesh)
        assert isinstance(pool, ShardedBlockPool), \
            "ShardedPagedBackend needs a ShardedBlockPool"
        if devices is not None:
            assert len(devices) == pool.n_shards, \
                (len(devices), pool.n_shards)
        self.cfg = cfg
        self.pool = pool
        self.backends = [
            PagedBackend(cfg, pool=shard_pool,
                         device=None if devices is None else devices[i],
                         **kw)
            for i, shard_pool in enumerate(pool.shards)]
        self._seqs: dict[int, tuple[int, int]] = {}   # gsid -> (shard, isid)
        self._rev: dict[tuple[int, int], int] = {}    # (shard, isid) -> gsid
        self._next_sid = 0
        self._batch: list[int] = []
        self._released = False
        # split-phase pipeline state (mirrors PagedBackend's; the inner
        # per-shard steps live in the outer step's ``parts``)
        self._inflight: Optional[DecodeStep] = None
        self._pending: Optional[DecodeStep] = None
        self._steps = 0

    def _check_released(self) -> None:
        if self._released:
            raise RuntimeError(
                "ShardedPagedBackend released: release() returned every "
                "block to its shard pool; build a new backend to serve "
                "again")

    # decode_mode / kernel staging reads mirror PagedBackend's so the
    # engine's use_kernel override and the staging tests stay backend-
    # -agnostic (setter fans out to every shard)

    @property
    def decode_mode(self) -> str:
        return self.backends[0].decode_mode

    @decode_mode.setter
    def decode_mode(self, mode: str) -> None:
        if mode not in ("kernel", "gather"):
            raise ValueError(f"unknown decode_mode {mode!r}")
        for b in self.backends:
            b.decode_mode = mode

    @property
    def staged_blocks_last_step(self) -> int:
        return sum(b.staged_blocks_last_step for b in self.backends)

    # -- sequence-level API (what the serve engine drives) ------------------

    def new_seq(self, params, prompt: Sequence[int],
                on_alloc: Optional[Callable[[int, int], None]] = None,
                shard: Optional[int] = None) -> tuple[int, Any, int]:
        """Prefill one sequence on one shard.

        Args:
          shard: the routed shard (what ``MarsScheduler`` stamped on the
            request via ``ShardedBlockPool.route``); None picks the
            least-loaded shard (direct API use, no scheduler in front).
        Returns/invariants: as ``PagedBackend.new_seq`` — additionally,
        every block of the sequence lives in ``pool.shards[shard]``.
        """
        self._check_released()
        # barrier across *all* shards (the inner new_seq only flushes its
        # own) so admission reads post-commit pool state everywhere
        self.flush()
        if shard is None:
            shard = self.pool.least_loaded()
        assert 0 <= shard < self.pool.n_shards, shard
        gsid = self._next_sid
        self._next_sid += 1
        cb = None if on_alloc is None else \
            (lambda _isid, n: on_alloc(gsid, n))
        isid, logits, shared = self.backends[shard].new_seq(
            params, prompt, on_alloc=cb)
        self._seqs[gsid] = (shard, isid)
        self._rev[(shard, isid)] = gsid
        return gsid, logits, shared

    def fork_seq(self, sid: int) -> int:
        """Fork within the parent's shard — CoW forks are shard-local by
        construction (blocks of one pool cannot be referenced from
        another).  Forces a flush barrier first (every shard — the
        outer step is all-or-nothing across shards)."""
        self._check_released()
        self.flush()
        shard, isid = self._seqs[sid]
        nisid = self.backends[shard].fork_seq(isid)
        gsid = self._next_sid
        self._next_sid += 1
        self._seqs[gsid] = (shard, nisid)
        self._rev[(shard, nisid)] = gsid
        return gsid

    # -- decode preemption (pause -> demote -> resume) -----------------------

    def pause_seq(self, sid: int) -> dict:
        """Preempt a live decode on its shard: barrier across every
        shard first (the outer round is all-or-nothing), then capture
        and release on the owning shard (``PagedBackend.pause_seq``).
        The record remembers the shard so an un-routed resume defaults
        back to where the cached/demoted blocks still live."""
        self._check_released()
        self.flush()
        shard, isid = self._seqs.pop(sid)
        del self._rev[(shard, isid)]
        rec = self.backends[shard].pause_seq(isid)
        rec["shard"] = shard
        return rec

    def resume_seq(self, rec: dict,
                   on_alloc: Optional[Callable[[int, int], None]] = None,
                   shard: Optional[int] = None) -> int:
        """Re-admit a paused sequence under a new global sid.

        ``shard=None`` resumes on the pause shard (prefix/tier matches
        only ever hit there); an explicit shard restores the captured
        payload onto that shard instead — the bytes are shard-agnostic,
        only the cache reuse is not.  Bitwise either way."""
        self._check_released()
        self.flush()
        if shard is None:
            shard = rec.get("shard", self.pool.least_loaded())
        assert 0 <= shard < self.pool.n_shards, shard
        gsid = self._next_sid
        self._next_sid += 1
        cb = None if on_alloc is None else \
            (lambda _isid, n: on_alloc(gsid, n))
        isid = self.backends[shard].resume_seq(rec, on_alloc=cb)
        self._seqs[gsid] = (shard, isid)
        self._rev[(shard, isid)] = gsid
        return gsid

    def decode(self, params, sids: Sequence[int], tokens: Sequence[int],
               on_alloc: Optional[Callable[[int, int], None]] = None):
        """One ragged decode round across shards — the synchronous
        compatibility wrapper: ``dispatch_decode`` + ``sync`` +
        ``commit``.  Even this wrapper is issue-then-gather: every
        shard's kernel is dispatched before any shard's logits are
        awaited.  Returns float32 (len(sids), V) row-aligned to sids.

        All-or-nothing across shards, like ``PagedBackend.decode`` is
        within one: every shard's worst-case block need is prechecked
        before ANY shard dispatches, so a "pool exhausted" raise leaves
        every sequence — on every shard — exactly as it was (no lane
        double-appends KV on a retry)."""
        step = self.dispatch_decode(params, tokens, sids=sids,
                                    on_alloc=on_alloc)
        out = self.sync(step)
        self.commit(step)
        return out

    # -- split-phase decode lifecycle (issue-then-gather) --------------------

    def dispatch_decode(self, params, tokens, *, sids=None,
                        on_alloc: Optional[Callable[[int, int], None]]
                        = None) -> DecodeStep:
        """Dispatch one decode round on every involved shard before any
        is synced: flush (committing the prior round everywhere), run
        the cross-shard capacity precheck, then launch each shard's
        kernel back-to-back — jax queues them asynchronously, so the
        per-shard kernels and mirror scatters overlap instead of running
        host-blocking round trips shard by shard."""
        self._check_released()
        batch_api = sids is None
        if batch_api:
            sids = list(self._batch)
            tokens = [int(t) for t in np.asarray(tokens).reshape(-1)]
        assert sids, "no active sequences to decode (prefill first)"
        if self._inflight is not None:
            raise RuntimeError(
                "a decode step is already in flight; sync() it before "
                "dispatching the next")
        self._commit_pending()
        by_shard: dict[int, list[int]] = {}
        for i, s in enumerate(sids):
            by_shard.setdefault(self._seqs[s][0], []).append(i)
        # cross-shard capacity precheck (mirrors the per-shard one):
        # each lane needs at most one fresh block — a new tail, or a CoW
        # copy of a shared tail.  Prechecking every shard before ANY
        # dispatches keeps the round all-or-nothing.
        page = self.pool.cfg.block_size
        for shard, idxs in by_shard.items():
            inner = self.backends[shard]
            need = 0
            for i in idxs:
                t = inner._seqs[self._seqs[sids[i]][1]].table
                fill = t.num_tokens % page
                if fill == 0 or inner.pool.refcount[t.blocks[-1]] > 1:
                    need += 1
            if not inner.pool.can_alloc(need):
                raise RuntimeError(
                    f"pool exhausted on shard {shard}: decode step needs "
                    f"{need} blocks, free {inner.pool.num_free}, "
                    f"cached {inner.pool.num_cached}")
        parts = []
        for shard, idxs in sorted(by_shard.items()):
            cb = None if on_alloc is None else \
                (lambda isid, n, _s=shard:
                 on_alloc(self._rev[(_s, isid)], n))
            inner_step = self.backends[shard].dispatch_decode(
                params, [tokens[i] for i in idxs],
                sids=[self._seqs[sids[i]][1] for i in idxs], on_alloc=cb)
            parts.append((shard, inner_step, idxs))
        step = DecodeStep(index=self._steps, sids=list(sids),
                          tokens=[int(t) for t in tokens],
                          staged=self.staged_blocks_last_step,
                          batch_api=batch_api, parts=parts)
        self._steps += 1
        self._inflight = step
        return step

    def sync(self, step: DecodeStep):
        """Gather every shard's logits (all kernels were already issued
        by ``dispatch_decode``) and reassemble rows in call order.
        Idempotent on a synced step."""
        self._check_released()
        if step.synced:
            return step.logits
        if step is not self._inflight:
            raise RuntimeError(
                "sync() of a step that is not in flight on this backend")
        rows: dict[int, np.ndarray] = {}
        for shard, inner_step, idxs in step.parts:
            lg = self.backends[shard].sync(inner_step)
            for j, i in enumerate(idxs):
                rows[i] = lg[j]
        step.logits = np.stack([rows[i] for i in range(len(step.sids))])
        if step.batch_api:
            step.logits = jnp.asarray(step.logits)[:, None, :]
        step.synced = True
        self._inflight = None
        self._pending = step
        return step.logits

    def commit(self, step: Optional[DecodeStep] = None) -> None:
        """Commit every shard's part of the pending round."""
        self._check_released()
        if step is not None:
            if step.committed:
                return
            if step is not self._pending:
                raise RuntimeError(
                    "commit() of a step that is not pending on this "
                    "backend (sync() it first)")
        self._commit_pending()

    def _commit_pending(self) -> None:
        step = self._pending
        if step is None:
            return
        self._pending = None
        for shard, inner_step, _ in step.parts:
            self.backends[shard].commit(inner_step)
        step.committed = True

    def flush(self) -> None:
        """Barrier across every shard: sync the in-flight round, commit
        the pending one, and drain each shard backend (covers direct
        inner-backend use too).  Idempotent; raises once released."""
        self._check_released()
        if self._inflight is not None:
            self.sync(self._inflight)
        self._commit_pending()
        for b in self.backends:
            b.flush()

    @property
    def inflight_steps(self) -> int:
        """Cross-shard rounds between dispatch and commit (0, 1, or 2 —
        a round counts once however many shards it spans)."""
        return int(self._inflight is not None) + \
            int(self._pending is not None)

    def free_seq(self, sid: int) -> None:
        """Release a finished sequence back to its shard's pool (after
        the flush barrier — the deferred round may still owe it a
        committed token)."""
        self._check_released()
        self.flush()
        shard, isid = self._seqs.pop(sid)
        del self._rev[(shard, isid)]
        self.backends[shard].free_seq(isid)

    def table(self, sid: int) -> BlockTable:
        self._check_released()
        shard, isid = self._seqs[sid]
        return self.backends[shard].table(isid)

    def shard_of(self, sid: int) -> int:
        """Shard a live sequence's blocks occupy — the leading coordinate
        of its placement key (``placement.placement_key``)."""
        self._check_released()
        return self._seqs[sid][0]

    # -- tiered KV memory (per-shard tiers, demotion/promotion shard-local) --

    @property
    def tiered(self) -> bool:
        """True iff the per-shard backends carry spill tiers (the
        ``tiered=`` kwarg fans out to every shard: one ``TierManager``
        per shard pool, so demoted payloads never cross shards)."""
        return self.backends[0].tiers is not None

    def tier_shard_for(self, prompt: Sequence[int]) -> Optional[int]:
        """Shard whose spill tiers hold the prompt's first full prefix
        block, or ``None`` — the promotable lower-tier prefix hit the
        scheduler may count toward affinity routing
        (``MarsScheduler.tier_probe``).  Routing a request here turns a
        would-be recompute into a shard-local promotion."""
        self._check_released()
        for i, b in enumerate(self.backends):
            if b.tiers is not None and b.tiers.holds_prefix(prompt):
                return i
        return None

    # -- batch-level KVBackend API ------------------------------------------

    def prefill(self, params, tokens, frontend_emb=None):
        """Protocol ``prefill``: rows route greedily to the least-loaded
        shard (load measured in blocks, each row charged its block need —
        the batch API has no prefix pages to be affine to), then each
        shard prefills its rows in one batched call.  Atomic across
        shards like ``PagedBackend._add_seqs`` is within one: if a later
        shard exhausts its pool, rows already prefilled on earlier shards
        are freed before the error re-raises — nothing stays live.
        Returns last-position logits (B, 1, V) in row order."""
        self._check_released()
        assert frontend_emb is None, "paged backend has no frontend state"
        self.flush()
        old, self._batch = self._batch, []
        for sid in old:
            self.free_seq(sid)
        tokens = np.asarray(tokens)
        B = tokens.shape[0]
        # same unit as pool.load (blocks): a row stores S prompt tokens.
        # Shard ranking comes from the shared load snapshot (the same
        # numbers ShardedBlockPool.route and the obs gauges use).
        from repro.obs.observer import shard_load_snapshot
        row_blocks = -(-tokens.shape[1] // self.pool.cfg.block_size)
        load = [r["load"] for r in shard_load_snapshot(self.pool)]
        plan: dict[int, list[int]] = {}
        for i in range(B):
            s = min(range(self.pool.n_shards),
                    key=lambda x: (load[x], x))
            plan.setdefault(s, []).append(i)
            load[s] += row_blocks
        out = np.zeros((B, self.cfg.vocab), np.float32)
        gsids: dict[int, int] = {}
        for shard, idxs in sorted(plan.items()):
            try:
                lg, isids, _ = self.backends[shard]._add_seqs(
                    params, tokens[idxs])
            except RuntimeError:
                # the failing shard rolled itself back; free the rows
                # earlier shards already created, then surface the error
                for gsid in gsids.values():
                    self.free_seq(gsid)
                raise
            for j, i in enumerate(idxs):
                out[i] = lg[j]
                gsid = self._next_sid
                self._next_sid += 1
                self._seqs[gsid] = (shard, isids[j])
                self._rev[(shard, isids[j])] = gsid
                gsids[i] = gsid
        self._batch = [gsids[i] for i in range(B)]
        return jnp.asarray(out)[:, None, :]

    def decode_step(self, params, tokens):
        """Protocol ``decode_step`` over the prefill lanes (see
        ``PagedBackend.decode_step``); lanes decode on their own shards.
        Returns next-token logits (B, 1, V)."""
        self._check_released()
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        logits = self.decode(params, self._batch, toks)
        return jnp.asarray(logits)[:, None, :]

    @property
    def lengths(self) -> np.ndarray:
        """(B,) int32 cached token count per prefill lane."""
        self._check_released()
        return np.asarray([self.table(s).num_tokens for s in self._batch],
                          np.int32)

    def release(self) -> None:
        """Drain the pipeline (implicit flush), then release every shard
        backend; later entry points raise."""
        if not self._released:
            if self._inflight is not None:
                self.sync(self._inflight)
            self._commit_pending()
        for b in self.backends:
            b.release()
        self._seqs.clear()
        self._rev.clear()
        self._batch = []
        self._released = True


def make_backend(cfg: ModelConfig, kind: str = "dense", *,
                 batch: int = 1, max_seq: int = 0, enc_len: int = 0,
                 pool: Optional[BlockPool] = None,
                 shards: Optional[int] = None, device=None,
                 **kw) -> KVBackend:
    """Backend registry — the single documented construction surface:
    "dense" | "paged" | "sharded-paged".

    One keyword surface configures every kind alike: ``decode_mode``
    ("kernel"/"gather"), ``kernel_interpret`` (False on real TPU),
    ``tiered`` (spill tiers behind the pool), ``shards`` (shard count —
    ``shards > 1`` turns "paged" into the mesh-sharded backend), and
    ``device`` (the jax device for the staged mirror; per-shard
    ``devices=[...]`` for sharded kinds).

    Args:
      batch/max_seq: capacity request — dense allocates (B, max_seq)
        directly; paged kinds size the pool to hold ``batch`` lanes of
        ``max_seq`` tokens (+1 decode slot each) unless ``num_blocks`` or
        an explicit ``pool`` overrides it.
      pool: concrete storage to share (``BlockPool`` for "paged",
        ``ShardedBlockPool`` for "sharded-paged").
      shards: partition the pool across this many shards (kind "paged"
        with ``shards > 1`` routes to "sharded-paged"; aliases
        ``n_shards`` there).
      device: jax device for a paged backend's mirror + operands.
      Remaining kwargs forward to the backend constructor.
    Returns: an object satisfying the ``KVBackend`` protocol.

    >>> make_backend(None, "holographic")
    Traceback (most recent call last):
        ...
    ValueError: unknown KV backend kind 'holographic'

    The split-phase decode lifecycle (dispatch → sync → commit, with
    ``flush()`` as the barrier — decode step N commits step N-1):

    >>> import jax
    >>> from repro import configs
    >>> from repro.models import lm
    >>> cfg = configs.get_smoke("qwen1_5_0_5b")
    >>> params = lm.init(cfg, jax.random.key(0)).params
    >>> b = make_backend(cfg, "paged", num_blocks=16, block_size=4,
    ...                  decode_mode="gather")
    >>> sid, _, _ = b.new_seq(params, [1, 2, 3, 4, 5])
    >>> step = b.dispatch_decode(params, [7], sids=[sid])  # no block
    >>> step.synced, b.inflight_steps
    (False, 1)
    >>> logits = b.sync(step)              # block on logits only
    >>> logits.shape[0], step.synced, step.committed
    (1, True, False)
    >>> b.table(sid).num_tokens            # write-back still deferred
    5
    >>> b.flush()                          # barrier: commit the KV
    >>> b.table(sid).num_tokens, b.inflight_steps
    (6, 0)
    >>> b.release()
    """
    if kind == "dense":
        return DenseBackend(cfg, batch, max_seq, enc_len)
    if kind in ("paged", "sharded-paged"):
        if shards is not None and kind == "paged" and shards > 1:
            kind = "sharded-paged"
        if kind == "sharded-paged" and shards is not None:
            kw.setdefault("n_shards", shards)
        size_request = pool is None and "num_blocks" not in kw and max_seq
        # honor the caller's capacity request: room for `batch` lanes of
        # max_seq tokens (+1 decode slot each)
        bs = kw.get("block_size", 16)
        lane_blocks = -(-(max_seq + 1) // bs)
        if kind == "paged":
            if size_request:
                kw["num_blocks"] = batch * lane_blocks
            return PagedBackend(cfg, pool=pool, device=device, **kw)
        if device is not None:
            raise ValueError(
                "sharded-paged takes per-shard devices=[...], not device=")
        if size_request:
            from repro.kvcache.sharded_pool import discover_shards
            n = kw["n_shards"] = discover_shards(kw.get("n_shards"),
                                                 kw.get("mesh"))
            # a lane never spans shards, so splitting batch*lane_blocks
            # evenly would under-size shards whenever n does not divide
            # batch: every shard must hold its share of WHOLE lanes
            kw["num_blocks"] = n * (-(-batch // n)) * lane_blocks
        return ShardedPagedBackend(cfg, pool=pool, **kw)
    raise ValueError(f"unknown KV backend kind {kind!r}")
