"""Unified KV-backend API: dense and paged serving caches, one interface.

The model (``models.lm``) speaks to its KV storage only through
``KVBackend``: ``prefill`` runs a prompt batch and stores every layer's
K/V, ``decode_step`` advances every lane one token.  Two implementations:

  DenseBackend   wraps the concrete per-layer ``lm.Cache`` pytree — the
                 training/dry-run storage.  Reads of ``.k``/``.v``/
                 ``.length`` forward to the cache, so code written against
                 the old concrete-Cache API keeps working.
  PagedBackend   per-sequence block tables over a layered ``BlockPool``
                 (one block id addresses a token-chunk's KV for *every*
                 layer — a single MARS placement decision co-locates a
                 token's per-layer blocks in one DRAM row group).  Supports
                 ragged continuous-batching decode, prefix sharing and
                 copy-on-write forks, and is what ``serve.engine`` drives.
                 Hybrid (attention + SSM) families keep their per-sequence
                 SSM/conv decode state host-side next to the block tables
                 (forked with the sequence, freed with it).

Decode through the paged backend has two modes (``decode_mode``):

  "kernel"   the default: ``lm.paged_decode_step`` reads each layer's KV
             straight from the pool's layered page buffers via the Pallas
             ``paged_attention`` kernel (online-softmax merge of the
             in-flight token) — the MARS placement decisions *are* the
             kernel's page-walk addresses, nothing is flattened first.
             Sliding-window configs run natively: the scan flips the
             kernel's window mask per layer (``global_every`` hybrids
             keep their global layers unmasked).
  "gather"   the fallback/oracle: gather each lane's pages into a dense
             per-layer view and run the *same* ``lm.dense_decode_step``
             math as the dense backend, so gather-path logits agree with
             the dense backend bit-for-bit.

Either way the new token's K/V is extracted from the step and written
back into the pool host-side after attention (the pool mutates in place,
exactly like the single-layer engine of PR 1), so the kernel never reads
a partially-written page.  The pool buffers are staged to device through
a mirror that re-uploads only the blocks dirtied since the previous step
(``BlockPool.drain_dirty``) — never the whole pool per token.

A released backend (``release()``) raises a clear "backend released"
error from every serving entry point instead of an opaque NoneType /
KeyError; build a new backend to serve again.

Adding a backend: implement ``prefill``/``decode_step``/``lengths``/
``release`` against ``lm.prefill_parts`` (storage-agnostic prompt run)
and ``lm.dense_decode_step`` (ragged one-token step), register a
constructor in ``make_backend``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Protocol, Sequence, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache.pool import BlockPool, PoolConfig
from repro.kvcache.prefix import BlockTable, PrefixCache
from repro.models.config import ModelConfig


@runtime_checkable
class KVBackend(Protocol):
    """What the model needs from its KV storage — nothing more."""

    cfg: ModelConfig

    def prefill(self, params, tokens, frontend_emb=None):
        """Run a (B, S) prompt batch, storing all layers' K/V.
        Returns last-position logits (B, 1, V)."""
        ...

    def decode_step(self, params, tokens):
        """Advance every lane one token.  tokens: (B, 1) int32 inputs.
        Returns next-token logits (B, 1, V)."""
        ...

    @property
    def lengths(self) -> np.ndarray:
        """Per-lane cached token counts, int32 (B,)."""
        ...

    def release(self) -> None:
        """Drop all storage (paged: decref blocks back to the pool)."""
        ...


# ---------------------------------------------------------------------------
# Dense backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _dense_decode(params, cfg, tokens, cache):
    from repro.models import lm
    return lm.dense_decode_step(params, cfg, tokens, cache)


class DenseBackend:
    """The old concrete ``lm.Cache`` behind the backend interface."""

    def __init__(self, cfg: ModelConfig, batch: int, max_seq: int,
                 enc_len: int = 0):
        from repro.models import lm
        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self._cache = lm.init_dense_cache(cfg, batch, max_seq, enc_len)

    def _check_released(self) -> None:
        if self._cache is None:
            raise RuntimeError(
                "DenseBackend released: release() dropped the cache "
                "storage; build a new backend to serve again")

    # -- backend API --------------------------------------------------------

    def prefill(self, params, tokens, frontend_emb=None):
        from repro.models import lm
        self._check_released()
        logits, self._cache = lm.dense_prefill(
            params, self.cfg, tokens, self.max_seq, frontend_emb)
        return logits

    def decode_step(self, params, tokens):
        self._check_released()
        logits, self._cache = _dense_decode(params, self.cfg, tokens,
                                            self._cache)
        return logits

    @property
    def lengths(self) -> np.ndarray:
        self._check_released()
        ln = np.asarray(self._cache.length, np.int32)
        return np.broadcast_to(np.atleast_1d(ln), (self.batch,)).copy()

    def release(self) -> None:
        self._cache = None

    # -- concrete-Cache compatibility reads ---------------------------------

    @property
    def cache(self):
        return self._cache

    def __getattr__(self, name):
        # k / v / ssm / conv / xk / xv / length forwarded to the pytree
        if name in ("k", "v", "ssm", "conv", "xk", "xv", "length"):
            if self.__dict__.get("_cache") is None:
                raise RuntimeError(
                    f"DenseBackend released: cannot read .{name} after "
                    "release(); build a new backend to serve again")
            return getattr(self._cache, name)
        raise AttributeError(name)


# ---------------------------------------------------------------------------
# Paged backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _paged_decode(params, cfg, tokens, k_pages, v_pages, page_tables,
                  lengths, ssm, conv):
    """Gather each lane's pages into a dense per-layer view, run the ragged
    dense decode step, and extract the new token's K/V for write-back.

    k/v_pages: (L, P, page, K, dh); page_tables: (B, n_pages) int32;
    lengths: (B,) int32 — the padded view always has room for slot
    ``lengths[b]`` (the backend pads the table before calling).
    ssm/conv: hybrid side state (L, B, H, P, N) / (L, B, k-1, ch), or
    None for attention-only families.
    Returns (logits, k_new (L, B, 1, K, dh), v_new, ssm_new, conv_new).
    """
    from repro.models import lm
    L = k_pages.shape[0]
    K, dh = k_pages.shape[-2:]
    B = tokens.shape[0]
    k = k_pages[:, page_tables].reshape(L, B, -1, K, dh)
    v = v_pages[:, page_tables].reshape(L, B, -1, K, dh)
    cache = lm.Cache(k=k, v=v, ssm=ssm, conv=conv, xk=None, xv=None,
                     length=lengths)
    logits, new = lm.dense_decode_step(params, cfg, tokens, cache)
    idx = lengths.astype(jnp.int32)[None, :, None, None, None]
    k_new = jnp.take_along_axis(new.k, idx, axis=2)
    v_new = jnp.take_along_axis(new.v, idx, axis=2)
    return logits, k_new, v_new, new.ssm, new.conv


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def _paged_decode_kernel(params, cfg, tokens, k_pages, v_pages,
                         page_tables, lengths, ssm, conv, interpret=True):
    """Kernel-path decode: per-layer Pallas paged attention straight over
    the pool's layered page buffers (no dense gather).  Same operand and
    result shapes as ``_paged_decode``."""
    from repro.models import lm
    return lm.paged_decode_step(params, cfg, tokens, k_pages, v_pages,
                                page_tables, lengths, ssm_state=ssm,
                                conv_state=conv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_prefill_parts(params, cfg, tokens):
    from repro.models import lm
    return lm.prefill_parts(params, cfg, tokens)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks(dev, idx, vals):
    """Write dirty block planes into the device mirror.  The mirror is
    donated so XLA updates it in place — no pool-sized device copy per
    step.  ``idx`` may repeat (pow2 padding); duplicate indices write the
    same value twice, harmlessly."""
    return dev.at[:, idx].set(vals)


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class _PagedSeq:
    sid: int
    table: BlockTable
    tokens: list            # tokens whose KV is cached
    # hybrid side state the pool cannot hold: per-sequence SSM recurrent
    # state (L, H, P, N) float32 and conv trailing context (L, k-1, ch),
    # host-side, forked with the sequence, freed with it
    ssm: Optional[np.ndarray] = None
    conv: Optional[np.ndarray] = None


class PagedBackend:
    """Per-sequence block tables over a layered ``BlockPool``.

    Sequence-level API (what the serve engine drives): ``new_seq`` /
    ``fork_seq`` / ``decode`` / ``free_seq``.  The batch-level
    ``KVBackend`` API (``prefill`` / ``decode_step``) runs the same
    machinery over a fixed batch, giving drop-in parity with
    ``DenseBackend``.

    Prompt K/V is always recomputed (prefill logits need the full
    context); prefix sharing is at the *storage* level — matched blocks
    are referenced instead of re-allocated, which is what bounds pool
    occupancy under hot prefixes.
    """

    def __init__(self, cfg: ModelConfig, pool: Optional[BlockPool] = None,
                 *, num_blocks: int = 256, block_size: int = 16,
                 placement: str = "mars", eviction: str = "fifo",
                 share_prefixes: bool = True, decode_mode: str = "kernel",
                 kernel_interpret: bool = True):
        if not cfg.has_attention or cfg.enc_layers \
                or cfg.family in ("encdec", "vlm"):
            raise NotImplementedError(
                f"PagedBackend pages attention KV plus per-sequence "
                f"SSM/conv decode state; family {cfg.family!r} needs "
                f"state the pool does not hold yet (encoder KV / "
                f"frontend prefixes, or has no attention KV at all)")
        if decode_mode not in ("kernel", "gather"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        self.decode_mode = decode_mode
        self.kernel_interpret = kernel_interpret
        self.cfg = cfg
        if pool is None:
            pool = BlockPool(PoolConfig(
                num_blocks=num_blocks, block_size=block_size,
                placement=placement, eviction=eviction,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.d_head,
                n_layers=cfg.n_layers, dtype=str(cfg.kvdtype)))
        assert pool.k_pages is not None, "paged backend needs a KV pool"
        assert pool.cfg.n_layers == cfg.n_layers \
            and pool.cfg.n_kv_heads == cfg.n_kv_heads \
            and pool.cfg.head_dim == cfg.d_head, \
            "pool KV buffer does not match the model config"
        self.pool = pool
        self.prefix = PrefixCache(pool.cfg.block_size)
        if share_prefixes:
            self.prefix.attach(pool)
        self.share_prefixes = share_prefixes
        self._seqs: dict[int, _PagedSeq] = {}
        self._next_sid = 0
        self._batch: list[int] = []      # batch-level API lane order
        self._released = False
        # device mirror of the pool's KV buffers: decode re-stages only
        # blocks dirtied since the previous step (this backend is the
        # pool's single drain_dirty consumer)
        self._k_dev = self._v_dev = None
        self.staged_blocks_last_step = 0

    def _check_released(self) -> None:
        if self._released:
            raise RuntimeError(
                "PagedBackend released: release() returned every block "
                "to the pool; build a new backend to serve again")

    # -- device staging ------------------------------------------------------

    def _staged_pages(self):
        """Stage the pool's host-mutated KV buffers to device, uploading
        only blocks written since the last call (full upload first time).
        ``staged_blocks_last_step`` records how many blocks moved."""
        pool = self.pool
        if self._k_dev is None:
            pool.drain_dirty()           # full upload covers everything
            self._k_dev = jnp.asarray(pool.k_pages)
            self._v_dev = jnp.asarray(pool.v_pages)
            self.staged_blocks_last_step = pool.cfg.num_blocks
        else:
            dirty = pool.drain_dirty()
            self.staged_blocks_last_step = len(dirty)
            if dirty:
                # pad the id list to a power of two (repeating the last
                # id) so the donated scatter compiles O(log) variants
                pad = dirty + [dirty[-1]] * (_pow2(len(dirty)) - len(dirty))
                idx = jnp.asarray(pad, jnp.int32)
                self._k_dev = _scatter_blocks(
                    self._k_dev, idx, jnp.asarray(pool.k_pages[:, pad]))
                self._v_dev = _scatter_blocks(
                    self._v_dev, idx, jnp.asarray(pool.v_pages[:, pad]))
        return self._k_dev, self._v_dev

    # -- sequence-level API (continuous batching) ---------------------------

    def new_seq(self, params, prompt: Sequence[int],
                on_alloc: Optional[Callable[[int, int], None]] = None
                ) -> tuple[int, Any, int]:
        """Prefill one sequence.  Returns (sid, last-position logits
        (1, V), shared-prefix token count)."""
        logits, sids, shared = self._add_seqs(
            params, np.asarray([list(prompt)], np.int32), on_alloc)
        return sids[0], logits[0], shared[0]

    def _add_seqs(self, params, tokens: np.ndarray,
                  on_alloc=None) -> tuple[Any, list[int], list[int]]:
        """Batched prompt prefill -> one new sequence per row.

        Atomic under pool exhaustion: if any row's ``table.extend``
        raises, the partial table (prefix-matched increfed blocks plus
        blocks allocated before the failure) is decref'd back and rows
        already added by this call are freed, then the error re-raises —
        nothing stays live.
        """
        self._check_released()
        B, S = tokens.shape
        logits, parts = _jit_prefill_parts(
            params, self.cfg, jnp.asarray(tokens, jnp.int32))
        kvd = self.cfg.kvdtype
        k_all = np.asarray(parts["k"].astype(kvd))   # (L, B, S, K, dh)
        v_all = np.asarray(parts["v"].astype(kvd))
        ssm_all = conv_all = None
        if self.cfg.has_ssm:
            ssm_all = np.asarray(parts["ssm"], np.float32)
            conv_all = np.asarray(parts["conv"])
        sids, shared = [], []
        for b in range(B):
            prompt = [int(t) for t in tokens[b]]
            if self.share_prefixes:
                bids, n = self.prefix.match(prompt, self.pool)
            else:
                bids, n = [], 0
            table = BlockTable(list(bids), n)
            allocs0 = self.pool.stats.allocs
            try:
                table.extend(
                    self.pool, prompt[n:], seq_tokens=prompt,
                    cache=self.prefix if self.share_prefixes else None,
                    kv=(k_all[:, b, n:], v_all[:, b, n:]))
            except RuntimeError:
                # roll back: this row's partial table (registered blocks
                # stay as evictable cache, private ones free), then the
                # rows this call already created — batched prefill is
                # all-or-nothing
                self.prefix.release(table, self.pool)
                for sid in sids:
                    self.free_seq(sid)
                raise
            sid = self._next_sid
            self._next_sid += 1
            seq = _PagedSeq(sid, table, list(prompt))
            if ssm_all is not None:
                seq.ssm = np.ascontiguousarray(ssm_all[:, b])
                seq.conv = np.ascontiguousarray(conv_all[:, b])
            self._seqs[sid] = seq
            if on_alloc is not None:
                on_alloc(sid, self.pool.stats.allocs - allocs0)
            sids.append(sid)
            shared.append(n)
        return np.asarray(logits[:, 0], np.float32), sids, shared

    def fork_seq(self, sid: int) -> int:
        """Fork a sequence, sharing every block (CoW on first append);
        the hybrid side state is copied — it is mutated every step."""
        self._check_released()
        src = self._seqs[sid]
        nsid = self._next_sid
        self._next_sid += 1
        self._seqs[nsid] = _PagedSeq(
            nsid, src.table.fork(self.pool), list(src.tokens),
            ssm=None if src.ssm is None else src.ssm.copy(),
            conv=None if src.conv is None else src.conv.copy())
        return nsid

    def decode(self, params, sids: Sequence[int], tokens: Sequence[int],
               on_alloc: Optional[Callable[[int, int], None]] = None):
        """One ragged decode step: feed ``tokens[i]`` to sequence
        ``sids[i]``, cache its K/V, return next-token logits (n, V)."""
        self._check_released()
        assert sids, "no active sequences to decode (prefill first)"
        from repro.kernels.paged_attention import ops
        seqs = [self._seqs[s] for s in sids]
        B = len(seqs)
        page = self.pool.cfg.block_size
        # padded page-table view: every lane needs room for slot len(seq)
        # on the gather path (the kernel path attends the in-flight token
        # out of registers, but shares the padding so both compile alike)
        n_pages = _pow2(max(
            -(-(len(s.tokens) + 1) // page) for s in seqs))
        Bp = _pow2(B)                       # lane padding bounds recompiles
        pt, lengths = ops.pool_page_tables(
            [s.table for s in seqs], pad_to=n_pages, pad_lanes=Bp)
        toks = np.zeros((Bp, 1), np.int32)
        toks[:B, 0] = list(tokens)
        kp, vp = self._staged_pages()
        ssm = conv = None
        if self.cfg.has_ssm:
            # batch the per-sequence hybrid side state (padded lanes get
            # zeros; their outputs are discarded below)
            L = self.cfg.n_layers
            ssm_np = np.zeros((L, Bp) + seqs[0].ssm.shape[1:],
                              seqs[0].ssm.dtype)
            conv_np = np.zeros((L, Bp) + seqs[0].conv.shape[1:],
                               seqs[0].conv.dtype)
            for i, s in enumerate(seqs):
                ssm_np[:, i] = s.ssm
                conv_np[:, i] = s.conv
            ssm = jnp.asarray(ssm_np)
            conv = jnp.asarray(conv_np)
        if self.decode_mode == "kernel":
            logits, k_new, v_new, ssm_new, conv_new = _paged_decode_kernel(
                params, self.cfg, jnp.asarray(toks), kp, vp,
                jnp.asarray(pt), jnp.asarray(lengths), ssm, conv,
                interpret=self.kernel_interpret)
        else:
            logits, k_new, v_new, ssm_new, conv_new = _paged_decode(
                params, self.cfg, jnp.asarray(toks), kp, vp,
                jnp.asarray(pt), jnp.asarray(lengths), ssm, conv)
        k_new = np.asarray(k_new)           # (L, Bp, 1, K, dh)
        v_new = np.asarray(v_new)
        if ssm_new is not None:
            ssm_new = np.asarray(ssm_new)   # (L, Bp, H, P, N)
            conv_new = np.asarray(conv_new)
        # capacity precheck so the write-back loop cannot die halfway
        # (rolling back a committed lane would mean undoing CoW/eviction
        # side effects): each lane needs at most one fresh block — a new
        # tail, or a CoW copy of a shared tail.  Raising here leaves
        # every sequence exactly as it was before the step.
        need = 0
        for s in seqs:
            fill = s.table.num_tokens % page
            if fill == 0 or \
                    self.pool.refcount[s.table.blocks[-1]] > 1:
                need += 1
        if not self.pool.can_alloc(need):
            raise RuntimeError(
                f"pool exhausted: decode step needs {need} blocks, "
                f"free {self.pool.num_free}, cached {self.pool.num_cached}")
        for i, (s, tok) in enumerate(zip(seqs, tokens)):
            allocs0 = self.pool.stats.allocs
            new_tokens = s.tokens + [int(tok)]
            s.table.extend(
                self.pool, [int(tok)], seq_tokens=new_tokens,
                cache=self.prefix if self.share_prefixes else None,
                kv=(k_new[:, i], v_new[:, i]))
            s.tokens = new_tokens     # commit only after the extend
            if ssm_new is not None:
                s.ssm = np.ascontiguousarray(ssm_new[:, i])
                s.conv = np.ascontiguousarray(conv_new[:, i])
            if on_alloc is not None:
                on_alloc(s.sid, self.pool.stats.allocs - allocs0)
        return np.asarray(logits[:B, 0], np.float32)

    def free_seq(self, sid: int) -> None:
        """Finished sequence: registered prefix blocks stay evictable;
        the hybrid side state dies with the sequence."""
        self._check_released()
        seq = self._seqs.pop(sid)
        self.prefix.release(seq.table, self.pool)

    def table(self, sid: int) -> BlockTable:
        self._check_released()
        return self._seqs[sid].table

    def block_of(self, sid: int, layer: int, token_index: int) -> int:
        """Pool block holding a token's KV for one layer — the layer axis
        shares the block id, so one placement covers all layers."""
        assert 0 <= layer < self.cfg.n_layers
        seq = self._seqs[sid]
        assert token_index < seq.table.num_tokens
        return seq.table.blocks[token_index // self.pool.cfg.block_size]

    # -- batch-level KVBackend API ------------------------------------------

    def prefill(self, params, tokens, frontend_emb=None):
        self._check_released()
        assert frontend_emb is None, "paged backend has no frontend state"
        old, self._batch = self._batch, []
        for sid in old:              # re-prefill replaces the batch lanes
            self.free_seq(sid)
        logits, self._batch, _ = self._add_seqs(params, np.asarray(tokens))
        return jnp.asarray(logits)[:, None, :]

    def decode_step(self, params, tokens):
        self._check_released()
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        logits = self.decode(params, self._batch, toks)
        return jnp.asarray(logits)[:, None, :]

    @property
    def lengths(self) -> np.ndarray:
        self._check_released()
        return np.asarray(
            [self._seqs[s].table.num_tokens for s in self._batch], np.int32)

    def release(self) -> None:
        for sid in list(self._seqs):
            self.free_seq(sid)
        self._batch = []
        self._k_dev = self._v_dev = None
        self._released = True


def make_backend(cfg: ModelConfig, kind: str = "dense", *,
                 batch: int = 1, max_seq: int = 0, enc_len: int = 0,
                 pool: Optional[BlockPool] = None, **kw) -> KVBackend:
    """Backend registry: "dense" | "paged"."""
    if kind == "dense":
        return DenseBackend(cfg, batch, max_seq, enc_len)
    if kind == "paged":
        if pool is None and "num_blocks" not in kw and max_seq:
            # honor the caller's capacity request: room for `batch` lanes
            # of max_seq tokens (+1 decode slot each)
            bs = kw.get("block_size", 16)
            kw["num_blocks"] = batch * (-(-(max_seq + 1) // bs))
        return PagedBackend(cfg, pool, **kw)
    raise ValueError(f"unknown KV backend kind {kind!r}")
