"""Unified KV-backend API: dense and paged serving caches, one interface.

The model (``models.lm``) speaks to its KV storage only through
``KVBackend``: ``prefill`` runs a prompt batch and stores every layer's
K/V, ``decode_step`` advances every lane one token.  Two implementations:

  DenseBackend   wraps the concrete per-layer ``lm.Cache`` pytree — the
                 training/dry-run storage.  Reads of ``.k``/``.v``/
                 ``.length`` forward to the cache, so code written against
                 the old concrete-Cache API keeps working.
  PagedBackend   per-sequence block tables over a layered ``BlockPool``
                 (one block id addresses a token-chunk's KV for *every*
                 layer — a single MARS placement decision co-locates a
                 token's per-layer blocks in one DRAM row group).  Supports
                 ragged continuous-batching decode, prefix sharing and
                 copy-on-write forks, and is what ``serve.engine`` drives.
                 Hybrid (attention + SSM) families keep their per-sequence
                 SSM/conv decode state host-side next to the block tables
                 (forked with the sequence, freed with it).

A third implementation scales the paged path across a device mesh:
``ShardedPagedBackend`` drives a ``kvcache.sharded_pool
.ShardedBlockPool`` with one complete ``PagedBackend`` per shard (own
pool, prefix cache, device mirror, optionally own mesh device) — the
kernel runs per shard over shard-local page tables, sequences never span
shards, and the scheduler routes admissions so shared prefixes co-locate.

Decode through the paged backend has two modes (``decode_mode``):

  "kernel"   the default: ``lm.paged_decode_step`` reads each layer's KV
             straight from the pool's layered page buffers via the Pallas
             ``paged_attention`` kernel (online-softmax merge of the
             in-flight token) — the MARS placement decisions *are* the
             kernel's page-walk addresses, nothing is flattened first.
             Sliding-window configs run natively: the scan flips the
             kernel's window mask per layer (``global_every`` hybrids
             keep their global layers unmasked).
  "gather"   the fallback/oracle: gather each lane's pages into a dense
             per-layer view and run the *same* ``lm.dense_decode_step``
             math as the dense backend, so gather-path logits agree with
             the dense backend bit-for-bit.

Either way the new token's K/V is extracted from the step and written
back into the pool host-side after attention (the pool mutates in place,
exactly like the single-layer engine of PR 1), so the kernel never reads
a partially-written page.  The pool buffers are staged to device through
a mirror that re-uploads only the blocks dirtied since the previous step
(``BlockPool.drain_dirty``) — never the whole pool per token.

A released backend (``release()``) raises a clear "backend released"
error from every serving entry point instead of an opaque NoneType /
KeyError; build a new backend to serve again.

Adding a backend: implement ``prefill``/``decode_step``/``lengths``/
``release`` against ``lm.prefill_parts`` (storage-agnostic prompt run)
and ``lm.dense_decode_step`` (ragged one-token step), register a
constructor in ``make_backend``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Protocol, Sequence, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache.pool import BlockPool, PoolConfig
from repro.kvcache.prefix import BlockTable, PrefixCache
from repro.models.config import ModelConfig


@runtime_checkable
class KVBackend(Protocol):
    """What the model needs from its KV storage — nothing more."""

    cfg: ModelConfig

    def prefill(self, params, tokens, frontend_emb=None):
        """Run a prompt batch and store every layer's K/V.

        Args:
          params: the model parameter tree (``lm.init(cfg).params``).
          tokens: (B, S) int32 prompt batch; replaces any lanes a prior
            ``prefill`` stored (the batch-level API serves one fixed
            batch at a time).
          frontend_emb: precomputed modality embeddings for families with
            frontends; backends that hold no frontend state reject it.
        Returns:
          last-position logits, shape (B, 1, V).
        Invariant: after the call ``lengths[b] == S`` for every lane.
        """
        ...

    def decode_step(self, params, tokens):
        """Advance every prefill lane one token.

        Args:
          params: the model parameter tree.
          tokens: (B, 1) int32 — lane ``b``'s next input token.
        Returns:
          next-token logits, shape (B, 1, V).
        Invariant: each call appends exactly one cached position per lane
        (``lengths`` increases by 1 elementwise); must follow ``prefill``.
        """
        ...

    @property
    def lengths(self) -> np.ndarray:
        """Per-lane cached token counts, int32 (B,) — what a position
        index may address in the next ``decode_step``."""
        ...

    def release(self) -> None:
        """Drop all storage (paged: decref every block back to the pool —
        registered prefix blocks stay evictable, private ones free).
        Idempotence is not promised; every subsequent entry point raises
        a clear "backend released" ``RuntimeError``."""
        ...


# ---------------------------------------------------------------------------
# Dense backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _dense_decode(params, cfg, tokens, cache):
    from repro.models import lm
    return lm.dense_decode_step(params, cfg, tokens, cache)


class DenseBackend:
    """The old concrete ``lm.Cache`` behind the backend interface."""

    def __init__(self, cfg: ModelConfig, batch: int, max_seq: int,
                 enc_len: int = 0):
        from repro.models import lm
        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self._cache = lm.init_dense_cache(cfg, batch, max_seq, enc_len)

    def _check_released(self) -> None:
        if self._cache is None:
            raise RuntimeError(
                "DenseBackend released: release() dropped the cache "
                "storage; build a new backend to serve again")

    # -- backend API --------------------------------------------------------

    def prefill(self, params, tokens, frontend_emb=None):
        """Dense prompt run: builds a fresh ``lm.Cache`` sized ``max_seq``
        and fills positions [0, S).  tokens: (B, S) int32 with
        B == ``self.batch``.  Returns last-position logits (B, 1, V)."""
        from repro.models import lm
        self._check_released()
        logits, self._cache = lm.dense_prefill(
            params, self.cfg, tokens, self.max_seq, frontend_emb)
        return logits

    def decode_step(self, params, tokens):
        """One dense decode step at slot ``length`` (jitted; the cache
        pytree is threaded functionally).  tokens: (B, 1) int32.
        Returns next-token logits (B, 1, V)."""
        self._check_released()
        logits, self._cache = _dense_decode(params, self.cfg, tokens,
                                            self._cache)
        return logits

    @property
    def lengths(self) -> np.ndarray:
        """(B,) int32 — the dense cache keeps one shared scalar length
        (all lanes advance in lockstep), broadcast to per-lane form."""
        self._check_released()
        ln = np.asarray(self._cache.length, np.int32)
        return np.broadcast_to(np.atleast_1d(ln), (self.batch,)).copy()

    def release(self) -> None:
        """Drop the cache pytree; later reads raise "backend released"."""
        self._cache = None

    # -- concrete-Cache compatibility reads ---------------------------------

    @property
    def cache(self):
        return self._cache

    def __getattr__(self, name):
        # k / v / ssm / conv / xk / xv / length forwarded to the pytree
        if name in ("k", "v", "ssm", "conv", "xk", "xv", "length"):
            if self.__dict__.get("_cache") is None:
                raise RuntimeError(
                    f"DenseBackend released: cannot read .{name} after "
                    "release(); build a new backend to serve again")
            return getattr(self._cache, name)
        raise AttributeError(name)


# ---------------------------------------------------------------------------
# Paged backend
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _paged_decode(params, cfg, tokens, k_pages, v_pages, page_tables,
                  lengths, ssm, conv):
    """Gather each lane's pages into a dense per-layer view, run the ragged
    dense decode step, and extract the new token's K/V for write-back.

    k/v_pages: (L, P, page, K, dh); page_tables: (B, n_pages) int32;
    lengths: (B,) int32 — the padded view always has room for slot
    ``lengths[b]`` (the backend pads the table before calling).
    ssm/conv: hybrid side state (L, B, H, P, N) / (L, B, k-1, ch), or
    None for attention-only families.
    Returns (logits, k_new (L, B, 1, K, dh), v_new, ssm_new, conv_new).
    """
    from repro.models import lm
    L = k_pages.shape[0]
    K, dh = k_pages.shape[-2:]
    B = tokens.shape[0]
    k = k_pages[:, page_tables].reshape(L, B, -1, K, dh)
    v = v_pages[:, page_tables].reshape(L, B, -1, K, dh)
    cache = lm.Cache(k=k, v=v, ssm=ssm, conv=conv, xk=None, xv=None,
                     length=lengths)
    logits, new = lm.dense_decode_step(params, cfg, tokens, cache)
    idx = lengths.astype(jnp.int32)[None, :, None, None, None]
    k_new = jnp.take_along_axis(new.k, idx, axis=2)
    v_new = jnp.take_along_axis(new.v, idx, axis=2)
    return logits, k_new, v_new, new.ssm, new.conv


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def _paged_decode_kernel(params, cfg, tokens, k_pages, v_pages,
                         page_tables, lengths, ssm, conv, interpret=True):
    """Kernel-path decode: per-layer Pallas paged attention straight over
    the pool's layered page buffers (no dense gather).  Same operand and
    result shapes as ``_paged_decode``."""
    from repro.models import lm
    return lm.paged_decode_step(params, cfg, tokens, k_pages, v_pages,
                                page_tables, lengths, ssm_state=ssm,
                                conv_state=conv, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_prefill_parts(params, cfg, tokens):
    from repro.models import lm
    return lm.prefill_parts(params, cfg, tokens)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks(dev, idx, vals):
    """Write dirty block planes into the device mirror.  The mirror is
    donated so XLA updates it in place — no pool-sized device copy per
    step.  ``idx`` may repeat (pow2 padding); duplicate indices write the
    same value twice, harmlessly."""
    return dev.at[:, idx].set(vals)


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class _PagedSeq:
    sid: int
    table: BlockTable
    tokens: list            # tokens whose KV is cached
    # hybrid side state the pool cannot hold: per-sequence SSM recurrent
    # state (L, H, P, N) float32 and conv trailing context (L, k-1, ch),
    # host-side, forked with the sequence, freed with it
    ssm: Optional[np.ndarray] = None
    conv: Optional[np.ndarray] = None


class PagedBackend:
    """Per-sequence block tables over a layered ``BlockPool``.

    Sequence-level API (what the serve engine drives): ``new_seq`` /
    ``fork_seq`` / ``decode`` / ``free_seq``.  The batch-level
    ``KVBackend`` API (``prefill`` / ``decode_step``) runs the same
    machinery over a fixed batch, giving drop-in parity with
    ``DenseBackend``.

    Prompt K/V is always recomputed (prefill logits need the full
    context); prefix sharing is at the *storage* level — matched blocks
    are referenced instead of re-allocated, which is what bounds pool
    occupancy under hot prefixes.
    """

    def __init__(self, cfg: ModelConfig, pool: Optional[BlockPool] = None,
                 *, num_blocks: int = 256, block_size: int = 16,
                 placement: str = "mars", eviction: str = "fifo",
                 share_prefixes: bool = True, decode_mode: str = "kernel",
                 kernel_interpret: bool = True, device=None,
                 tiered: bool = False, tier_specs=None):
        """Build a paged backend over ``pool`` (or a fresh pool sized by
        ``num_blocks``/``block_size`` matching the model config).

        Args:
          cfg: model config; must be an attention-bearing decoder-only
            family (encoder-decoder / VLM state is not paged yet).
          pool: existing layered ``BlockPool`` to share; its KV buffer
            shape must match ``cfg`` (asserted).
          placement/eviction: pool policies when building a fresh pool
            ("cost" eviction pairs naturally with ``tiered``: the tier
            manager installs its recompute-vs-refetch scoring hook).
          share_prefixes: storage-level prefix sharing via ``PrefixCache``.
          decode_mode: "kernel" (Pallas paged_attention per layer, the
            default) or "gather" (dense-view oracle).
          kernel_interpret: run the Pallas kernel in interpret mode
            (CPU/CI); pass False on real TPU.
          device: jax device the staged KV mirror and decode operands are
            committed to; ``None`` uses the default device.  A mesh-
            sharded deployment (``ShardedPagedBackend``) gives each
            shard's backend its own device.
          tiered: put host/mock-remote spill tiers behind the pool
            (``kvcache.tiers.TierManager``): eviction demotes registered
            prefix blocks instead of dropping them, and prefix misses
            that hit a lower tier promote blocks back through a
            MARS-reordered batched copy-in.  Requires prefix sharing.
          tier_specs: ``TierSpec`` sequence overriding
            ``tiers.default_tiers`` (capacity / latency / bandwidth).
        """
        if not cfg.has_attention or cfg.enc_layers \
                or cfg.family in ("encdec", "vlm"):
            raise NotImplementedError(
                f"PagedBackend pages attention KV plus per-sequence "
                f"SSM/conv decode state; family {cfg.family!r} needs "
                f"state the pool does not hold yet (encoder KV / "
                f"frontend prefixes, or has no attention KV at all)")
        if decode_mode not in ("kernel", "gather"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        self.decode_mode = decode_mode
        self.kernel_interpret = kernel_interpret
        self.device = device
        self.cfg = cfg
        if pool is None:
            pool = BlockPool(PoolConfig(
                num_blocks=num_blocks, block_size=block_size,
                placement=placement, eviction=eviction,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.d_head,
                n_layers=cfg.n_layers, dtype=str(cfg.kvdtype)))
        assert pool.k_pages is not None, "paged backend needs a KV pool"
        assert pool.cfg.n_layers == cfg.n_layers \
            and pool.cfg.n_kv_heads == cfg.n_kv_heads \
            and pool.cfg.head_dim == cfg.d_head, \
            "pool KV buffer does not match the model config"
        self.pool = pool
        self.prefix = PrefixCache(pool.cfg.block_size)
        if share_prefixes:
            self.prefix.attach(pool)
        self.share_prefixes = share_prefixes
        # tiered KV memory: demote-on-evict / promote-on-miss behind the
        # pool (kvcache.tiers).  The manager interposes on pool.on_evict
        # AFTER prefix.attach so demotion captures the payload before
        # the prefix cache unregisters the block.
        self.tiers = None
        if tiered:
            assert share_prefixes, \
                "tiered KV spills registered prefix blocks; enable " \
                "share_prefixes"
            from repro.kvcache.tiers import TierManager
            self.tiers = TierManager(pool, self.prefix, tier_specs)
        self._seqs: dict[int, _PagedSeq] = {}
        self._next_sid = 0
        self._batch: list[int] = []      # batch-level API lane order
        self._released = False
        # telemetry (obs.Observer.attach): spans + the live row-locality
        # feed; obs_shard tags events with this backend's shard index
        self.obs = None
        self.obs_shard = 0
        # device mirror of the pool's KV buffers: decode re-stages only
        # blocks dirtied since the previous step (this backend is the
        # pool's single drain_dirty consumer)
        self._k_dev = self._v_dev = None
        self.staged_blocks_last_step = 0

    def _check_released(self) -> None:
        if self._released:
            raise RuntimeError(
                "PagedBackend released: release() returned every block "
                "to the pool; build a new backend to serve again")

    # -- device staging ------------------------------------------------------

    def _put(self, x):
        """Commit an operand to this backend's device (default device when
        unset) — per-shard backends keep their mirrors and decode inputs
        on their own mesh device."""
        a = jnp.asarray(x)
        return a if self.device is None else jax.device_put(a, self.device)

    def _staged_pages(self):
        """Stage the pool's host-mutated KV buffers to device, uploading
        only blocks written since the last call (full upload first time).
        ``staged_blocks_last_step`` records how many blocks moved."""
        pool = self.pool
        if self._k_dev is None:
            pool.drain_dirty()           # full upload covers everything
            self._k_dev = self._put(pool.k_pages)
            self._v_dev = self._put(pool.v_pages)
            self.staged_blocks_last_step = pool.cfg.num_blocks
        else:
            dirty = pool.drain_dirty()
            self.staged_blocks_last_step = len(dirty)
            if dirty:
                # pad the id list to a power of two (repeating the last
                # id) so the donated scatter compiles O(log) variants
                pad = dirty + [dirty[-1]] * (_pow2(len(dirty)) - len(dirty))
                idx = self._put(np.asarray(pad, np.int32))
                self._k_dev = _scatter_blocks(
                    self._k_dev, idx, self._put(pool.k_pages[:, pad]))
                self._v_dev = _scatter_blocks(
                    self._v_dev, idx, self._put(pool.v_pages[:, pad]))
        if self.obs is not None:
            self.obs.trace.event("backend.stage", shard=self.obs_shard,
                                 blocks=self.staged_blocks_last_step)
        return self._k_dev, self._v_dev

    # -- sequence-level API (continuous batching) ---------------------------

    def new_seq(self, params, prompt: Sequence[int],
                on_alloc: Optional[Callable[[int, int], None]] = None
                ) -> tuple[int, Any, int]:
        """Prefill one sequence into the pool.

        Args:
          params: model parameter tree.
          prompt: token ids; the prompt's full-block prefix is matched
            against the prefix cache first (matched blocks are referenced,
            not re-stored).
          on_alloc: callback ``(sid, n_fresh_blocks)`` fired once with the
            number of blocks this prefill actually allocated (the engine
            converts admission reservations into claims with it).
        Returns:
          (sid, last-position logits (V,) float32, shared-prefix tokens).
        Invariant: atomic under pool exhaustion — on RuntimeError nothing
        stays live (see ``_add_seqs``).
        """
        logits, sids, shared = self._add_seqs(
            params, np.asarray([list(prompt)], np.int32), on_alloc)
        return sids[0], logits[0], shared[0]

    def _add_seqs(self, params, tokens: np.ndarray,
                  on_alloc=None) -> tuple[Any, list[int], list[int]]:
        """Batched prompt prefill -> one new sequence per row.

        Atomic under pool exhaustion: if any row's ``table.extend``
        raises, the partial table (prefix-matched increfed blocks plus
        blocks allocated before the failure) is decref'd back and rows
        already added by this call are freed, then the error re-raises —
        nothing stays live.
        """
        self._check_released()
        if self.obs is not None:
            with self.obs.trace.span("backend.prefill",
                                     shard=self.obs_shard,
                                     rows=int(tokens.shape[0])) as sp:
                out = self._add_seqs_impl(params, tokens, on_alloc)
                sp["shared_tokens"] = int(sum(out[2]))
                return out
        return self._add_seqs_impl(params, tokens, on_alloc)

    def _add_seqs_impl(self, params, tokens: np.ndarray,
                       on_alloc=None) -> tuple[Any, list[int], list[int]]:
        B, S = tokens.shape
        logits, parts = _jit_prefill_parts(
            params, self.cfg, jnp.asarray(tokens, jnp.int32))
        kvd = self.cfg.kvdtype
        k_all = np.asarray(parts["k"].astype(kvd))   # (L, B, S, K, dh)
        v_all = np.asarray(parts["v"].astype(kvd))
        ssm_all = conv_all = None
        if self.cfg.has_ssm:
            ssm_all = np.asarray(parts["ssm"], np.float32)
            conv_all = np.asarray(parts["conv"])
        sids, shared = [], []
        for b in range(B):
            prompt = [int(t) for t in tokens[b]]
            if not self.share_prefixes:
                bids, n = [], 0
            elif self.tiers is not None:
                # tier-aware match: in-pool chain first, then promotable
                # lower-tier blocks — copy-ins queue in the manager's
                # lookahead buffer and land batched (flushed below)
                bids, n = self.tiers.match(prompt)
            else:
                bids, n = self.prefix.match(prompt, self.pool)
            table = BlockTable(list(bids), n)
            allocs0 = self.pool.stats.allocs
            try:
                table.extend(
                    self.pool, prompt[n:], seq_tokens=prompt,
                    cache=self.prefix if self.share_prefixes else None,
                    kv=(k_all[:, b, n:], v_all[:, b, n:]))
            except RuntimeError:
                # roll back: queued promotions first (their destination
                # blocks are released with the tables below; the tier
                # entries were never removed), then this row's partial
                # table (registered blocks stay as evictable cache,
                # private ones free), then the rows this call already
                # created — batched prefill is all-or-nothing
                if self.tiers is not None:
                    self.tiers.cancel_promotions()
                self.prefix.release(table, self.pool)
                for sid in sids:
                    self.free_seq(sid)
                raise
            sid = self._next_sid
            self._next_sid += 1
            seq = _PagedSeq(sid, table, list(prompt))
            if ssm_all is not None:
                seq.ssm = np.ascontiguousarray(ssm_all[:, b])
                seq.conv = np.ascontiguousarray(conv_all[:, b])
            self._seqs[sid] = seq
            if on_alloc is not None:
                on_alloc(sid, self.pool.stats.allocs - allocs0)
            sids.append(sid)
            shared.append(n)
        if self.tiers is not None:
            # the whole batch's promotions land in one MARS-reordered
            # copy-in; the dirtied blocks re-stage to the device mirror
            # before the next decode step touches them
            self.tiers.flush_promotions()
        return np.asarray(logits[:, 0], np.float32), sids, shared

    def fork_seq(self, sid: int) -> int:
        """Fork a sequence, sharing every block (CoW on first append);
        the hybrid side state is copied — it is mutated every step."""
        self._check_released()
        src = self._seqs[sid]
        nsid = self._next_sid
        self._next_sid += 1
        self._seqs[nsid] = _PagedSeq(
            nsid, src.table.fork(self.pool), list(src.tokens),
            ssm=None if src.ssm is None else src.ssm.copy(),
            conv=None if src.conv is None else src.conv.copy())
        return nsid

    def decode(self, params, sids: Sequence[int], tokens: Sequence[int],
               on_alloc: Optional[Callable[[int, int], None]] = None):
        """One ragged decode step over live sequences.

        Args:
          sids: sequences to advance (any subset of the live set, each at
            its own length).
          tokens: ``tokens[i]`` is fed to ``sids[i]``.
          on_alloc: per-sequence callback ``(sid, n_fresh_blocks)`` — a
            lane allocates at most one block per step (new tail or CoW).
        Returns:
          next-token logits, float32 (len(sids), V), row-aligned to sids.
        Invariants: the new K/V is written back host-side *after* the
        step (the kernel never reads a half-written page); a capacity
        precheck makes the step all-or-nothing — on "pool exhausted"
        every sequence is exactly as it was.
        """
        self._check_released()
        assert sids, "no active sequences to decode (prefill first)"
        if self.obs is not None:
            with self.obs.trace.span("backend.decode",
                                     shard=self.obs_shard,
                                     lanes=len(sids)) as sp:
                out = self._decode_impl(params, sids, tokens, on_alloc)
                sp["staged"] = self.staged_blocks_last_step
                return out
        return self._decode_impl(params, sids, tokens, on_alloc)

    def _decode_impl(self, params, sids, tokens, on_alloc=None):
        from repro.kernels.paged_attention import ops
        # tier contract: every queued promotion flushed (copy-in complete,
        # block dirtied for staging) before a promoted page can enter a
        # decode batch — prefill flushes per batch, so the queue must be
        # empty here
        assert self.tiers is None or self.tiers.pending == 0, \
            "unflushed tier promotions entering a decode batch"
        seqs = [self._seqs[s] for s in sids]
        B = len(seqs)
        page = self.pool.cfg.block_size
        # padded page-table view: every lane needs room for slot len(seq)
        # on the gather path (the kernel path attends the in-flight token
        # out of registers, but shares the padding so both compile alike)
        n_pages = _pow2(max(
            -(-(len(s.tokens) + 1) // page) for s in seqs))
        Bp = _pow2(B)                       # lane padding bounds recompiles
        pt, lengths = ops.pool_page_tables(
            [s.table for s in seqs], pad_to=n_pages, pad_lanes=Bp)
        toks = np.zeros((Bp, 1), np.int32)
        toks[:B, 0] = list(tokens)
        kp, vp = self._staged_pages()
        if self.obs is not None:
            # live row-locality: this step's page walk in kernel issue
            # order (sequence-major, page-contiguous — the MARS-reordered
            # stream; defined the same way on the gather path so the
            # gauge is mode-independent), fed to this shard's open-row
            # model
            self.obs.observe_kv_walk(
                self.obs_shard,
                ops.kv_read_trace_kernel([s.table for s in seqs],
                                         block_size=page))
        ssm = conv = None
        if self.cfg.has_ssm:
            # batch the per-sequence hybrid side state (padded lanes get
            # zeros; their outputs are discarded below)
            L = self.cfg.n_layers
            ssm_np = np.zeros((L, Bp) + seqs[0].ssm.shape[1:],
                              seqs[0].ssm.dtype)
            conv_np = np.zeros((L, Bp) + seqs[0].conv.shape[1:],
                               seqs[0].conv.dtype)
            for i, s in enumerate(seqs):
                ssm_np[:, i] = s.ssm
                conv_np[:, i] = s.conv
            ssm = self._put(ssm_np)
            conv = self._put(conv_np)
        if self.decode_mode == "kernel":
            logits, k_new, v_new, ssm_new, conv_new = _paged_decode_kernel(
                params, self.cfg, self._put(toks), kp, vp,
                self._put(pt), self._put(lengths), ssm, conv,
                interpret=self.kernel_interpret)
        else:
            logits, k_new, v_new, ssm_new, conv_new = _paged_decode(
                params, self.cfg, self._put(toks), kp, vp,
                self._put(pt), self._put(lengths), ssm, conv)
        k_new = np.asarray(k_new)           # (L, Bp, 1, K, dh)
        v_new = np.asarray(v_new)
        if ssm_new is not None:
            ssm_new = np.asarray(ssm_new)   # (L, Bp, H, P, N)
            conv_new = np.asarray(conv_new)
        # capacity precheck so the write-back loop cannot die halfway
        # (rolling back a committed lane would mean undoing CoW/eviction
        # side effects): each lane needs at most one fresh block — a new
        # tail, or a CoW copy of a shared tail.  Raising here leaves
        # every sequence exactly as it was before the step.
        need = 0
        for s in seqs:
            fill = s.table.num_tokens % page
            if fill == 0 or \
                    self.pool.refcount[s.table.blocks[-1]] > 1:
                need += 1
        if not self.pool.can_alloc(need):
            raise RuntimeError(
                f"pool exhausted: decode step needs {need} blocks, "
                f"free {self.pool.num_free}, cached {self.pool.num_cached}")
        for i, (s, tok) in enumerate(zip(seqs, tokens)):
            allocs0 = self.pool.stats.allocs
            new_tokens = s.tokens + [int(tok)]
            s.table.extend(
                self.pool, [int(tok)], seq_tokens=new_tokens,
                cache=self.prefix if self.share_prefixes else None,
                kv=(k_new[:, i], v_new[:, i]))
            s.tokens = new_tokens     # commit only after the extend
            if ssm_new is not None:
                s.ssm = np.ascontiguousarray(ssm_new[:, i])
                s.conv = np.ascontiguousarray(conv_new[:, i])
            if on_alloc is not None:
                on_alloc(s.sid, self.pool.stats.allocs - allocs0)
        return np.asarray(logits[:B, 0], np.float32)

    def free_seq(self, sid: int) -> None:
        """Finished sequence: registered prefix blocks stay evictable;
        the hybrid side state dies with the sequence."""
        self._check_released()
        seq = self._seqs.pop(sid)
        self.prefix.release(seq.table, self.pool)

    def table(self, sid: int) -> BlockTable:
        self._check_released()
        return self._seqs[sid].table

    def block_of(self, sid: int, layer: int, token_index: int) -> int:
        """Pool block holding a token's KV for one layer — the layer axis
        shares the block id, so one placement covers all layers."""
        assert 0 <= layer < self.cfg.n_layers
        seq = self._seqs[sid]
        assert token_index < seq.table.num_tokens
        return seq.table.blocks[token_index // self.pool.cfg.block_size]

    # -- batch-level KVBackend API ------------------------------------------

    def prefill(self, params, tokens, frontend_emb=None):
        """Protocol ``prefill``: one new sequence per row of the (B, S)
        batch, freeing any lanes a prior call created.  Returns
        last-position logits (B, 1, V)."""
        self._check_released()
        assert frontend_emb is None, "paged backend has no frontend state"
        old, self._batch = self._batch, []
        for sid in old:              # re-prefill replaces the batch lanes
            self.free_seq(sid)
        logits, self._batch, _ = self._add_seqs(params, np.asarray(tokens))
        return jnp.asarray(logits)[:, None, :]

    def decode_step(self, params, tokens):
        """Protocol ``decode_step``: advance the prefill lanes one token
        (tokens (B, 1) int32, row order = prefill row order).  Returns
        next-token logits (B, 1, V)."""
        self._check_released()
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        logits = self.decode(params, self._batch, toks)
        return jnp.asarray(logits)[:, None, :]

    @property
    def lengths(self) -> np.ndarray:
        """(B,) int32 cached token count per prefill lane — genuinely
        ragged (unlike the dense backend's broadcast scalar)."""
        self._check_released()
        return np.asarray(
            [self._seqs[s].table.num_tokens for s in self._batch], np.int32)

    def release(self) -> None:
        """Free every live sequence (registered prefix blocks stay as
        evictable cache), drop the device mirror, and poison the backend:
        all later entry points raise "backend released"."""
        for sid in list(self._seqs):
            self.free_seq(sid)
        self._batch = []
        self._k_dev = self._v_dev = None
        self._released = True


# ---------------------------------------------------------------------------
# Mesh-sharded paged backend
# ---------------------------------------------------------------------------

class ShardedPagedBackend:
    """One ``PagedBackend`` per shard of a ``ShardedBlockPool``.

    Each shard owns a complete serving stack: its own block pool, prefix
    cache, staged-dirty device mirror, and — when ``devices`` are given —
    its own mesh device, so ``lm.paged_decode_step`` runs the kernel
    **per shard over shard-local pools** (per-shard page tables; no
    global block-id space exists).  A sequence lives entirely on one
    shard: ``fork_seq`` forks within the parent's shard (CoW stays
    shard-local) and prefix sharing only ever matches blocks the same
    shard stored — which is why the scheduler routes shared prefixes to
    one shard in the first place.

    Sequence ids handed out here are backend-global; the mapping to
    (shard, inner sid) is internal.  ``decode`` accepts any mix of
    sequences, groups them by shard, runs one ragged kernel step per
    shard, and reassembles logits in call order — so the engine's lane
    loop is shard-agnostic.  The batch-level ``KVBackend`` API routes
    prefill rows to the least-loaded shard, giving drop-in parity with
    ``DenseBackend``/``PagedBackend``.
    """

    def __init__(self, cfg: ModelConfig, pool=None, *,
                 n_shards: Optional[int] = None, mesh=None,
                 devices: Optional[Sequence] = None,
                 num_blocks: int = 256, block_size: int = 16,
                 placement: str = "mars", eviction: str = "fifo", **kw):
        """Args:
          pool: a ``ShardedBlockPool`` to drive, or None to build one
            (``num_blocks`` total across shards).
          n_shards/mesh: shard-count discovery when building the pool —
            forwarded to ``ShardedBlockPool`` (mesh model axis; 1
            without a mesh).
          devices: per-shard jax devices for the staged mirrors + decode
            (length ``n_shards``; entries may repeat when fewer devices
            than shards exist).  None keeps everything on the default
            device — pool sharding still partitions placement.
          num_blocks: total capacity request when building a pool; it is
            rounded *up* to a multiple of the shard count, so any
            capacity request is honored.
          Remaining kwargs (decode_mode, kernel_interpret,
          share_prefixes, ...) configure every per-shard backend alike.
        """
        from repro.kvcache.sharded_pool import ShardedBlockPool, \
            discover_shards
        if pool is None:
            n_shards = discover_shards(n_shards, mesh)
            num_blocks = -(-num_blocks // n_shards) * n_shards
            pool = ShardedBlockPool(
                PoolConfig(num_blocks=num_blocks, block_size=block_size,
                           placement=placement, eviction=eviction,
                           n_kv_heads=cfg.n_kv_heads, head_dim=cfg.d_head,
                           n_layers=cfg.n_layers, dtype=str(cfg.kvdtype)),
                n_shards=n_shards, mesh=mesh)
        assert isinstance(pool, ShardedBlockPool), \
            "ShardedPagedBackend needs a ShardedBlockPool"
        if devices is not None:
            assert len(devices) == pool.n_shards, \
                (len(devices), pool.n_shards)
        self.cfg = cfg
        self.pool = pool
        self.backends = [
            PagedBackend(cfg, shard_pool,
                         device=None if devices is None else devices[i],
                         **kw)
            for i, shard_pool in enumerate(pool.shards)]
        self._seqs: dict[int, tuple[int, int]] = {}   # gsid -> (shard, isid)
        self._rev: dict[tuple[int, int], int] = {}    # (shard, isid) -> gsid
        self._next_sid = 0
        self._batch: list[int] = []
        self._released = False

    def _check_released(self) -> None:
        if self._released:
            raise RuntimeError(
                "ShardedPagedBackend released: release() returned every "
                "block to its shard pool; build a new backend to serve "
                "again")

    # decode_mode / kernel staging reads mirror PagedBackend's so the
    # engine's use_kernel override and the staging tests stay backend-
    # -agnostic (setter fans out to every shard)

    @property
    def decode_mode(self) -> str:
        return self.backends[0].decode_mode

    @decode_mode.setter
    def decode_mode(self, mode: str) -> None:
        if mode not in ("kernel", "gather"):
            raise ValueError(f"unknown decode_mode {mode!r}")
        for b in self.backends:
            b.decode_mode = mode

    @property
    def staged_blocks_last_step(self) -> int:
        return sum(b.staged_blocks_last_step for b in self.backends)

    # -- sequence-level API (what the serve engine drives) ------------------

    def new_seq(self, params, prompt: Sequence[int],
                on_alloc: Optional[Callable[[int, int], None]] = None,
                shard: Optional[int] = None) -> tuple[int, Any, int]:
        """Prefill one sequence on one shard.

        Args:
          shard: the routed shard (what ``MarsScheduler`` stamped on the
            request via ``ShardedBlockPool.route``); None picks the
            least-loaded shard (direct API use, no scheduler in front).
        Returns/invariants: as ``PagedBackend.new_seq`` — additionally,
        every block of the sequence lives in ``pool.shards[shard]``.
        """
        self._check_released()
        if shard is None:
            shard = self.pool.least_loaded()
        assert 0 <= shard < self.pool.n_shards, shard
        gsid = self._next_sid
        self._next_sid += 1
        cb = None if on_alloc is None else \
            (lambda _isid, n: on_alloc(gsid, n))
        isid, logits, shared = self.backends[shard].new_seq(
            params, prompt, on_alloc=cb)
        self._seqs[gsid] = (shard, isid)
        self._rev[(shard, isid)] = gsid
        return gsid, logits, shared

    def fork_seq(self, sid: int) -> int:
        """Fork within the parent's shard — CoW forks are shard-local by
        construction (blocks of one pool cannot be referenced from
        another)."""
        self._check_released()
        shard, isid = self._seqs[sid]
        nisid = self.backends[shard].fork_seq(isid)
        gsid = self._next_sid
        self._next_sid += 1
        self._seqs[gsid] = (shard, nisid)
        self._rev[(shard, nisid)] = gsid
        return gsid

    def decode(self, params, sids: Sequence[int], tokens: Sequence[int],
               on_alloc: Optional[Callable[[int, int], None]] = None):
        """One ragged decode round across shards: group ``sids`` by
        shard, run one ``PagedBackend.decode`` (one kernel invocation
        over that shard's pool) per shard, reassemble logits in call
        order.  Returns float32 (len(sids), V) row-aligned to sids.

        All-or-nothing across shards, like ``PagedBackend.decode`` is
        within one: every shard's worst-case block need is prechecked
        before ANY shard commits its write-back, so a "pool exhausted"
        raise leaves every sequence — on every shard — exactly as it
        was (no lane double-appends KV on a retry)."""
        self._check_released()
        assert sids, "no active sequences to decode (prefill first)"
        by_shard: dict[int, list[int]] = {}
        for i, s in enumerate(sids):
            by_shard.setdefault(self._seqs[s][0], []).append(i)
        # cross-shard capacity precheck (mirrors PagedBackend.decode's):
        # each lane needs at most one fresh block — a new tail, or a CoW
        # copy of a shared tail
        page = self.pool.cfg.block_size
        for shard, idxs in by_shard.items():
            inner = self.backends[shard]
            need = 0
            for i in idxs:
                t = inner._seqs[self._seqs[sids[i]][1]].table
                fill = t.num_tokens % page
                if fill == 0 or inner.pool.refcount[t.blocks[-1]] > 1:
                    need += 1
            if not inner.pool.can_alloc(need):
                raise RuntimeError(
                    f"pool exhausted on shard {shard}: decode step needs "
                    f"{need} blocks, free {inner.pool.num_free}, "
                    f"cached {inner.pool.num_cached}")
        rows: dict[int, np.ndarray] = {}
        for shard, idxs in sorted(by_shard.items()):
            cb = None if on_alloc is None else \
                (lambda isid, n, _s=shard:
                 on_alloc(self._rev[(_s, isid)], n))
            lg = self.backends[shard].decode(
                params, [self._seqs[sids[i]][1] for i in idxs],
                [tokens[i] for i in idxs], on_alloc=cb)
            for j, i in enumerate(idxs):
                rows[i] = lg[j]
        return np.stack([rows[i] for i in range(len(sids))])

    def free_seq(self, sid: int) -> None:
        """Release a finished sequence back to its shard's pool."""
        self._check_released()
        shard, isid = self._seqs.pop(sid)
        del self._rev[(shard, isid)]
        self.backends[shard].free_seq(isid)

    def table(self, sid: int) -> BlockTable:
        self._check_released()
        shard, isid = self._seqs[sid]
        return self.backends[shard].table(isid)

    def shard_of(self, sid: int) -> int:
        """Shard a live sequence's blocks occupy — the leading coordinate
        of its placement key (``placement.placement_key``)."""
        self._check_released()
        return self._seqs[sid][0]

    # -- tiered KV memory (per-shard tiers, demotion/promotion shard-local) --

    @property
    def tiered(self) -> bool:
        """True iff the per-shard backends carry spill tiers (the
        ``tiered=`` kwarg fans out to every shard: one ``TierManager``
        per shard pool, so demoted payloads never cross shards)."""
        return self.backends[0].tiers is not None

    def tier_shard_for(self, prompt: Sequence[int]) -> Optional[int]:
        """Shard whose spill tiers hold the prompt's first full prefix
        block, or ``None`` — the promotable lower-tier prefix hit the
        scheduler may count toward affinity routing
        (``MarsScheduler.tier_probe``).  Routing a request here turns a
        would-be recompute into a shard-local promotion."""
        self._check_released()
        for i, b in enumerate(self.backends):
            if b.tiers is not None and b.tiers.holds_prefix(prompt):
                return i
        return None

    # -- batch-level KVBackend API ------------------------------------------

    def prefill(self, params, tokens, frontend_emb=None):
        """Protocol ``prefill``: rows route greedily to the least-loaded
        shard (load measured in blocks, each row charged its block need —
        the batch API has no prefix pages to be affine to), then each
        shard prefills its rows in one batched call.  Atomic across
        shards like ``PagedBackend._add_seqs`` is within one: if a later
        shard exhausts its pool, rows already prefilled on earlier shards
        are freed before the error re-raises — nothing stays live.
        Returns last-position logits (B, 1, V) in row order."""
        self._check_released()
        assert frontend_emb is None, "paged backend has no frontend state"
        old, self._batch = self._batch, []
        for sid in old:
            self.free_seq(sid)
        tokens = np.asarray(tokens)
        B = tokens.shape[0]
        # same unit as pool.load (blocks): a row stores S prompt tokens.
        # Shard ranking comes from the shared load snapshot (the same
        # numbers ShardedBlockPool.route and the obs gauges use).
        from repro.obs.observer import shard_load_snapshot
        row_blocks = -(-tokens.shape[1] // self.pool.cfg.block_size)
        load = [r["load"] for r in shard_load_snapshot(self.pool)]
        plan: dict[int, list[int]] = {}
        for i in range(B):
            s = min(range(self.pool.n_shards),
                    key=lambda x: (load[x], x))
            plan.setdefault(s, []).append(i)
            load[s] += row_blocks
        out = np.zeros((B, self.cfg.vocab), np.float32)
        gsids: dict[int, int] = {}
        for shard, idxs in sorted(plan.items()):
            try:
                lg, isids, _ = self.backends[shard]._add_seqs(
                    params, tokens[idxs])
            except RuntimeError:
                # the failing shard rolled itself back; free the rows
                # earlier shards already created, then surface the error
                for gsid in gsids.values():
                    self.free_seq(gsid)
                raise
            for j, i in enumerate(idxs):
                out[i] = lg[j]
                gsid = self._next_sid
                self._next_sid += 1
                self._seqs[gsid] = (shard, isids[j])
                self._rev[(shard, isids[j])] = gsid
                gsids[i] = gsid
        self._batch = [gsids[i] for i in range(B)]
        return jnp.asarray(out)[:, None, :]

    def decode_step(self, params, tokens):
        """Protocol ``decode_step`` over the prefill lanes (see
        ``PagedBackend.decode_step``); lanes decode on their own shards.
        Returns next-token logits (B, 1, V)."""
        self._check_released()
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        logits = self.decode(params, self._batch, toks)
        return jnp.asarray(logits)[:, None, :]

    @property
    def lengths(self) -> np.ndarray:
        """(B,) int32 cached token count per prefill lane."""
        self._check_released()
        return np.asarray([self.table(s).num_tokens for s in self._batch],
                          np.int32)

    def release(self) -> None:
        """Release every shard backend; later entry points raise."""
        for b in self.backends:
            b.release()
        self._seqs.clear()
        self._rev.clear()
        self._batch = []
        self._released = True


def make_backend(cfg: ModelConfig, kind: str = "dense", *,
                 batch: int = 1, max_seq: int = 0, enc_len: int = 0,
                 pool: Optional[BlockPool] = None, **kw) -> KVBackend:
    """Backend registry: "dense" | "paged" | "sharded-paged".

    Args:
      batch/max_seq: capacity request — dense allocates (B, max_seq)
        directly; paged kinds size the pool to hold ``batch`` lanes of
        ``max_seq`` tokens (+1 decode slot each) unless ``num_blocks`` or
        an explicit ``pool`` overrides it.
      pool: concrete storage to share (``BlockPool`` for "paged",
        ``ShardedBlockPool`` for "sharded-paged").
      Remaining kwargs forward to the backend constructor.
    Returns: an object satisfying the ``KVBackend`` protocol.

    >>> make_backend(None, "holographic")
    Traceback (most recent call last):
        ...
    ValueError: unknown KV backend kind 'holographic'
    """
    if kind == "dense":
        return DenseBackend(cfg, batch, max_seq, enc_len)
    if kind in ("paged", "sharded-paged"):
        size_request = pool is None and "num_blocks" not in kw and max_seq
        # honor the caller's capacity request: room for `batch` lanes of
        # max_seq tokens (+1 decode slot each)
        bs = kw.get("block_size", 16)
        lane_blocks = -(-(max_seq + 1) // bs)
        if kind == "paged":
            if size_request:
                kw["num_blocks"] = batch * lane_blocks
            return PagedBackend(cfg, pool, **kw)
        if size_request:
            from repro.kvcache.sharded_pool import discover_shards
            n = kw["n_shards"] = discover_shards(kw.get("n_shards"),
                                                 kw.get("mesh"))
            # a lane never spans shards, so splitting batch*lane_blocks
            # evenly would under-size shards whenever n does not divide
            # batch: every shard must hold its share of WHOLE lanes
            kw["num_blocks"] = n * (-(-batch // n)) * lane_blocks
        return ShardedPagedBackend(cfg, pool, **kw)
    raise ValueError(f"unknown KV backend kind {kind!r}")
