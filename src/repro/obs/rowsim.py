"""Incremental open-row model: live row-buffer hit accounting.

``core/dram.simulate`` answers "what row-hit rate did this trace get?"
by replaying the whole address stream through the jitted FR-FCFS timing
model — fine for benches, far too heavy for every decode step.  This
module is the hit-accounting half of that controller extracted into an
*incremental* counter: same channel split (``dram.split_channels``),
same bank hash and row decode (``dram.decode_lines``), same per-bank
open-row registers, but no timing — just "would this access have hit the
open row?", carried across ``observe()`` calls so the serving stack can
publish a running row-hit % gauge.

Two serve-order models:

  * ``window=1`` (default): in-order service, fully vectorized numpy —
    a stable sort groups each batch by bank and compares every access's
    row against its predecessor in the same bank (the persistent open
    row for the first of each bank group).  For the kernel decode path's
    page walk (``ops.kv_read_trace_kernel`` — sequence-major, page-
    contiguous) in-order service is *exactly* what the FR-FCFS window
    produces: the stream has no interleaving left for lookahead to
    reorder, so the live gauge matches ``dram.simulate`` replay to the
    digit (pinned within 0.1% by ``tests/test_obs.py``).  Cost is
    O(n log n) per step, ~tens of microseconds for a decode walk.
  * ``window=W>1``: a faithful Python replay of the controller's
    FR-FCFS pick (row hits first, oldest first, inside a W-entry
    pending window).  O(W) per access — verification tool for arbitrary
    interleaved traces (e.g. the gather path's round-robin stream,
    where in-order and windowed service genuinely diverge), not a hot
    path.  Windowed mode buffers up to W accesses; call ``drain()``
    before reading final counts.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from repro.core.dram import DramConfig, decode_lines, split_channels


class OpenRowCounter:
    """Running row-hit counter over an incrementally observed 64B-line
    address stream (same address map as ``core/dram.py``)."""

    def __init__(self, cfg: Optional[DramConfig] = None, window: int = 1):
        self.cfg = cfg or DramConfig()
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self.hits = 0
        self.served = 0
        # persistent per-(channel, bank) open row; -1 = closed
        self._open = np.full((self.cfg.n_channels, self.cfg.n_banks),
                             -1, np.int64)
        # windowed mode: per-channel pending (arrival, bank, row) queues
        self._pending = [deque() for _ in range(self.cfg.n_channels)]
        self._arrival = 0

    def observe(self, addr) -> None:
        """Account a batch of line addresses (arrival order preserved)."""
        addr = np.asarray(addr, np.int64)
        if addr.size == 0:
            return
        ch, local = split_channels(addr, self.cfg)
        for c in range(self.cfg.n_channels):
            l = local[ch == c]
            if l.size == 0:
                continue
            _, bank, row = decode_lines(l, self.cfg)
            if self.window == 1:
                self._serve_inorder(c, np.asarray(bank), np.asarray(row))
            else:
                self._enqueue_windowed(c, bank, row)

    def _serve_inorder(self, c: int, bank: np.ndarray,
                       row: np.ndarray) -> None:
        # stable sort by bank keeps arrival order inside each bank group,
        # so "previous row in this bank" is one shifted comparison
        order = np.argsort(bank, kind="stable")
        b, r = bank[order], row[order]
        same_bank = np.concatenate(([False], b[1:] == b[:-1]))
        prev = np.where(same_bank,
                        np.concatenate(([-1], r[:-1])),   # shifted rows
                        self._open[c][b])                 # carry-in
        self.hits += int(np.count_nonzero(prev == r))
        self.served += b.size
        last = np.concatenate((b[1:] != b[:-1], [True]))  # group tails
        self._open[c][b[last]] = r[last]

    # -- windowed FR-FCFS replay (verification mode) --------------------

    def _enqueue_windowed(self, c: int, bank, row) -> None:
        q = self._pending[c]
        for b, r in zip(bank.tolist(), row.tolist()):
            if len(q) >= self.window:
                self._serve_one(c)
            q.append((self._arrival, int(b), int(r)))
            self._arrival += 1

    def _serve_one(self, c: int) -> None:
        # FR-FCFS pick: oldest row hit if any, else oldest.  The queue is
        # kept in arrival order, so the first hit scanned is the oldest.
        q = self._pending[c]
        pick = None
        for i, (_, b, r) in enumerate(q):
            if self._open[c, b] == r:
                pick = i
                break
        if pick is None:
            pick = 0
        else:
            self.hits += 1
        _, b, r = q[pick]
        del q[pick]
        self._open[c, b] = r
        self.served += 1

    def drain(self) -> None:
        """Serve out any pending windowed accesses (no-op for window=1)."""
        for c in range(self.cfg.n_channels):
            while self._pending[c]:
                self._serve_one(c)

    @property
    def row_hit_rate(self) -> float:
        """Hits over accesses *served* so far (0.0 before any traffic)."""
        return self.hits / self.served if self.served else 0.0

    def __repr__(self):
        return (f"OpenRowCounter(window={self.window}, served={self.served}, "
                f"row_hit_rate={self.row_hit_rate:.4f})")
