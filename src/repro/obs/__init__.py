"""Serving telemetry: metrics registry, trace spans, live row-locality.

The observability layer the MARS serving stack reports through:

  ``obs.metrics``    counters / gauges / fixed-bucket histograms behind a
                     process-local registry, plus the ``StatGroup``
                     facade that superseded the ad-hoc stats dataclasses
  ``obs.trace``      ring-buffered JSONL event log with monotonic
                     timestamps and nested spans
  ``obs.rowsim``     incremental open-row model (extracted from
                     ``core/dram.py``) feeding the live row-hit % gauge
  ``obs.observer``   the ``Observer`` hub + ``attach(engine)`` wiring
                     and the shared ``shard_load_snapshot`` helper

Everything is dependency-free (stdlib + numpy; the row model shares
``core/dram``'s address decode) and costs one ``is not None`` test per
instrumented site when disabled.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               StatGroup, exp_edges)
from repro.obs.observer import Observer, shard_load_snapshot
from repro.obs.rowsim import OpenRowCounter
from repro.obs.trace import TraceLog

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatGroup",
    "exp_edges", "Observer", "shard_load_snapshot", "OpenRowCounter",
    "TraceLog",
]
