"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

The serving stack's observability spine.  Three metric kinds, all
dependency-free and cheap enough to live on the decode hot path:

  Counter    monotonically increasing count (``inc`` rejects negative
             deltas); the unit the per-component stats facades are built
             from.
  Gauge      last-write-wins instantaneous value (occupancy, row-hit %).
  Histogram  fixed bucket edges chosen at creation; ``observe`` is one
             bisect + add, and the snapshot reports count/sum plus
             p50/p99 by linear interpolation inside the owning bucket —
             no sample retention, so memory is O(buckets) forever.

``MetricsRegistry`` names metrics (dotted paths like
``pool.shard0.allocs``) and renders one deterministic ``snapshot()``
dict — same metrics + same values = byte-identical JSON, which is what
lets CI diff snapshots.

``StatGroup`` is the compatible facade that absorbed the ad-hoc
per-component stats dataclasses (``PoolStats`` / ``EngineStats`` /
``SchedulerStats``): subclasses declare integer/float fields in
``FIELDS``; instances expose them as plain attributes (reads return
numbers, ``stats.allocs += n`` updates the underlying ``Counter``), and
``MetricsRegistry.adopt`` publishes the *same* counter objects under a
prefix — component code and the registry can never skew because there is
only one copy of each number.

>>> reg = MetricsRegistry()
>>> reg.counter("pool.allocs").inc(3)
>>> reg.gauge("pool.occupancy").set(0.5)
>>> reg.snapshot()["counters"]["pool.allocs"]
3
"""
from __future__ import annotations

import bisect
from typing import Optional, Sequence


class Counter:
    """Monotonic counter.  ``value`` is directly writable (the stats
    facades assign through it); ``inc`` enforces monotonicity."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self, value: float = 0):
        self.value = value

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, value: float = 0.0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = v


def exp_edges(lo: float, hi: float, n: int) -> tuple:
    """``n`` geometrically spaced bucket edges from ``lo`` to ``hi``."""
    assert lo > 0 and hi > lo and n >= 2
    r = (hi / lo) ** (1.0 / (n - 1))
    return tuple(lo * r ** i for i in range(n))


# engine-step latency default: 10us .. 100s, 48 geometric buckets
DEFAULT_MS_EDGES = exp_edges(0.01, 100_000.0, 48)


class Histogram:
    """Fixed-bucket histogram with interpolated quantile snapshots.

    ``edges`` are the bucket upper bounds; a value lands in the first
    bucket whose edge is >= value (bisect), with one extra overflow
    bucket past ``edges[-1]``.  Quantiles interpolate linearly within
    the owning bucket (overflow clamps to ``edges[-1]``), so p50/p99
    are deterministic functions of the counts — no samples kept.
    """

    kind = "histogram"

    def __init__(self, edges: Sequence[float] = DEFAULT_MS_EDGES):
        assert len(edges) >= 1 and list(edges) == sorted(edges)
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)   # +1 = overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.count += 1
        self.sum += v

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile (0..1); 0.0 when empty."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c:
                if i >= len(self.edges):          # overflow bucket
                    return self.edges[-1]
                lo = self.edges[i - 1] if i else 0.0
                hi = self.edges[i]
                return lo + (hi - lo) * max(target - cum, 0.0) / c
            cum += c
        return self.edges[-1]

    def to_snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": round(self.sum, 6),
            "mean": round(self.sum / self.count, 6) if self.count else 0.0,
            "p50": round(self.quantile(0.50), 6),
            "p99": round(self.quantile(0.99), 6),
        }


class MetricsRegistry:
    """Named metrics + one deterministic snapshot.

    Metric creation is get-or-create by dotted name; asking for an
    existing name with a different kind raises (one name, one meaning).
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(*args)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"wanted {cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges: Sequence[float] = DEFAULT_MS_EDGES) -> Histogram:
        return self._get(name, Histogram, edges)

    # convenience write-throughs (hot paths keep the metric object instead)

    def inc(self, name: str, n: float = 1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def attach_metric(self, name: str, metric) -> None:
        """Publish an externally owned metric object (e.g. a component's
        live ``Histogram``) under ``name`` — aliasing like ``adopt``, so
        snapshots read the component's own values.  Idempotent for the
        same object; a different object under a taken name raises."""
        have = self._metrics.get(name)
        if have is None:
            self._metrics[name] = metric
        elif have is not metric:
            raise ValueError(f"metric {name!r} already registered")

    def adopt(self, prefix: str, group: "StatGroup") -> None:
        """Publish a stats facade's counters under ``prefix.<field>``.

        The registry holds the SAME ``Counter`` objects the facade
        mutates — adoption is aliasing, not copying, so snapshots always
        read the live values.  Re-adopting the same group is idempotent;
        adopting a different group under a taken name raises.
        """
        for field, counter in group.counters().items():
            name = f"{prefix}.{field}"
            have = self._metrics.get(name)
            if have is None:
                self._metrics[name] = counter
            elif have is not counter:
                raise ValueError(
                    f"metric {name!r} already adopted from another group")

    def snapshot(self) -> dict:
        """{"counters": {...}, "gauges": {...}, "histograms": {...}},
        every section sorted by name — deterministic for identical
        metric states."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out["histograms"][name] = m.to_snapshot()
            elif isinstance(m, Gauge):
                out["gauges"][name] = _round(m.value)
            else:
                out["counters"][name] = _round(m.value)
        return out


def _round(v):
    return round(v, 6) if isinstance(v, float) else v


class StatGroup:
    """Attribute-compatible facade over ``Counter`` objects.

    Subclasses declare ``FIELDS`` (name -> default).  Instances read and
    write the fields like the dataclasses they replaced
    (``stats.allocs += n``), keyword construction still works
    (``PoolStats(allocs=3)``), and ``counters()`` exposes the live
    ``Counter`` objects for ``MetricsRegistry.adopt``.
    """

    FIELDS: dict[str, float] = {}

    def __init__(self, **kw):
        stats = {f: Counter(kw.pop(f, d)) for f, d in self.FIELDS.items()}
        if kw:
            raise TypeError(f"unknown stats field(s): {sorted(kw)}")
        object.__setattr__(self, "_stats", stats)

    def __getattr__(self, name):
        stats = object.__getattribute__(self, "_stats")
        try:
            return stats[name].value
        except KeyError:
            raise AttributeError(name) from None

    def __setattr__(self, name, value):
        try:
            self._stats[name].value = value
        except KeyError:
            raise AttributeError(
                f"{type(self).__name__} has no stats field {name!r}") \
                from None

    def counters(self) -> dict[str, Counter]:
        return dict(self._stats)

    def fields(self) -> tuple:
        return tuple(self.FIELDS)

    def as_dict(self) -> dict:
        return {f: c.value for f, c in self._stats.items()}

    def __repr__(self):
        body = ", ".join(f"{f}={c.value}" for f, c in self._stats.items())
        return f"{type(self).__name__}({body})"

    def __eq__(self, other):
        return isinstance(other, StatGroup) and \
            self.as_dict() == other.as_dict()
