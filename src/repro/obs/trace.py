"""Trace spans: a ring-buffered JSONL event log for the serving stack.

Every instrumented point in the request path (scheduler offer/route,
pool alloc/evict, backend prefill/decode, engine admit→free) appends one
event — a flat dict with a monotonic microsecond timestamp ``ts`` and an
event name ``ev`` — so one grep over the flushed file reconstructs any
request's timeline:

    {"ts": 1042, "ev": "sched.offer", "rid": 3, "ok": true}
    {"ts": 1180, "ev": "engine.prefill", "rid": 3, "dur_us": 95, ...}
    {"ts": 9021, "ev": "engine.free", "rid": 3, "sid": 7}

Design points:

  * ring buffer (``collections.deque(maxlen=...)``): a forgotten trace
    can never grow without bound; overflow evicts the oldest events and
    counts them in ``dropped``;
  * injectable clock: tests pass a fake monotonic clock and get
    byte-identical timelines; production uses ``time.monotonic`` with
    ``ts`` measured in integer microseconds since the log was created
    (small, diff-friendly numbers);
  * ``span(...)`` is a context manager that emits ONE event at exit
    carrying ``ts`` (entry time), ``dur_us`` and its nesting ``depth``
    — cheaper than begin/end pairs and trivially greppable.  The yielded
    dict is the event's field bag: instrumented code can add fields
    discovered mid-span (lane counts, staged blocks).

>>> clk = iter(range(100)).__next__
>>> t = TraceLog(clock=lambda: clk() * 1e-6)
>>> with t.span("engine.step", step=0) as sp:
...     sp["lanes"] = 4
...     t.event("engine.token", rid=1)
>>> [e["ev"] for e in t.events()]     # ordered by entry timestamp
['engine.step', 'engine.token']
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Callable, Iterator, Optional


class TraceLog:
    """Bounded, flushable event log with monotonic microsecond stamps."""

    def __init__(self, capacity: int = 65536,
                 clock: Optional[Callable[[], float]] = None):
        self._clock = clock or time.monotonic
        self._t0 = self._clock()
        self._buf: deque = deque(maxlen=capacity)
        self.capacity = capacity
        self.dropped = 0        # events evicted by ring overflow
        self.total = 0          # events ever recorded
        self._depth = 0         # live span nesting level

    def now_us(self) -> int:
        return int(round((self._clock() - self._t0) * 1e6))

    def event(self, ev: str, **fields) -> None:
        """Record one instantaneous event."""
        self._append({"ts": self.now_us(), "ev": ev, **fields})

    @contextmanager
    def span(self, ev: str, **fields) -> Iterator[dict]:
        """Record a timed region as one event at exit.

        The event carries the entry timestamp, ``dur_us``, and the
        nesting ``depth`` at entry (0 = top level).  Yields the mutable
        field dict so callers can attach results discovered inside.
        """
        rec = {"ts": self.now_us(), "ev": ev, "depth": self._depth,
               **fields}
        self._depth += 1
        try:
            yield rec
        finally:
            self._depth -= 1
            rec["dur_us"] = self.now_us() - rec["ts"]
            self._append(rec)

    def _append(self, rec: dict) -> None:
        if len(self._buf) == self.capacity:
            self.dropped += 1
        self._buf.append(rec)
        self.total += 1

    def events(self) -> list:
        """Buffered events, oldest first (spans appear at exit time)."""
        return sorted(self._buf, key=lambda e: e["ts"])

    def lines(self) -> list:
        """Buffered events rendered as JSONL strings."""
        return [json.dumps(e, sort_keys=True) for e in self.events()]

    def flush(self, path: str) -> int:
        """Append buffered events to ``path`` as JSONL and clear the
        buffer; returns the number of events written."""
        evs = self.lines()
        with open(path, "a", encoding="utf-8") as fh:
            for line in evs:
                fh.write(line + "\n")
        self._buf.clear()
        return len(evs)
