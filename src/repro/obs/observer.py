"""Observer: one attach point wiring the serving stack for telemetry.

``Observer`` bundles the three obs primitives — a ``MetricsRegistry``, a
``TraceLog``, and per-shard ``OpenRowCounter``s — and ``attach(engine)``
threads it through every serving layer by setting each component's
``obs`` attribute (scheduler, pool(s), backend(s), engine) and adopting
their stats facades into the registry:

    engine.<field>        EngineStats        (steps, decode_tokens, ...)
    sched.<field>         SchedulerStats     (scheduled, shard_defers, ...)
    pool.<field>          aggregate PoolStats
    pool.shardN.<field>   per-shard PoolStats (sharded pools)

Instrumented code pays ONE attribute test (``if self.obs is not None``)
when telemetry is off — nothing else; see ``docs/OBSERVABILITY.md`` for
the metric-name catalogue and span schema.

``shard_load_snapshot`` is the single per-shard load/occupancy summary
the routing layers consume (``ShardedBlockPool.route``/``least_loaded``
and ``ShardedPagedBackend.prefill`` used to hand-roll their own): the
``load`` and ``headroom`` columns are definitionally the pool's routing
metric (live + reserved) and reservation headroom (free + cached −
reserved), so every consumer ranks shards by the same numbers the
gauges report.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.rowsim import OpenRowCounter
from repro.obs.trace import TraceLog


def shard_load_snapshot(pool, registry: Optional[MetricsRegistry] = None
                        ) -> list:
    """Per-shard load summary of a ``BlockPool`` or ``ShardedBlockPool``.

    One row per shard (a single pool is one shard, index 0)::

        {"shard": i, "blocks": capacity, "live": .., "cached": ..,
         "free": .., "reserved": .., "load": live + reserved,
         "headroom": free + cached - reserved,
         "occupancy": (live + cached) / blocks}

    ``load`` is the routing metric (``ShardedBlockPool.load``);
    ``headroom`` is reservation capacity (``can_reserve(n)`` iff
    ``headroom >= n``).  With ``registry``, each row is also published
    as ``pool.shardN.{load,occupancy}`` gauges.
    """
    shards = pool.shards if getattr(pool, "is_sharded", False) else [pool]
    out = []
    for i, p in enumerate(shards):
        blocks = p.cfg.num_blocks
        live, cached, free = p.num_live, p.num_cached, p.num_free
        row = {"shard": i, "blocks": blocks, "live": live,
               "cached": cached, "free": free, "reserved": p.reserved,
               "load": live + p.reserved,
               "headroom": free + cached - p.reserved,
               "occupancy": (live + cached) / blocks if blocks else 0.0}
        if registry is not None:
            registry.set(f"pool.shard{i}.load", row["load"])
            registry.set(f"pool.shard{i}.occupancy", row["occupancy"])
        out.append(row)
    return out


class Observer:
    """Telemetry hub for one serving engine.

    Args:
      paranoid: run ``pool.check_invariants(incremental=True)`` every
        ``paranoid_every`` engine steps (the ``--metrics --paranoid``
        serve mode).
      row_cfg: DRAM config for the live open-row model; ``None`` uses
        the model's LPDDR4-3200 defaults.
      clock/capacity: forwarded to ``TraceLog`` (tests inject a fake
        clock for deterministic timelines).
    """

    def __init__(self, *, paranoid: bool = False, paranoid_every: int = 8,
                 row_cfg=None, clock=None, capacity: int = 65536):
        self.registry = MetricsRegistry()
        self.trace = TraceLog(capacity=capacity, clock=clock)
        self.paranoid = paranoid
        self.paranoid_every = max(1, paranoid_every)
        self._row_cfg = row_cfg
        self.rows: dict[int, OpenRowCounter] = {}
        # tier-boundary promotion copy-ins get their own open-row model:
        # the write stream is disjoint from the decode walk, so mixing
        # them would blur both gauges
        self.promo_rows: dict[int, OpenRowCounter] = {}
        self._engine = None

    # -- wiring --------------------------------------------------------------

    def attach(self, engine) -> "Observer":
        """Wire a ``ServeEngine`` (and everything below it) to this
        observer.  Idempotent; returns self for chaining."""
        self._engine = engine
        engine.obs = self
        self.registry.adopt("engine", engine.stats)
        engine.scheduler.obs = self
        self.registry.adopt("sched", engine.scheduler.stats)
        # per-traffic-class streams (SMS staged scheduling): counters
        # adopt as ``class.<name>.<field>``, and the scheduler's live
        # wait-time histograms alias in as ``class.<name>.wait_ms`` (the
        # p50/p99 gauges are published by ``schedule_batch`` itself)
        for cname, cs in getattr(engine.scheduler, "class_stats",
                                 {}).items():
            self.registry.adopt(f"class.{cname}", cs)
        for cname, h in getattr(engine.scheduler, "wait_hist", {}).items():
            self.registry.attach_metric(f"class.{cname}.wait_ms", h)
        pool = engine.pool
        if getattr(pool, "is_sharded", False):
            pool.obs = self
            for i, p in enumerate(pool.shards):
                p.obs = self
                p.obs_shard = i
                self.registry.adopt(f"pool.shard{i}", p.stats)
        else:
            pool.obs = self
            pool.obs_shard = 0
            self.registry.adopt("pool", pool.stats)
        backend = getattr(engine.model, "backend", None)
        if backend is not None:
            inners = getattr(backend, "backends", None) or [backend]
            for i, b in enumerate(inners):
                b.obs = self
                b.obs_shard = i
                tiers = getattr(b, "tiers", None)
                if tiers is not None:
                    tiers.obs = self
                    tiers.obs_shard = i
                    self.registry.adopt(f"tier.shard{i}", tiers.stats)
                    tiers._publish()     # occupancy gauges exist from step 0
        return self

    # -- live row-locality ---------------------------------------------------

    def observe_kv_walk(self, shard: int, addrs) -> None:
        """Feed one decode step's kernel page walk (64B-line ids from
        ``ops.kv_read_trace_kernel``) into shard ``shard``'s open-row
        model and refresh the row-hit gauges."""
        rc = self.rows.get(shard)
        if rc is None:
            rc = self.rows[shard] = OpenRowCounter(self._row_cfg)
        rc.observe(addrs)
        self.registry.set(f"dram.shard{shard}.row_hit_pct",
                          100.0 * rc.row_hit_rate)
        hits = sum(r.hits for r in self.rows.values())
        served = sum(r.served for r in self.rows.values())
        self.registry.set("dram.row_hit_pct",
                          100.0 * hits / served if served else 0.0)
        self.registry.counter("dram.kv_lines").inc(
            0 if addrs is None else len(addrs))

    def observe_promotion(self, shard: int, addrs) -> None:
        """Feed one tier-promotion batch's copy-in write stream (64B-line
        ids from ``TierManager.write_trace``, already MARS-ordered by
        destination row group) into shard ``shard``'s promotion open-row
        model and refresh the ``tier.promote_row_hit_pct`` gauges."""
        rc = self.promo_rows.get(shard)
        if rc is None:
            rc = self.promo_rows[shard] = OpenRowCounter(self._row_cfg)
        rc.observe(addrs)
        self.registry.set(f"tier.shard{shard}.promote_row_hit_pct",
                          100.0 * rc.row_hit_rate)
        hits = sum(r.hits for r in self.promo_rows.values())
        served = sum(r.served for r in self.promo_rows.values())
        self.registry.set("tier.promote_row_hit_pct",
                          100.0 * hits / served if served else 0.0)

    # -- per-step bookkeeping (called by the engine) -------------------------

    def step_done(self, engine, dt_ms: float, lanes: int,
                  tokens: int) -> None:
        """End-of-step hook: step-latency histogram, occupancy/rate
        gauges, and (paranoid mode) the periodic incremental invariant
        sweep."""
        self.registry.observe("engine.step_ms", dt_ms)
        self.registry.set("engine.lanes", lanes)
        self.sample(engine)
        if self.paranoid and engine.stats.steps % self.paranoid_every == 0:
            engine.pool.check_invariants(incremental=True)

    def sample(self, engine) -> None:
        """Refresh derived gauges from the engine's pools and stats."""
        pool = engine.pool
        snap = shard_load_snapshot(pool, self.registry)
        blocks = sum(r["blocks"] for r in snap)
        live = sum(r["live"] for r in snap)
        cached = sum(r["cached"] for r in snap)
        self.registry.set("pool.occupancy",
                          (live + cached) / blocks if blocks else 0.0)
        st = pool.stats
        self.registry.set("kvcache.eviction_rate",
                          st.evictions / max(st.allocs, 1))
        es = engine.stats
        self.registry.set("kvcache.prefix_hit_rate",
                          es.shared_prompt_tokens / max(es.prefill_tokens, 1))
        backend = getattr(engine.model, "backend", None)
        if backend is not None:
            # decode-pipeline depth: 0 idle, 1 dispatched-unsynced or
            # synced-uncommitted, 2 both (one step in flight on device
            # while the previous step's write-back is still deferred)
            self.registry.set("backend.inflight_steps",
                              getattr(backend, "inflight_steps", 0))

    # -- surfacing -----------------------------------------------------------

    def snapshot(self) -> dict:
        """The registry snapshot plus trace/rowsim meta — what
        ``launch/serve.py --metrics`` writes as JSON."""
        out = self.registry.snapshot()
        out["trace"] = {"events": self.trace.total,
                        "dropped": self.trace.dropped}
        return out

    def summary_lines(self) -> list:
        """One-screen human summary of the headline metrics."""
        s = self.snapshot()
        g, c, h = s["gauges"], s["counters"], s["histograms"]
        step = h.get("engine.step_ms", {})
        lines = [
            f"row-hit %            {g.get('dram.row_hit_pct', 0.0):7.2f}",
            f"prefix hit rate      {g.get('kvcache.prefix_hit_rate', 0.0):7.3f}",
            f"eviction rate        {g.get('kvcache.eviction_rate', 0.0):7.3f}",
            f"step latency ms      p50 {step.get('p50', 0.0):.3f} / "
            f"p99 {step.get('p99', 0.0):.3f}  (n={step.get('count', 0)})",
            f"steps / tokens       {c.get('engine.steps', 0)} / "
            f"{c.get('engine.decode_tokens', 0)}",
        ]
        for name in sorted(n for n in g if n.endswith(".occupancy")
                           and n.startswith("pool.shard")):
            shard = name.split(".")[1]
            lines.append(f"{shard + ' occupancy':<21}{g[name]:7.3f}")
        lines.append(f"trace events         {s['trace']['events']} "
                     f"({s['trace']['dropped']} dropped)")
        return lines
