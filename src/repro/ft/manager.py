"""Fault-tolerance runtime: heartbeats, straggler mitigation, elastic
restart.

On a real multi-pod deployment each host runs this manager beside the
training loop.  The control plane is deliberately simple and file/launcher
based (no external services), which is what actually survives at scale:

  * HeartbeatMonitor — every host writes a monotonic heartbeat; the leader
    declares a host dead after ``timeout_s`` and triggers an elastic
    restart from the last committed checkpoint.
  * StragglerDetector — EWMA of per-step wall time; a host is a straggler
    when its step time exceeds ``factor`` x the fleet median for
    ``patience`` consecutive steps.  Action: flag for preemptive restart /
    hot-spare swap (the scheduler decides; we surface the signal).
  * ElasticPlan — given the surviving device set, picks the largest valid
    (pod, data, model) mesh <= the original, preserving the model axis
    (TP/EP degree must not change — parameters reshard only along
    data/pod), and returns the new mesh + the checkpoint resharding plan.

Failure handling is CHECKPOINT-RESTART based: collectives on TPU cannot
survive membership change mid-step, so the recovery unit is the step. The
cost model is: lose <= ckpt_interval steps + restart time; the interval
auto-tunes from measured step time and MTBF (Young/Daly).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from collections import defaultdict, deque
from pathlib import Path
from typing import Callable, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HeartbeatMonitor:
    directory: Path
    host_id: int
    timeout_s: float = 60.0

    def __post_init__(self):
        self.directory = Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    def beat(self, step: int) -> None:
        p = self.directory / f"hb_{self.host_id}.json"
        tmp = p.with_suffix(".tmp")
        tmp.write_text(json.dumps({"t": time.time(), "step": step}))
        os.replace(tmp, p)

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = now or time.time()
        dead = []
        for p in self.directory.glob("hb_*.json"):
            try:
                t = json.loads(p.read_text())["t"]
            except (json.JSONDecodeError, KeyError):
                continue
            if now - t > self.timeout_s:
                dead.append(int(p.stem.split("_")[1]))
        return sorted(dead)


# ---------------------------------------------------------------------------
# Stragglers
# ---------------------------------------------------------------------------

class StragglerDetector:
    """EWMA step-time tracking vs fleet median."""

    def __init__(self, n_hosts: int, factor: float = 1.5,
                 patience: int = 5, alpha: float = 0.3):
        self.factor = factor
        self.patience = patience
        self.alpha = alpha
        self.ewma = np.zeros(n_hosts)
        self.strikes = np.zeros(n_hosts, np.int32)

    def observe(self, host: int, step_time_s: float) -> None:
        e = self.ewma[host]
        self.ewma[host] = step_time_s if e == 0 else \
            self.alpha * step_time_s + (1 - self.alpha) * e

    def stragglers(self) -> list[int]:
        active = self.ewma[self.ewma > 0]
        if len(active) < 2:
            return []
        med = float(np.median(active))
        out = []
        for h, e in enumerate(self.ewma):
            if e > self.factor * med:
                self.strikes[h] += 1
                if self.strikes[h] >= self.patience:
                    out.append(h)
            else:
                self.strikes[h] = 0
        return out


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    dropped_hosts: tuple
    notes: str


def plan_elastic_mesh(original_shape: tuple, axis_names: tuple,
                      surviving_devices: int) -> ElasticPlan:
    """Largest valid mesh under the survivor count.

    The model axis is preserved (changing TP/EP degree would invalidate
    every parameter shard); capacity shrinks along data, then pod.  E.g.
    (2,16,16) with one pod lost -> (1,16,16); 512 -> 448 devices keeps
    (2,14,16) if 'data' can shrink to 14 and the global batch divides.
    """
    shape = dict(zip(axis_names, original_shape))
    model = shape.get("model", 1)
    if surviving_devices < model:
        raise ValueError("cannot preserve model axis; survivors "
                         f"{surviving_devices} < model {model}")
    rest = surviving_devices // model
    pod = shape.get("pod", 1)
    data = shape.get("data", 1)
    # shrink data first; on equal capacity prefer fewer pods (a whole-pod
    # loss should collapse to a clean single-pod mesh, not two half-pods)
    best = None
    for p in range(1, pod + 1):
        d = min(data, rest // p)
        if d >= 1 and (best is None or p * d > best[0] * best[1]):
            best = (p, d)
    p, d = best
    new = []
    for a in axis_names:
        new.append({"pod": p, "data": d, "model": model}.get(a, shape[a]))
    used = p * d * model
    return ElasticPlan(tuple(new), tuple(axis_names),
                       dropped_hosts=(),
                       notes=f"{surviving_devices} survivors -> "
                             f"{used} used ({surviving_devices-used} spare)")


# ---------------------------------------------------------------------------
# Checkpoint cadence (Young/Daly)
# ---------------------------------------------------------------------------

def optimal_ckpt_interval_steps(step_time_s: float, ckpt_time_s: float,
                                mtbf_hours: float, n_hosts: int) -> int:
    """Young/Daly: T_opt = sqrt(2 * C * MTBF_system)."""
    mtbf_system = mtbf_hours * 3600.0 / max(n_hosts, 1)
    t_opt = math.sqrt(2.0 * ckpt_time_s * mtbf_system)
    return max(1, int(t_opt / max(step_time_s, 1e-6)))


# ---------------------------------------------------------------------------
# Run supervisor
# ---------------------------------------------------------------------------

class RunSupervisor:
    """Glue: drives heartbeat + straggler + checkpoint cadence around a
    step function; used by launch/train.py and the FT integration test."""

    def __init__(self, workdir: str, n_hosts: int = 1, host_id: int = 0,
                 ckpt_interval: int = 50, hb_timeout_s: float = 60.0,
                 mtbf_hours: float = 24.0):
        self.workdir = Path(workdir)
        self.ckpt_dir = self.workdir / "ckpt"
        self.hb = HeartbeatMonitor(self.workdir / "hb", host_id,
                                   hb_timeout_s)
        self.stragglers = StragglerDetector(n_hosts)
        self.ckpt_interval = ckpt_interval
        self.mtbf_hours = mtbf_hours
        self.n_hosts = n_hosts
        self._step_times: deque = deque(maxlen=50)
        self._ckpt_times: deque = deque(maxlen=5)

    def should_checkpoint(self, step: int) -> bool:
        return step > 0 and step % self.ckpt_interval == 0

    def after_step(self, step: int, step_time_s: float) -> dict:
        self._step_times.append(step_time_s)
        self.hb.beat(step)
        self.stragglers.observe(0, step_time_s)
        events = {"dead": self.hb.dead_hosts(),
                  "stragglers": self.stragglers.stragglers()}
        # retune cadence from live measurements
        if self._step_times and self._ckpt_times:
            self.ckpt_interval = optimal_ckpt_interval_steps(
                float(np.mean(self._step_times)),
                float(np.mean(self._ckpt_times)),
                self.mtbf_hours, self.n_hosts)
        return events

    def record_ckpt_time(self, seconds: float) -> None:
        self._ckpt_times.append(seconds)
