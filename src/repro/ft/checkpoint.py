"""Sharded, atomic, resharding-on-restore checkpointing.

Design for thousands of nodes:
  * every host writes ONLY the shards it owns (``addressable_shards``) —
    no gather, no single writer bottleneck;
  * a two-phase commit: shards land in ``step_NNN.tmp/``, a manifest with
    content hashes is written last, then the directory is atomically
    renamed — a crashed writer can never produce a half-valid checkpoint;
  * restore reassembles from any worker count / mesh shape (resharding on
    load): each host reads the byte ranges covering its new shards, so an
    elastic restart after losing a pod just works;
  * dependency-free format: one ``.npy`` per (param-leaf, shard) + JSON
    manifest.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save(tree, step: int, directory: str | os.PathLike,
         process_index: int | None = None) -> Path:
    """Write this process's shards + manifest; atomic rename on completion."""
    directory = Path(directory)
    pidx = jax.process_index() if process_index is None else process_index
    tmp = directory / f"step_{step:08d}.tmp"
    final = directory / f"step_{step:08d}"
    (tmp / "shards").mkdir(parents=True, exist_ok=True)

    manifest = {"step": step, "leaves": {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "shards": []}
        x = leaf if hasattr(leaf, "addressable_shards") else None
        if x is not None and hasattr(x, "sharding") \
                and not x.sharding.is_fully_replicated:
            seen = set()
            for sh in x.addressable_shards:
                key = tuple((s.start or 0, s.stop) for s in sh.index)
                if key in seen:
                    continue
                seen.add(key)
                data = np.asarray(sh.data)
                fname = f"{hashlib.sha1((name + str(key)).encode()).hexdigest()[:16]}.npy"
                np.save(tmp / "shards" / fname, data)
                entry["shards"].append(
                    {"index": [[s.start or 0,
                                s.stop if s.stop is not None else dim]
                               for s, dim in zip(sh.index, arr.shape)],
                     "file": fname,
                     "sha1": hashlib.sha1(data.tobytes()).hexdigest()[:16]})
        else:
            if pidx == 0:
                fname = f"{hashlib.sha1(name.encode()).hexdigest()[:16]}.npy"
                np.save(tmp / "shards" / fname, arr)
                entry["shards"].append(
                    {"index": [[0, d] for d in arr.shape], "file": fname,
                     "sha1": hashlib.sha1(arr.tobytes()).hexdigest()[:16]})
        manifest["leaves"][name] = entry

    with open(tmp / f"manifest_{pidx}.json", "w") as f:
        json.dump(manifest, f)
    # single-process (and process 0 in multi-host): commit
    if pidx == 0:
        os.replace(tmp, final)
        _gc(directory, keep=3)
    return final


def _gc(directory: Path, keep: int):
    steps = sorted(directory.glob("step_[0-9]*"))
    steps = [s for s in steps if not s.name.endswith(".tmp")]
    for s in steps[:-keep]:
        shutil.rmtree(s, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(int(p.name.split("_")[1])
                   for p in directory.glob("step_[0-9]*")
                   if not p.name.endswith(".tmp"))
    return steps[-1] if steps else None


def restore(tree_like, step: int, directory: str | os.PathLike,
            shardings=None):
    """Rebuild the tree at ``step``.  ``tree_like`` supplies structure and
    shapes; ``shardings`` (optional) the *target* shardings — which may
    differ from those at save time (elastic restart / new mesh)."""
    directory = Path(directory) / f"step_{step:08d}"
    manifests = sorted(directory.glob("manifest_*.json"))
    merged: dict = {}
    for m in manifests:
        with open(m) as f:
            data = json.load(f)
        for name, entry in data["leaves"].items():
            e = merged.setdefault(name, {"shape": entry["shape"],
                                         "dtype": entry["dtype"],
                                         "shards": []})
            e["shards"].extend(entry["shards"])

    names = dict(_leaf_paths(tree_like))
    out_leaves = {}
    for name, proto in names.items():
        entry = merged[name]
        full = np.zeros(entry["shape"], entry["dtype"])
        for sh in entry["shards"]:
            data = np.load(directory / "shards" / sh["file"])
            if hashlib.sha1(data.tobytes()).hexdigest()[:16] != sh["sha1"]:
                raise IOError(f"checksum mismatch for {name}:{sh['file']}")
            idx = tuple(slice(a, b) for a, b in sh["index"])
            full[idx] = data
        out_leaves[name] = full

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    rebuilt = []
    for (path, proto), shd in zip(flat, shard_flat):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = jnp.asarray(out_leaves[name])
        if shd is not None:
            arr = jax.device_put(arr, shd)
        rebuilt.append(arr)
    return jax.tree_util.tree_unflatten(treedef, rebuilt)
