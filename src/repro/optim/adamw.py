"""Optimizers: AdamW (mixed-precision, ZeRO-friendly) and Adafactor
(factored second moment, for trillion-parameter MoE where full AdamW state
does not fit the pod).

States live in the same sharding as their parameters (which are themselves
FSDP-sharded under the default rules), so optimizer state is automatically
ZeRO-3 partitioned — no extra machinery needed under pjit.  All state trees
are None-free so pytree structures always match the gradient tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"  # bfloat16 halves AdamW state memory
    # adafactor
    factored_min: int = 128        # factor 2nd moment for dims >= this


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cosine = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cosine)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any       # fp32 master copy (always present)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any           # row 2nd-moment factor (full moment if not factored)
    vc: Any           # col factor ((1,) dummy if not factored)
    master: Any


def _factorable(p, cfg: OptConfig):
    return (p.ndim >= 2 and p.shape[-1] >= cfg.factored_min
            and p.shape[-2] >= cfg.factored_min)


def adamw_init(params, cfg: OptConfig) -> AdamWState:
    md = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params),
                      jax.tree.map(lambda p: p.astype(jnp.float32), params))


def adamw_update(grads, state: AdamWState, params, cfg: OptConfig):
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    md = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step)
        vh = v / (1 - cfg.b2 ** step)
        new = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                             + cfg.weight_decay * master)
        return new.astype(p.dtype), m.astype(md), v.astype(md), new

    flat_g, treedef = jax.tree.flatten(grads)
    res = [upd(g, m, v, p, ms) for g, m, v, p, ms in zip(
        flat_g, treedef.flatten_up_to(state.m),
        treedef.flatten_up_to(state.v), treedef.flatten_up_to(params),
        treedef.flatten_up_to(state.master))]
    new_p = treedef.unflatten([r[0] for r in res])
    st = AdamWState(step,
                    treedef.unflatten([r[1] for r in res]),
                    treedef.unflatten([r[2] for r in res]),
                    treedef.unflatten([r[3] for r in res]))
    return new_p, st, {"grad_norm": gnorm, "lr": lr}


def adafactor_init(params, cfg: OptConfig) -> AdafactorState:
    def vr(p):
        return jnp.zeros(p.shape[:-1] if _factorable(p, cfg) else p.shape,
                         jnp.float32)

    def vc(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:]
                         if _factorable(p, cfg) else (1,), jnp.float32)
    return AdafactorState(jnp.zeros((), jnp.int32),
                          jax.tree.map(vr, params),
                          jax.tree.map(vc, params),
                          jax.tree.map(lambda p: p.astype(jnp.float32),
                                       params))


def adafactor_update(grads, state: AdafactorState, params, cfg: OptConfig):
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(g, vr, vc, p, master):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + 1e-30
        if _factorable(p, cfg):
            vr_n = decay * vr + (1 - decay) * g2.mean(-1)
            vc_n = decay * vc + (1 - decay) * g2.mean(-2)
            denom = (vr_n[..., None] * vc_n[..., None, :]
                     / jnp.maximum(vr_n.mean(-1, keepdims=True)[..., None],
                                   1e-30))
            u = g * jax.lax.rsqrt(denom + 1e-30)
        else:
            vr_n = decay * vr + (1 - decay) * g2
            vc_n = vc
            u = g * jax.lax.rsqrt(vr_n + 1e-30)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)   # Adafactor update clipping
        u = u / jnp.maximum(1.0, rms)
        new = master - lr * (u + cfg.weight_decay * master)
        return new.astype(p.dtype), vr_n, vc_n, new

    flat_g, treedef = jax.tree.flatten(grads)
    res = [upd(g, vr, vc, p, ms) for g, vr, vc, p, ms in zip(
        flat_g, treedef.flatten_up_to(state.vr),
        treedef.flatten_up_to(state.vc), treedef.flatten_up_to(params),
        treedef.flatten_up_to(state.master))]
    new_p = treedef.unflatten([r[0] for r in res])
    st = AdafactorState(step,
                        treedef.unflatten([r[1] for r in res]),
                        treedef.unflatten([r[2] for r in res]),
                        treedef.unflatten([r[3] for r in res]))
    return new_p, st, {"grad_norm": gnorm, "lr": lr}


def opt_init(params, cfg: OptConfig):
    return adamw_init(params, cfg) if cfg.kind == "adamw" \
        else adafactor_init(params, cfg)


def opt_update(grads, state, params, cfg: OptConfig):
    return adamw_update(grads, state, params, cfg) if cfg.kind == "adamw" \
        else adafactor_update(grads, state, params, cfg)


def state_shardings(state, param_shardings, mesh):
    """Optimizer state inherits its parameter's sharding; scalars and
    factored moments that lost axes fall back sensibly."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())

    def like(s_leaf, p_shard):
        if s_leaf.ndim == 0 or s_leaf.shape == (1,):
            return rep
        spec = p_shard.spec
        if len(spec) == s_leaf.ndim:
            return p_shard
        if len(spec) > s_leaf.ndim:   # factored moment: drop trailing axes
            return NamedSharding(mesh, P(*spec[:s_leaf.ndim]))
        return rep

    def map_like(leaf_tree):
        return jax.tree.map(like, leaf_tree, param_shardings)

    if isinstance(state, AdamWState):
        return AdamWState(rep, map_like(state.m), map_like(state.v),
                          map_like(state.master))
    return AdafactorState(rep, map_like(state.vr), map_like(state.vc),
                          map_like(state.master))
