"""starcoder2-7b [dense] — GQA, RoPE, non-gated GELU MLP, LayerNorm.

32L d_model=4608 36H (kv=4) d_ff=18432 vocab=49152  [arXiv:2402.19173]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab=49152, rope_theta=1_000_000.0, norm="ln", act="gelu",
    mlp_gated=False, qkv_bias=True,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128)
