"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128  [arXiv:2405.21060]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=0,
    vocab=50280, use_rope=False,
    ssm_state=128, d_ssm_head=64, ssm_expand=2, ssm_conv=4, ssm_chunk=64,
    tie_embeddings=True,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", n_layers=2, d_model=64, vocab=128,
        ssm_state=16, d_ssm_head=16, ssm_chunk=8)
