"""whisper-base [audio] — encoder-decoder; conv frontend is a STUB
(input_specs provides precomputed frame embeddings).

enc 6L + dec 6L d_model=512 8H d_ff=2048 vocab=51865  [arXiv:2212.04356]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, use_rope=False, norm="ln", act="gelu",
    mlp_gated=False, frontend="audio", frontend_seq=1500,
    tie_embeddings=True,
    max_position=65_536,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", n_layers=2, enc_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, frontend_seq=16,
        max_position=512)
