"""paligemma-3b [vlm] — SigLIP patch prefix (STUB) + gemma decoder (MQA).

18L d_model=2048 8H (kv=1) d_ff=16384 vocab=257216  [arXiv:2407.07726]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=257216, rope_theta=10_000.0, act="gelu", mlp_gated=True,
    tie_embeddings=True, frontend="image", frontend_seq=256,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="paligemma-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab=256, frontend_seq=8)
