"""Architecture registry: ``get(name)`` -> full ModelConfig,
``get_smoke(name)`` -> reduced same-family config for CPU tests."""
from __future__ import annotations

import importlib

ARCHS = (
    "mamba2_370m",
    "deepseek_coder_33b",
    "qwen1_5_0_5b",
    "starcoder2_7b",
    "phi3_medium_14b",
    "arctic_480b",
    "kimi_k2_1t_a32b",
    "whisper_base",
    "paligemma_3b",
    "hymba_1_5b",
)

# CLI ids (--arch) map dashes to underscores
ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def _module(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).smoke()


def all_archs():
    return list(ARCHS)
