"""phi3-medium-14b [dense] — RoPE SwiGLU GQA.

40L d_model=5120 40H (kv=10) d_ff=17920 vocab=100352  [arXiv:2404.14219]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
    vocab=100352, rope_theta=10_000.0,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="phi3-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128)
