"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer,
sliding-window attention with periodic global layers.

32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001 ssm_state=16
[arXiv:2411.13676]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, rope_theta=10_000.0,
    sliding_window=1024, global_every=16,
    ssm_state=16, d_ssm_head=64, ssm_expand=2, ssm_conv=4, ssm_chunk=64,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="hymba-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128, sliding_window=16,
        global_every=2, ssm_state=8, d_ssm_head=16, ssm_chunk=8)
