"""arctic-480b [moe] — 128 experts top-2 with a parallel dense residual MLP.

35L d_model=7168 56H (kv=8) d_ff=4864 vocab=32000
[hf:Snowflake/snowflake-arctic-base]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000,
    n_experts=128, top_k=2, d_expert=4864, moe_dense_residual=True,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="arctic-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab=128, n_experts=8, top_k=2, d_expert=96)
