"""deepseek-coder-33b [dense] — llama-arch GQA.

62L d_model=7168 56H (kv=8) d_ff=19200 vocab=32256  [arXiv:2401.14196]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab=32256, rope_theta=100_000.0, act="silu", mlp_gated=True,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="deepseek-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128)
