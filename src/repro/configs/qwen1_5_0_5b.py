"""qwen1.5-0.5b [dense] — GQA with QKV bias, tied embeddings.

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936  [hf:Qwen/Qwen1.5-0.5B]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, rope_theta=1_000_000.0, qkv_bias=True,
    tie_embeddings=True,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="qwen-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256)
