"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8,
one shared expert, first layer dense (paper-table config).

61L d_model=7168 64H (kv=8) d_ff=2048 vocab=163840  [arXiv:2501.kimi2]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840,
    n_experts=384, top_k=8, d_expert=2048, n_shared_experts=1,
    n_dense_layers=1,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="kimi-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab=128, n_experts=8, top_k=2,
        d_expert=96, n_shared_experts=1, n_dense_layers=1)
