"""Data pipeline: deterministic sharded token streams + MARS bucket buffer.

Two parts:

  * ``TokenStream`` — synthetic-corpus token batches, sharded per host
    (each data-parallel host draws a disjoint, deterministic slice; resume
    is exact from (seed, step)).  Used by examples and the train driver.

  * ``BucketReorderBuffer`` — the MARS policy applied to sample batching:
    the "page" is a length bucket; a bounded lookahead window groups
    samples by bucket (minimizing padding waste = wasted bandwidth), and
    buckets are drained oldest-first so no sample starves.  Identical
    structure to the paper's RequestQ/PhyPageOrderQ.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class TokenStream:
    """Deterministic, shardable, resumable synthetic LM data."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        # key: (seed, step, host) — exact resume, disjoint across hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + self.step) * 4096 + cfg.host_id)
        # zipf-ish marginals give the embedding gather a realistic page
        # distribution (hot rows + long tail) for the MARS gather path
        z = rng.zipf(1.3, size=(cfg.host_batch, cfg.seq_len + 1))
        tokens = (z % cfg.vocab).astype(np.int32)
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        self.step += 1
        return batch


class BucketReorderBuffer:
    """MARS lookahead for variable-length samples.

    offer() inserts a sample into its length bucket (page); take_batch()
    drains the bucket holding the oldest sample — padded to that bucket's
    upper bound only, not the global max.
    """

    def __init__(self, bucket_edges=(128, 256, 512, 1024, 2048, 4096),
                 window: int = 512):
        self.edges = tuple(bucket_edges)
        self.window = window
        self.buckets: "OrderedDict[int, deque]" = OrderedDict()
        self.total = 0

    def _bucket(self, length: int) -> int:
        for i, e in enumerate(self.edges):
            if length <= e:
                return i
        return len(self.edges) - 1

    def offer(self, sample: np.ndarray) -> bool:
        if self.total >= self.window:
            return False
        b = self._bucket(len(sample))
        self.buckets.setdefault(b, deque()).append(sample)
        self.total += 1
        return True

    def take_batch(self, batch_size: int):
        """Oldest-bucket-first drain; returns (padded batch, mask)."""
        if not self.buckets:
            return None
        b = next(iter(self.buckets))
        q = self.buckets[b]
        out = [q.popleft() for _ in range(min(batch_size, len(q)))]
        if not q:
            del self.buckets[b]
        self.total -= len(out)
        width = self.edges[b]
        arr = np.zeros((len(out), width), out[0].dtype)
        mask = np.zeros((len(out), width), bool)
        for i, s in enumerate(out):
            arr[i, :len(s)] = s
            mask[i, :len(s)] = True
        return arr, mask

    def padding_waste(self, batch, mask) -> float:
        return 1.0 - mask.mean()
