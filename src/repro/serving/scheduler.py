"""MARS request scheduler — the paper's architecture as a serving frontend.

This is the *online* software rendering of MARS (the kernels are the bulk
rendering).  Incoming inference requests are the interleaved streams; the
"physical page" is the KV-prefix block (requests sharing a prompt prefix
hit the same cache pages and the same expert routing neighborhoods).  The
three paper structures map 1:1:

  RequestQ       -> bounded request buffer (``request_q`` entries)
  PhyPageList    -> dict keyed by prefix-block hash, holding per-page FIFO
                    lists (set-associativity bounds tracked pages, exactly
                    like the 2-way SRAM table)
  PhyPageOrderQ  -> drain the page holding the oldest buffered request
                    (core/mars._forward) -> bounded delay (no starvation)
                    while batches stay page-coherent

``schedule_batch`` pops up to ``batch_size`` requests page-major — the
back-to-back CAS drain.  With MARS off it pops FIFO — the baseline.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict, deque
from typing import Optional

import numpy as np

from repro.obs.metrics import StatGroup


@dataclasses.dataclass
class Request:
    rid: int
    prompt: tuple           # token tuple (hashable)
    arrival: float = 0.0
    prefix_len: int = 64    # block size for page hashing
    max_new: int = 16
    n_samples: int = 1      # parallel samples (forked lanes, CoW tails)

    @property
    def page(self) -> str:
        block = self.prompt[:self.prefix_len]
        return hashlib.sha1(repr(block).encode()).hexdigest()[:12]

    def blocks_needed(self, block_size: int) -> int:
        """Worst-case (no prefix sharing) KV blocks over the full lifetime,
        counting every forked sample as its own sequence."""
        return -(-(len(self.prompt) + self.max_new) // block_size) \
            * self.n_samples


class SchedulerStats(StatGroup):
    """Scheduling counters as an ``obs.metrics.StatGroup`` facade (same
    attribute API as the old dataclass; a ``MetricsRegistry`` adopts the
    live counters).  The derived ratios stay plain properties."""
    FIELDS = {"scheduled": 0, "batches": 0, "page_switches": 0,
              "stall_rejects": 0, "pool_rejects": 0,
              # sharded pool: no shard had headroom yet
              "shard_defers": 0, "wait_sum": 0.0}

    @property
    def pages_per_batch(self) -> float:
        return self.page_switches / max(self.batches, 1)

    @property
    def mean_wait(self) -> float:
        return self.wait_sum / max(self.scheduled, 1)


class MarsScheduler:
    """Bounded-lookahead, page-grouping, oldest-page-first batcher."""

    def __init__(self, request_q: int = 512, page_entries: int = 128,
                 ways: int = 2, mars: bool = True, pool=None):
        self.request_q = request_q
        self.page_entries = page_entries
        self.nsets = page_entries // ways
        self.ways = ways
        self.mars = mars
        self.pages: "OrderedDict[str, deque]" = OrderedDict()
        self.setload: dict[int, set] = {}
        self.fifo: deque = deque()
        self.total = 0
        self.stats = SchedulerStats()
        # KV block pool (``kvcache.BlockPool``): admission is bounded by
        # physical cache capacity, not just RequestQ entries.  A request's
        # worst-case block need is reserved in the pool at offer(); the
        # engine converts the reservation into real allocations as the
        # sequence grows and releases the remainder when it finishes
        # (reservations must outlive scheduling — decode blocks are
        # allocated lazily, long after the batch was formed).
        self.pool = pool
        self._seq = 0                            # arrival counter
        self.obs = None          # telemetry hook (obs.Observer.attach)
        # tiered KV memory (sharded pools): optional probe mapping a
        # prompt to the shard whose spill tiers hold its prefix
        # (``ShardedPagedBackend.tier_shard_for``) — admission counts a
        # promotable lower-tier hit toward affinity routing, so the
        # request lands where its demoted blocks are instead of
        # recomputing them elsewhere
        self.tier_probe = None

    def _set_of(self, page: str) -> int:
        return int(page, 16) % self.nsets

    def offer(self, req: Request) -> bool:
        """Insert (paper Fig 5).  False = backpressure to the client."""
        ok, reason = self._offer(req)
        if self.obs is not None:
            self.obs.trace.event("sched.offer", rid=req.rid,
                                 page=req.page, ok=ok, reason=reason)
        return ok

    def _offer(self, req: Request) -> tuple:
        """(accepted, reason) — reason names the reject path ("ok",
        "queue_full", "pool_capacity", "page_ways")."""
        if self.total >= self.request_q:
            self.stats.stall_rejects += 1
            return False, "queue_full"
        if self.pool is not None:
            if not self.pool.can_reserve(
                    req.blocks_needed(self.pool.cfg.block_size)):
                self.stats.pool_rejects += 1
                return False, "pool_capacity"
        page = req.page
        if page not in self.pages:
            s = self._set_of(page)
            ways = self.setload.setdefault(s, set())
            if len(ways) >= self.ways:
                self.stats.stall_rejects += 1
                return False, "page_ways"
            ways.add(page)
            self.pages[page] = deque()
        req._seq = self._seq            # arrival stamp: drain-order key
        self._seq += 1
        self.pages[page].append(req)
        self.fifo.append(req)
        self.total += 1
        if self.pool is not None:
            self.pool.reserve(req.blocks_needed(self.pool.cfg.block_size))
        return True, "ok"

    def _route_shard(self, r: Request) -> bool:
        """Sharded pools only: commit ``r``'s aggregate admission
        reservation to a concrete shard (``ShardedBlockPool.route`` —
        prefix-page affinity first, then least shard load), stamping the
        choice on ``r._shard`` for the engine to honor at prefill.

        False = no shard has headroom *right now*; the request stays
        buffered (its ``_seq`` keeps its drain priority) and scheduling
        stops so the oldest request is never skipped — bounded delay is
        preserved, admission just waits for running sequences to free
        their shard.  Single pools always return True.
        """
        if self.pool is None or not getattr(self.pool, "is_sharded", False):
            return True
        if getattr(r, "_shard", None) is not None:
            return True              # already routed (re-scheduled batch)
        hint = None if self.tier_probe is None \
            else self.tier_probe(r.prompt)
        shard = self.pool.route(
            r.rid, r.page, r.blocks_needed(self.pool.cfg.block_size),
            tier_hint=hint)
        if shard is None:
            self.stats.shard_defers += 1
            if self.obs is not None:
                self.obs.trace.event("sched.defer", rid=r.rid)
            return False
        r._shard = shard
        if self.obs is not None:
            self.obs.trace.event("sched.route", rid=r.rid, shard=shard)
        return True

    def schedule_batch(self, batch_size: int, now: float | None = None,
                       cost_fn=None) -> list:
        """Forward (paper Fig 6): drain oldest pages to exhaustion.

        ``batch_size`` is a budget; each request costs ``cost_fn(r)``
        (default 1 — e.g. the engine charges one lane per forked sample).
        Scheduling stops before the first request that would overrun it.

        With a sharded pool every admitted request is additionally routed
        to a shard (``_route_shard``): page-grouped draining means the
        whole page's requests land on one shard back-to-back — the
        co-location that makes per-shard prefix caches hit.
        """
        now = time.time() if now is None else now
        cost_fn = cost_fn or (lambda r: 1)
        budget = batch_size
        out: list[Request] = []
        if not self.mars:
            while self.fifo and cost_fn(self.fifo[0]) <= budget \
                    and self._route_shard(self.fifo[0]):
                r = self.fifo.popleft()
                q = self.pages.get(r.page)
                if q and r in q:
                    q.remove(r)
                    if not q:
                        self._drop_page(r.page)
                    out.append(r)
                    budget -= cost_fn(r)
                    self.total -= 1
        else:
            last_page = None
            deferred = False
            while self.pages and budget > 0 and not deferred:
                # the page holding the oldest buffered request (the MARS
                # forward rule, core/mars._forward) — unlike oldest-page-
                # -allocation order, this bounds delay even when one hot
                # page refills faster than batches drain it
                page = min(self.pages,
                           key=lambda p: self.pages[p][0]._seq)
                q = self.pages[page]
                if cost_fn(q[0]) > budget:
                    break
                if not self._route_shard(q[0]):
                    break
                if page != last_page:
                    self.stats.page_switches += 1
                    last_page = page
                while q and cost_fn(q[0]) <= budget:
                    if not self._route_shard(q[0]):
                        deferred = True
                        break
                    r = q.popleft()
                    try:
                        self.fifo.remove(r)
                    except ValueError:
                        pass
                    out.append(r)
                    budget -= cost_fn(r)
                    self.total -= 1
                if not q:
                    self._drop_page(page)
        self.stats.scheduled += len(out)
        self.stats.batches += 1 if out else 0
        # clamp per-request: a request admitted before its arrival clock
        # tick (offline replay drives `now` coarser than arrivals) has
        # waited nothing, and the aggregate must never go negative
        self.stats.wait_sum += sum(max(now - r.arrival, 0.0) for r in out)
        return out

    def _drop_page(self, page: str) -> None:
        self.pages.pop(page, None)
        self.setload.get(self._set_of(page), set()).discard(page)

    def __len__(self) -> int:
        return self.total


def unique_prefix_blocks(batch: list) -> int:
    """Distinct KV prefix blocks a batch touches (the serving CAS/ACT)."""
    return len({r.page for r in batch})
