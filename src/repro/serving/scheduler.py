"""MARS request scheduler — the paper's architecture as a serving frontend.

This is the *online* software rendering of MARS (the kernels are the bulk
rendering).  Incoming inference requests are the interleaved streams; the
"physical page" is the KV-prefix block (requests sharing a prompt prefix
hit the same cache pages and the same expert routing neighborhoods).  The
three paper structures map 1:1:

  RequestQ       -> bounded request buffer (``request_q`` entries)
  PhyPageList    -> dict keyed by prefix-block hash, holding per-page FIFO
                    lists (set-associativity bounds tracked pages, exactly
                    like the 2-way SRAM table)
  PhyPageOrderQ  -> drain the page holding the oldest buffered request
                    (core/mars._forward) -> bounded delay (no starvation)
                    while batches stay page-coherent

``schedule_batch`` is a two-stage SMS pipeline (staged memory scheduler,
arxiv 1804.11043) when traffic classes are configured:

  stage 1  per-class batch formation (``_form_batch``): each class is one
           source stream with its own PhyPageList, drained by the MARS
           oldest-page rule above, bounded by a per-class admission
           ``quota`` — so MARS page routing (and per-shard prefix
           co-location) is preserved *within* every stream;
  stage 2  batch scheduling (``_class_order``): latency classes first,
           behind an aging escape hatch that promotes any bandwidth class
           whose oldest request has waited past ``max_age`` (no
           starvation), then throughput classes by batch-fill (most
           buffered first).

With ``classes=None`` (the default) there is a single implicit stream
and the pipeline degenerates to the original MARS drain — the class-blind
baseline the mixed-traffic bench compares against.  With MARS off it pops
FIFO — the class-blind baseline below *that*.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict, deque
from typing import Optional, Sequence

import numpy as np

from repro.obs.metrics import Histogram, StatGroup, exp_edges

# per-class wait-time histograms: 0.01ms .. 1e7ms (fake serve clocks count
# whole steps as seconds, so the span must hold thousands of seconds)
WAIT_MS_EDGES = exp_edges(0.01, 10_000_000.0, 64)


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One SMS source stream: a named traffic class with its admission
    policy knobs.

    latency      latency-sensitive (interactive): scheduled ahead of
                 throughput classes, and an arrival of this class bouncing
                 on capacity raises the scheduler's preemption hint.
    quota        max admissions per ``schedule_batch`` call (0 = no cap) —
                 the per-stream batch-formation bound of SMS stage 1.
    queue_depth  max buffered requests of this class (0 = no cap); beyond
                 it ``offer`` rejects with reason "class_depth".
    max_age      aging escape hatch, in serve-clock seconds: a non-latency
                 class whose oldest buffered request has waited at least
                 this long is scheduled ahead of the latency classes
                 (0 = never ages).  Bounds bandwidth-class delay so
                 latency-first cannot starve anyone.
    """
    name: str
    latency: bool = False
    quota: int = 0
    queue_depth: int = 0
    max_age: float = 0.0


def default_classes(n: int = 3) -> tuple:
    """The stock interactive / batch / long-context-stream mix the
    ``--classes N`` serve flag installs (first ``n`` of the presets)."""
    presets = (
        TrafficClass("interactive", latency=True),
        TrafficClass("batch", quota=2, max_age=8.0),
        TrafficClass("stream", quota=1, max_age=12.0),
    )
    assert 1 <= n <= len(presets), f"--classes supports 1..{len(presets)}"
    return presets[:n]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: tuple           # token tuple (hashable)
    arrival: float = 0.0
    prefix_len: int = 64    # block size for page hashing
    max_new: int = 16
    n_samples: int = 1      # parallel samples (forked lanes, CoW tails)
    traffic_class: str = "default"   # SMS source stream this request joins

    @property
    def page(self) -> str:
        block = self.prompt[:self.prefix_len]
        return hashlib.sha1(repr(block).encode()).hexdigest()[:12]

    def blocks_needed(self, block_size: int) -> int:
        """Worst-case (no prefix sharing) KV blocks over the full lifetime,
        counting every forked sample as its own sequence."""
        return -(-(len(self.prompt) + self.max_new) // block_size) \
            * self.n_samples


class SchedulerStats(StatGroup):
    """Scheduling counters as an ``obs.metrics.StatGroup`` facade (same
    attribute API as the old dataclass; a ``MetricsRegistry`` adopts the
    live counters).  The derived ratios stay plain properties."""
    FIELDS = {"scheduled": 0, "batches": 0, "page_switches": 0,
              "stall_rejects": 0, "pool_rejects": 0,
              # sharded pool: no shard had headroom yet
              "shard_defers": 0, "wait_sum": 0.0}

    @property
    def pages_per_batch(self) -> float:
        return self.page_switches / max(self.batches, 1)

    @property
    def mean_wait(self) -> float:
        """Aggregate mean wait over ALL classes — a capacity summary, not
        a latency metric.  Per-class latency lives in ``ClassStats`` /
        the ``class.<name>.*`` histograms: averaging interactive and batch
        waits together was the bug this split fixes."""
        return self.wait_sum / max(self.scheduled, 1)


class ClassStats(StatGroup):
    """Per-traffic-class counters (one group per configured class,
    adopted by the registry as ``class.<name>.<field>``)."""
    FIELDS = {"admit": 0, "reject": 0, "defer": 0, "preempt": 0,
              "scheduled": 0, "wait_sum": 0.0}

    @property
    def mean_wait(self) -> float:
        return self.wait_sum / max(self.scheduled, 1)


class MarsScheduler:
    """Bounded-lookahead, page-grouping, oldest-page-first batcher with
    SMS-staged traffic classes on top (see module docstring)."""

    def __init__(self, request_q: int = 512, page_entries: int = 128,
                 ways: int = 2, mars: bool = True, pool=None,
                 classes: Optional[Sequence[TrafficClass]] = None):
        self.request_q = request_q
        self.page_entries = page_entries
        self.nsets = page_entries // ways
        self.ways = ways
        self.mars = mars
        cl = list(classes) if classes else [TrafficClass("default")]
        assert len({c.name for c in cl}) == len(cl), "duplicate class names"
        self.classes: dict[str, TrafficClass] = {c.name: c for c in cl}
        self._default_cls = cl[0].name   # unknown tags fall back here
        # per-class PhyPageList: class -> page -> FIFO of requests.  The
        # ways table stays GLOBAL (one SRAM analog): a page buffered by
        # two classes holds one way, released when the last class drains
        # it (``_page_classes`` tracks the holders).
        self.pages: dict[str, "OrderedDict[str, deque]"] = \
            {c.name: OrderedDict() for c in cl}
        self._page_classes: dict[str, set] = {}
        self.setload: dict[int, set] = {}
        self.fifo: deque = deque()
        self.total = 0
        self._cls_total: dict[str, int] = {c.name: 0 for c in cl}
        self.stats = SchedulerStats()
        self.class_stats: dict[str, ClassStats] = \
            {c.name: ClassStats() for c in cl}
        self.wait_hist: dict[str, Histogram] = \
            {c.name: Histogram(WAIT_MS_EDGES) for c in cl}
        # overload signal for the engine: a latency-class request just
        # bounced on capacity (offer reject) or deferred (no shard
        # headroom) — preempting a running throughput decode would free
        # the headroom it needs.  Cleared by ``take_preempt_hint``.
        self.preempt_wanted = False
        # KV block pool (``kvcache.BlockPool``): admission is bounded by
        # physical cache capacity, not just RequestQ entries.  A request's
        # worst-case block need is reserved in the pool at offer(); the
        # engine converts the reservation into real allocations as the
        # sequence grows and releases the remainder when it finishes
        # (reservations must outlive scheduling — decode blocks are
        # allocated lazily, long after the batch was formed).
        self.pool = pool
        self._seq = 0                            # arrival counter
        self.obs = None          # telemetry hook (obs.Observer.attach)
        # tiered KV memory (sharded pools): optional probe mapping a
        # prompt to the shard whose spill tiers hold its prefix
        # (``ShardedPagedBackend.tier_shard_for``) — admission counts a
        # promotable lower-tier hit toward affinity routing, so the
        # request lands where its demoted blocks are instead of
        # recomputing them elsewhere
        self.tier_probe = None

    def _set_of(self, page: str) -> int:
        return int(page, 16) % self.nsets

    def _class_of(self, req: Request) -> str:
        name = getattr(req, "traffic_class", self._default_cls)
        return name if name in self.classes else self._default_cls

    def offer(self, req: Request) -> bool:
        """Insert (paper Fig 5).  False = backpressure to the client."""
        ok, reason = self._offer(req)
        if self.obs is not None:
            self.obs.trace.event("sched.offer", rid=req.rid,
                                 page=req.page, ok=ok, reason=reason)
        return ok

    def _offer(self, req: Request) -> tuple:
        """(accepted, reason) — reason names the reject path ("ok",
        "queue_full", "class_depth", "pool_capacity", "page_ways")."""
        cname = self._class_of(req)
        cls = self.classes[cname]
        cs = self.class_stats[cname]
        req._cls = cname
        if self.total >= self.request_q:
            self.stats.stall_rejects += 1
            cs.reject += 1
            return False, "queue_full"
        if cls.queue_depth and self._cls_total[cname] >= cls.queue_depth:
            self.stats.stall_rejects += 1
            cs.reject += 1
            return False, "class_depth"
        if self.pool is not None:
            if not self.pool.can_reserve(
                    req.blocks_needed(self.pool.cfg.block_size)):
                self.stats.pool_rejects += 1
                cs.reject += 1
                if cls.latency:
                    self.preempt_wanted = True
                return False, "pool_capacity"
        page = req.page
        pages = self.pages[cname]
        if page not in pages:
            if not self._page_classes.get(page):
                # page tracked by no class yet: it needs a ways slot
                s = self._set_of(page)
                ways = self.setload.setdefault(s, set())
                if len(ways) >= self.ways:
                    self.stats.stall_rejects += 1
                    cs.reject += 1
                    return False, "page_ways"
                ways.add(page)
            self._page_classes.setdefault(page, set()).add(cname)
            pages[page] = deque()
        req._seq = self._seq            # arrival stamp: drain-order key
        self._seq += 1
        pages[page].append(req)
        self.fifo.append(req)
        self.total += 1
        self._cls_total[cname] += 1
        cs.admit += 1
        if self.pool is not None:
            self.pool.reserve(req.blocks_needed(self.pool.cfg.block_size))
        return True, "ok"

    def _route_shard(self, r: Request) -> bool:
        """Sharded pools only: commit ``r``'s aggregate admission
        reservation to a concrete shard (``ShardedBlockPool.route`` —
        prefix-page affinity first, then least shard load), stamping the
        choice on ``r._shard`` for the engine to honor at prefill.

        False = no shard has headroom *right now*; the request stays
        buffered (its ``_seq`` keeps its drain priority) and its class's
        formation stops so the class's oldest request is never skipped —
        bounded delay is preserved, admission just waits for running
        sequences to free their shard.  A deferred *latency*-class
        request additionally raises the preemption hint.  Single pools
        always return True.
        """
        if self.pool is None or not getattr(self.pool, "is_sharded", False):
            return True
        if getattr(r, "_shard", None) is not None:
            return True              # already routed (re-scheduled batch)
        hint = None if self.tier_probe is None \
            else self.tier_probe(r.prompt)
        shard = self.pool.route(
            r.rid, r.page, r.blocks_needed(self.pool.cfg.block_size),
            tier_hint=hint)
        if shard is None:
            self.stats.shard_defers += 1
            cname = getattr(r, "_cls", self._default_cls)
            self.class_stats[cname].defer += 1
            if self.classes[cname].latency:
                self.preempt_wanted = True
            if self.obs is not None:
                self.obs.trace.event("sched.defer", rid=r.rid,
                                     traffic_class=cname)
            return False
        r._shard = shard
        if self.obs is not None:
            self.obs.trace.event("sched.route", rid=r.rid, shard=shard)
        return True

    # -- stage 2: batch scheduling policy -----------------------------------

    def _class_order(self, now: float) -> list:
        """Which stream to drain next (SMS stage 2): aged bandwidth
        classes first (the no-starvation escape hatch — their oldest
        request has waited past ``max_age``), then latency classes, then
        throughput classes by batch-fill (most buffered first).  Ties
        break toward the older head request."""
        live = [c for c in self.classes.values()
                if self._cls_total[c.name] > 0]
        if len(live) <= 1:
            return live

        def head(c):
            pages = self.pages[c.name]
            return min((q[0] for q in pages.values()),
                       key=lambda r: r._seq)

        aged, lat, thru = [], [], []
        for c in live:
            h = head(c)
            if not c.latency and c.max_age > 0 \
                    and now - h.arrival >= c.max_age:
                aged.append((h._seq, c.name))
            elif c.latency:
                lat.append((h._seq, c.name))
            else:
                thru.append((-self._cls_total[c.name], h._seq, c.name))
        names = [n for _, n in sorted(aged)] \
            + [n for _, n in sorted(lat)] \
            + [n for _, _, n in sorted(thru)]
        return [self.classes[n] for n in names]

    # -- stage 1: per-class batch formation ---------------------------------

    def _form_batch(self, cls: TrafficClass, budget: int, cost_fn, out: list,
                    last_page) -> tuple:
        """Drain class ``cls``'s oldest pages to exhaustion (paper Fig 6
        scoped to one source stream), bounded by the shared lane
        ``budget`` and the class admission ``quota``.  Appends to ``out``
        and returns (budget, last_page, admitted)."""
        pages = self.pages[cls.name]
        quota = cls.quota if cls.quota > 0 else (1 << 30)
        n = 0
        deferred = False
        while pages and budget > 0 and n < quota and not deferred:
            # the page holding the oldest buffered request (the MARS
            # forward rule, core/mars._forward) — unlike oldest-page-
            # -allocation order, this bounds delay even when one hot
            # page refills faster than batches drain it
            page = min(pages, key=lambda p: pages[p][0]._seq)
            q = pages[page]
            if cost_fn(q[0]) > budget:
                break
            if not self._route_shard(q[0]):
                break
            if page != last_page:
                self.stats.page_switches += 1
                last_page = page
            while q and cost_fn(q[0]) <= budget and n < quota:
                if not self._route_shard(q[0]):
                    deferred = True
                    break
                r = q.popleft()
                try:
                    self.fifo.remove(r)
                except ValueError:
                    pass
                out.append(r)
                budget -= cost_fn(r)
                self.total -= 1
                self._cls_total[cls.name] -= 1
                n += 1
            if not q:
                self._drop_page(page, cls.name)
        return budget, last_page, n

    def schedule_batch(self, batch_size: int, now: float | None = None,
                       cost_fn=None) -> list:
        """Forward (paper Fig 6), SMS-staged: ``_class_order`` picks the
        stream, ``_form_batch`` drains it page-major.

        ``batch_size`` is a budget; each request costs ``cost_fn(r)``
        (default 1 — e.g. the engine charges one lane per forked sample).
        Scheduling stops before the first request that would overrun it.

        With a sharded pool every admitted request is additionally routed
        to a shard (``_route_shard``): page-grouped draining means the
        whole page's requests land on one shard back-to-back — the
        co-location that makes per-shard prefix caches hit.
        """
        now = time.time() if now is None else now
        cost_fn = cost_fn or (lambda r: 1)
        budget = batch_size
        out: list[Request] = []
        if not self.mars:
            # class-blind FIFO baseline
            while self.fifo and cost_fn(self.fifo[0]) <= budget \
                    and self._route_shard(self.fifo[0]):
                r = self.fifo.popleft()
                cname = getattr(r, "_cls", self._default_cls)
                q = self.pages[cname].get(r.page)
                if q and r in q:
                    q.remove(r)
                    if not q:
                        self._drop_page(r.page, cname)
                    out.append(r)
                    budget -= cost_fn(r)
                    self.total -= 1
                    self._cls_total[cname] -= 1
        else:
            last_page = None
            for cls in self._class_order(now):
                if budget <= 0:
                    break
                budget, last_page, _ = self._form_batch(
                    cls, budget, cost_fn, out, last_page)
        self.stats.scheduled += len(out)
        self.stats.batches += 1 if out else 0
        # wait accounting, split per class (the old single aggregate let a
        # deferred batch request inflate the interactive latency stats).
        # clamp per-request: a request admitted before its arrival clock
        # tick (offline replay drives `now` coarser than arrivals) has
        # waited nothing, and the aggregate must never go negative
        admitted: dict[str, int] = {}
        for r in out:
            w = max(now - r.arrival, 0.0)
            cname = getattr(r, "_cls", self._default_cls)
            cs = self.class_stats[cname]
            cs.scheduled += 1
            cs.wait_sum += w
            self.wait_hist[cname].observe(w * 1e3)
            self.stats.wait_sum += w
            admitted[cname] = admitted.get(cname, 0) + 1
        if self.obs is not None and out:
            self.obs.trace.event(
                "sched.batch", classes=admitted,
                quotas={c: self.classes[c].quota for c in admitted})
            for cname in admitted:
                h = self.wait_hist[cname]
                self.obs.registry.set(f"class.{cname}.p50_ms",
                                      h.quantile(0.50))
                self.obs.registry.set(f"class.{cname}.p99_ms",
                                      h.quantile(0.99))
        return out

    # -- preemption signalling (consumed by serve/engine.py) ----------------

    def take_preempt_hint(self) -> bool:
        """True once per overload signal: a latency-class request bounced
        on pool capacity or deferred on shard headroom since the last
        call.  The engine responds by pausing a running throughput-class
        decode (``ServeEngine._maybe_preempt``)."""
        hint, self.preempt_wanted = self.preempt_wanted, False
        return hint

    def note_preempt(self, cname: str) -> None:
        """Engine callback: one running decode of class ``cname`` was
        paused to free headroom."""
        cs = self.class_stats.get(cname)
        if cs is None:
            cs = self.class_stats[self._default_cls]
        cs.preempt += 1

    def _drop_page(self, page: str, cname: str) -> None:
        self.pages[cname].pop(page, None)
        owners = self._page_classes.get(page)
        if owners is not None:
            owners.discard(cname)
            if owners:       # another class still buffers this page
                return
            del self._page_classes[page]
        self.setload.get(self._set_of(page), set()).discard(page)

    def __len__(self) -> int:
        return self.total


def unique_prefix_blocks(batch: list) -> int:
    """Distinct KV prefix blocks a batch touches (the serving CAS/ACT)."""
    return len({r.page for r in batch})
